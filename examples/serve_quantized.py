"""Example: train a small LM briefly, PTQ it with STaMP (W4A4KV4 + 64@8b),
and serve batched requests — comparing generation fidelity with and without
the sequence transform at the same bit budget.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import calibrate_and_quantize
from repro.data.pipeline import DataConfig, DataIterator, calibration_batches
from repro.launch.train import TrainConfig, train
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, ServingEngine

CFG = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                  d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                  vocab_size=512, tie_embeddings=True)


def main():
    # 1. train briefly so generations are non-trivial
    out = train(CFG, TrainConfig(steps=80, global_batch=8, seq=128, lr=3e-3),
                ckpt_dir=None, verbose=False)
    params = out["params"]
    print(f"trained: loss {out['losses'][0]:.2f} -> {out['losses'][-1]:.2f}")

    # 2. PTQ: calibrate + quantize (STaMP DWT, mixed-precision KV cache)
    dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=128, global_batch=4)
    sparams, serve, report = calibrate_and_quantize(
        params, calibration_batches(dcfg, 2), CFG)
    print(f"ptq: num_hi={report.num_hi} avg_bits={report.avg_bits:.3f} "
          f"toeplitz_fraction={report.toeplitz_fraction:.2f}")

    # 3. serve the same prompts with and without STaMP; compare to bf16
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, 96) for _ in range(8)]

    def run(sp, sv, tag):
        eng = ServingEngine(sp, CFG, sv, EngineConfig(max_batch=8,
                                                      bucket=96, max_seq=128))
        for p in prompts:
            eng.submit(p, max_new_tokens=16)
        done = eng.run()
        return np.stack([r.out_tokens for r in sorted(done,
                                                      key=lambda r: r.uid)])

    ref = run(params, lm.ServeConfig(
        stamp=None, kv=dataclasses.replace(serve.kv, quantized=False),
        weight_bits=None), "bf16")
    with_stamp = run(sparams, serve, "stamp")
    without = run(sparams, dataclasses.replace(serve, stamp=None), "plain")

    agree_stamp = float((with_stamp == ref).mean())
    agree_plain = float((without == ref).mean())
    print(f"token agreement vs bf16 reference: "
          f"W4A4KV4+STaMP {agree_stamp:.2%}  |  W4A4KV4 uniform "
          f"{agree_plain:.2%}")


if __name__ == "__main__":
    main()

"""Quickstart: STaMP in 60 seconds.

Shows the paper's core result on locally-correlated activations:
at the same average bit width, sequence-transform + mixed precision beats
uniform per-token quantization — and composes with feature transforms.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core import transforms as T
from repro.core.feature_transforms import hadamard_matrix
from repro.core.stamp import StampConfig, stamp_fake_quant
from repro.data.pipeline import ar_features

# 1. locally-correlated activations, like a transformer block sees
#    (batch 8, sequence 2048, features 256; AR(1) along the sequence)
x = jnp.asarray(ar_features((8, 2048, 256), rho=0.95, seed=0))

# 2. uniform per-token 4.125-bit quantization (matched budget baseline)
bits_budget = (64 * 8 + (2048 - 64) * 4) / 2048          # = 4.125
uniform = Q.fake_quant(x, bits_budget, axis=-1)
print(f"uniform A{bits_budget:.3f}:       SQNR = "
      f"{float(Q.sqnr_db(x, uniform)):6.2f} dB")

# 3. STaMP: Haar DWT along the sequence + 64 tokens at 8 bits, rest at 4
cfg = StampConfig(seq_transform="dwt", num_hi_tokens=64,  # levels auto
                  skip_first_token=False)
stamped = stamp_fake_quant(x, cfg)
print(f"STaMP  A{cfg.average_bits(2048):.3f} (DWT+MP): SQNR = "
      f"{float(Q.sqnr_db(x, stamped)):6.2f} dB")

# 4. ... and it composes with a feature transform (QuaRot-style Hadamard)
r = jnp.asarray(hadamard_matrix(256))
tx = T.haar_dwt(x, levels=5) @ r
bits = Q.mixed_precision_bits(2048, 64)
tq = Q.fake_quant(tx, bits, axis=-1)
both = T.haar_idwt(tq @ r.T, levels=5)
print(f"STaMP + Hadamard:        SQNR = {float(Q.sqnr_db(x, both)):6.2f} dB")

# 5. the energy story behind it (paper Fig. 3b)
e = np.asarray(jnp.sum(T.haar_dwt(x, levels=5) ** 2, axis=(0, -1)))
print(f"\nenergy in first 64/2048 transformed tokens: "
      f"{e[:64].sum() / e.sum() * 100:.1f}% (uniform would be 3.1%)")

"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic locally-correlated corpus, with checkpointing and the same
sharded train step the 512-chip dry run lowers.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: 12 layers, d_model 768, llama-style — a few ms/step on TPU,
minutes on this CPU container; use --tiny for a smoke run.)
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import TrainConfig, train
from repro.models.config import ModelConfig


def hundred_m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=8192, tie_embeddings=True,
        schedule="wsd",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m()
    if args.tiny:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, d_ff=256,
                                  vocab_size=512)
    tc = TrainConfig(steps=args.steps, global_batch=4 if args.tiny else 8,
                     seq=128 if args.tiny else 512, lr=3e-3 if args.tiny else 1e-3,
                     ckpt_every=max(args.steps // 4, 10))
    out = train(cfg, tc, ckpt_dir=args.ckpt_dir)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps")
    assert out["losses"][-1] < out["losses"][0], "model failed to learn"


if __name__ == "__main__":
    main()

"""repro: STaMP (sequence-transform + mixed-precision activation
quantization) as a first-class feature of a multi-pod JAX training/serving
framework."""

__version__ = "0.1.0"

"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407; hf] — GQA kv=8,
head_dim=128 (q_dim 4096 != d_model 5120), 128k context."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=160, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=448, vocab_size=512)

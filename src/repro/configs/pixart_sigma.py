"""PixArt-Sigma-like DiT backbone — the paper's LVM evaluation model
(Table 1).  We model the transformer blocks (self-attn + cross-attn + FFN)
on a flattened 2-D latent grid; conditioning is a pooled-text stub.  Used
by the LVM benchmarks, not by the assigned dry-run cells."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixart-sigma", family="dense",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=8,          # DiT: no vocab; stub for the LM head
    source="arXiv:2403.04692 (paper's Table 1 model)",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256)

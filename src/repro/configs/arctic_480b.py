"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf] —
128 experts top-2 with a dense residual MLP in parallel."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=4864, vocab_size=32000,
    num_experts=128, experts_per_token=2, moe_d_ff=4864,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, num_experts=8,
        experts_per_token=2, moe_d_ff=128)

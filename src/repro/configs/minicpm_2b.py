"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, MHA, WSD schedule."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    schedule="wsd", tie_embeddings=True,
    source="arXiv:2404.06395; hf",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=320, vocab_size=512)

"""Mamba2-1.3B [arXiv:2405.21060; unverified] — attention-free SSD
(state-space duality), ssm_state=128.  Serves under `PagedServingEngine`
pageless: the slot-dense SSM state pool is the whole cache, so slots are
the only capacity dimension (no page reservation, no preemption)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    sub_quadratic=True, tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, vocab_size=512,
        ssm_state=16, ssm_head_dim=16)

"""Qwen2-72B [arXiv:2407.10671; hf] — GQA kv=8, QKV bias."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6,
    source="arXiv:2407.10671; hf",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=384, vocab_size=512)

"""SeamlessM4T-Large-v2 [arXiv:2308.11596; hf] — encoder-decoder,
multimodal.  The speech frontend is a STUB: input_specs provides
pre-computed frame embeddings (b, seq/frame_ratio, d_model)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encoder_layers=24, frontend="frames", frame_ratio=4,
    source="arXiv:2308.11596; hf",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512)

"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — hybrid Mamba+attention
1:7 interleave (1 attention layer per period of 8), MoE 16e top-2 every
other layer.  The Mamba branch is implemented as Mamba2/SSD (state 128,
headdim 64) — see DESIGN.md §Arch-applicability for the substitution note.

Serves first-class under `PagedServingEngine`: paged mixed-precision K/V
for the attention layers + the slot-dense per-slot SSM state pool for the
Mamba layers (`reduced()` is the hybrid row in BENCH_serving.json)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_period=2,
    attn_period=8, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    sub_quadratic=True,
    source="arXiv:2403.19887; hf",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, num_experts=4, experts_per_token=2,
        ssm_state=16, ssm_head_dim=16)

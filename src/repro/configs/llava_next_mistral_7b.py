"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified] — VLM: anyres patch tiling is a frontend STUB; input_specs
provides pre-computed merged patch embeddings at d_model."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    frontend="patch", num_patches=576,
    rope_theta=1e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=384, vocab_size=512, num_patches=16)

"""Llama-3-8B — the paper's own LLM evaluation model (Table 2)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    rope_theta=5e5,
    source="arXiv:2407.21783 (paper's Table 2 model)",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=384, vocab_size=512)

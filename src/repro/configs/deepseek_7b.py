"""DeepSeek-7B [arXiv:2401.02954; hf] — dense llama-arch, MHA."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    source="arXiv:2401.02954; hf",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=352, vocab_size=512)

"""Assigned architecture registry: ``get_config(arch_id)``.

Each module defines ``CONFIG`` (the full published configuration) and
``reduced()`` (a tiny same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "minicpm_2b",
    "deepseek_7b",
    "mistral_nemo_12b",
    "qwen2_72b",
    "llava_next_mistral_7b",
    "jamba_1_5_large_398b",
    "seamless_m4t_large_v2",
    "kimi_k2_1t_a32b",
    "arctic_480b",
    "mamba2_1_3b",
    # the paper's own evaluation models
    "llama3_8b",
    "pixart_sigma",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "minicpm-2b": "minicpm_2b",
    "deepseek-7b": "deepseek_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-72b": "qwen2_72b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "arctic-480b": "arctic_480b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llama3-8b": "llama3_8b",
    "pixart-sigma": "pixart_sigma",
})


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_reduced(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.reduced()

"""Kimi-K2 1T-A32B [arXiv:2501.kimi2; unverified] — trillion-param MoE:
61 layers, 384 experts top-8 with per-expert d_ff=2048, first layer dense."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    head_dim=112, d_ff=18432, vocab_size=163840,
    num_experts=384, experts_per_token=8, moe_d_ff=2048,
    first_layer_dense=True,
    source="arXiv:2501.kimi2; unverified",
)

def reduced():
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=384, vocab_size=512, num_experts=8,
        experts_per_token=2, moe_d_ff=64)

"""AdamW with decoupled weight decay and global-norm clipping.

Kept as pure functions over pytrees so the dry-run `train_step` lowers the
*complete* production update (moments, clipping, schedule) and the memory
analysis reflects true optimizer-state residency.  Moments inherit the
parameter sharding (ZeRO-style: FSDP-sharded params ⇒ FSDP-sharded state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def adamw_init(params: Pytree, cfg: AdamWConfig) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    grads: Pytree,
    opt_state: Pytree,
    params: Pytree,
    cfg: AdamWConfig,
) -> tuple[Pytree, Pytree, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}

"""Gradient compression with error feedback for cross-pod all-reduce.

At 512+ chips the data-parallel gradient all-reduce crosses the (slow)
pod-to-pod links; int8 compression with per-tensor scales cuts those bytes
4× versus f32.  Error feedback (residual accumulation) keeps convergence:
``g_sent = Q(g + e);  e ← (g + e) − g_sent`` — the standard EF-SGD scheme.

The compressed collective composes with pjit: gradients are quantized
*before* `jax.lax.psum` inside a `shard_map`'d section (or, in auto-sharding
mode, before the optimizer step with GSPMD inserting the all-reduce on the
int8 tensor).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def compress_gradients(grads: Pytree, error: Pytree) -> tuple[Pytree, Pytree, Pytree]:
    """Returns (int8 grads, scales, new error residuals)."""
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(corrected)
        deq = q.astype(jnp.float32) * scale
        return q, scale, corrected - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    errs = treedef.unflatten([o[2] for o in out])
    return qs, scales, errs


def decompress_gradients(qs: Pytree, scales: Pytree, dtype=jnp.float32) -> Pytree:
    return jax.tree.map(lambda q, s: (q.astype(jnp.float32) * s).astype(dtype),
                        qs, scales)


def error_feedback_update(grads: Pytree, error: Pytree) -> tuple[Pytree, Pytree]:
    """One quantize→dequantize round trip, returning the gradients a receiver
    would reconstruct plus the updated error state (used when GSPMD owns the
    collective: the int8 tensor is what crosses the pod links)."""
    qs, scales, new_error = compress_gradients(grads, error)
    return decompress_gradients(qs, scales), new_error


def init_error_state(grads_shape: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)

"""Learning-rate schedules: cosine and WSD (warmup–stable–decay).

MiniCPM (arXiv:2404.06395) trains with WSD — the assigned minicpm-2b config
selects it via ``ModelConfig.schedule = 'wsd'``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01) -> Callable:
    """Warmup → stable plateau → sharp (exponential) decay over the final
    ``decay_frac`` of training (MiniCPM §4)."""
    decay_start = int(total * (1 - decay_frac))

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - decay_start) / max(total - decay_start, 1),
                        0.0, 1.0)
        decay = peak_lr * jnp.exp(jnp.log(min_ratio) * frac)
        out = jnp.where(step < warmup, warm, peak_lr)
        return jnp.where(step >= decay_start, decay, out)
    return fn


def make_schedule(kind: str, peak_lr: float, warmup: int, total: int) -> Callable:
    if kind == "wsd":
        return wsd_schedule(peak_lr, warmup, total)
    return cosine_schedule(peak_lr, warmup, total)

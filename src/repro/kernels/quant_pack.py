"""Pallas TPU kernel: fused per-token min-max quantization + int4 packing.

One VMEM pass computes the per-token min/max (Eq. 1's scale/offset), rounds,
clamps, and packs two int4 nibbles per byte along the feature axis — the
memory-bound triple (reduce, scale, pack) that a naive XLA lowering would
run as three HBM round trips.

Outputs: packed (s, d/2) uint8 (or unpacked int8 for bits=8), scale (s, 1)
f32, zero-point (s, 1) f32 — the mixed-precision KV-cache layout of
`repro.serving.kvcache`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, scale_ref, zp_ref, *, bits: int):
    x = x_ref[0].astype(jnp.float32)                  # (bs, d)
    n = float(2**bits - 1)
    mn = jnp.min(x, axis=-1, keepdims=True)
    mx = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / n, 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(x / scale) + zp, 0.0, n)
    if bits == 4:
        qi = q.astype(jnp.uint8)
        hi = qi[:, 0::2]
        lo = qi[:, 1::2]
        q_ref[0] = ((hi << 4) | lo).astype(jnp.uint8)
        zp_ref[0] = zp
    else:
        # unsigned codes shifted into int8 storage; zero point shifted
        # identically so (q − zp)·s is unchanged (MXU int8 is signed)
        q_ref[0] = (q - 128.0).astype(jnp.int8)
        zp_ref[0] = zp - 128.0
    scale_ref[0] = scale


def quant_pack_pallas(x: jax.Array, bits: int = 4, block_s: int = 256,
                      interpret: bool | None = None):
    """x: (batch, s, d) → (packed, scale, zp).

    d must be even for bits=4 (nibble pairs); block_s rows are quantized per
    program so the working set (block_s × d × 4 B) stays inside VMEM.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, s, d = x.shape
    bs = min(block_s, s)
    if s % bs:
        raise ValueError(f"seq {s} not divisible by block_s={bs}")
    out_d = d // 2 if bits == 4 else d
    out_dtype = jnp.uint8 if bits == 4 else jnp.int8
    kernel = functools.partial(_quant_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(b, s // bs),
        in_specs=[pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0))],
        out_specs=(
            pl.BlockSpec((1, bs, out_d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, 1), lambda i, j: (i, j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, s, out_d), out_dtype),
            jax.ShapeDtypeStruct((b, s, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, s, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x)

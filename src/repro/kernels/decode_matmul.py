"""Pallas TPU kernel: single-token STaMP decode matmul.

Decode feeds one token per slot through each linear, so there is no sequence
axis to transform — STaMP degenerates to per-token activation quantization
against the **already-prepared** int8 weight buffers
(`repro.core.stamp.prepare_linear`).  Before this kernel the decode path
re-dequantized those buffers to bf16 every step (the ROADMAP open item):
per linear per step that re-materializes the full (K, N) weight in HBM.
Here the int8 codes stream in directly:

    1. ``Q(x)``      — per-row (per-slot) asymmetric min-max quantize at
                       8 bits, codes shifted into signed int8 (one decode
                       token always sits in the hi-precision budget);
    2. ``Q(x) · Wq`` — int8 × int8 MXU GEMM, int32 accumulation, with the
                       per-row/per-column zero-point-correction epilogue
                       shared with `stamp_matmul.py`;
    3. ``+ 1βᵀ``     — bias inside the same VMEM residency.

Grid: ``(N / block_n,)``.  The (B, K) token batch is VMEM-resident across
all output blocks; quantization runs once (first grid step) into scratch.
HBM per step: B·K activation + K·N **int8** weight + B·N output — vs the
dequant path's extra K·N bf16 write + read every call.

Place in the unified ragged step: the single compiled step program
contains both regions, and `_linear`'s token-dim shape guard routes only
the decode sub-tensors ``(S, 1, d)`` here — chunk rows (C > 1) never
match, so the sequence transform can't be skipped on prefill work.  The
all-decode steady-state step (n_pf = 0) delegates to the plain decode
graph, where this kernel serves every prepared-weight linear exactly as
it did for the two-call engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, qw_ref, sw_ref, zw_ref, b_ref, o_ref,
            qx_ref, sx_ref, zx_ref, *, k_total: int):
    @pl.when(pl.program_id(0) == 0)
    def _quantize():
        x = x_ref[...].astype(jnp.float32)                 # (B, K)
        mn = jnp.min(x, axis=-1, keepdims=True)
        mx = jnp.max(x, axis=-1, keepdims=True)
        sx = jnp.maximum((mx - mn) / 255.0, 1e-8)
        zx = jnp.round(-mn / sx)
        q = jnp.clip(jnp.round(x / sx) + zx, 0.0, 255.0)
        qx_ref[...] = (q - 128.0).astype(jnp.int8)
        sx_ref[...] = sx
        zx_ref[...] = zx - 128.0

    qx = qx_ref[...]                                       # (B, K) int8
    qw = qw_ref[...]                                       # (K, bn) int8
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    qw_sum = jnp.sum(qw.astype(jnp.int32), axis=0,
                     keepdims=True).astype(jnp.float32)
    qx_sum = jnp.sum(qx.astype(jnp.int32), axis=1,
                     keepdims=True).astype(jnp.float32)
    sw = sw_ref[...].astype(jnp.float32)                   # (1, bn)
    zw = zw_ref[...].astype(jnp.float32)
    zxs = zx_ref[...]
    corr = acc - zxs * qw_sum - zw * qx_sum + float(k_total) * zxs * zw
    y = corr * sx_ref[...] * sw
    o_ref[...] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def stamp_decode_matmul_pallas(
    x: jax.Array,            # (B, K) float — one token per decode slot
    qw: jax.Array,           # (K, N) int8 signed codes
    sw: jax.Array,           # (1, N) f32 per-output-channel scale
    zw: jax.Array,           # (1, N) f32 signed-shifted zero point
    bias: jax.Array,         # (1, N) f32
    *,
    block_n: int = 512,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused decode linear: ``Q8(x) · Wq_deq + bias`` in one kernel."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, k = x.shape
    k2, n = qw.shape
    if k != k2:
        raise ValueError(f"activation K={k} does not match weight K={k2}")
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    kernel = functools.partial(_kernel, k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), out_dtype or x.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.int8),      # quantized token codes
            pltpu.VMEM((b, 1), jnp.float32),   # per-token scale
            pltpu.VMEM((b, 1), jnp.float32),   # per-token (shifted) zp
        ],
        interpret=interpret,
    )(x, qw, sw, zw, bias)

"""Pallas TPU kernel: fast Walsh–Hadamard transform (sequence or feature).

The CUDA warp-shuffle butterflies of `fast-hadamard-transform` become
in-VMEM (s/2h, 2, h, bd) reshapes; Mosaic lowers the pairwise add/sub to
VREG-level shuffles on (8, 128) tiles.  All log2(n) stages run in one VMEM
residency — one HBM read + one write, versus one round trip per stage if
expressed as XLA ops.

Sequence mode transforms axis -2 (STaMP's L); feature mode transforms the
last axis (QuaRot's R) by transposing tiles on the fly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _wht_seq_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[0].astype(jnp.float32)          # (s, bd); s == n (pow2)
    h = 1
    while h < n:
        shaped = x.reshape(n // (2 * h), 2, h, x.shape[-1])
        a = shaped[:, 0]
        b = shaped[:, 1]
        x = jnp.stack([a + b, a - b], axis=1).reshape(n, x.shape[-1])
        h *= 2
    o_ref[0] = (x * float(1.0 / np.sqrt(n))).astype(o_ref.dtype)


def _wht_feat_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[0].astype(jnp.float32)          # (bs, d); d == n (pow2)
    h = 1
    while h < n:
        shaped = x.reshape(x.shape[0], n // (2 * h), 2, h)
        a = shaped[:, :, 0]
        b = shaped[:, :, 1]
        x = jnp.stack([a + b, a - b], axis=2).reshape(x.shape[0], n)
        h *= 2
    o_ref[0] = (x * float(1.0 / np.sqrt(n))).astype(o_ref.dtype)


def wht_pallas(x: jax.Array, axis: int = -2, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Orthonormal WHT along ``axis`` (-2 sequence, -1 feature).
    The transformed axis length must be a power of two."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, s, d = x.shape
    if axis in (-2, 1):
        n = s
        if n & (n - 1):
            raise ValueError(f"seq {n} not a power of two")
        if d % block:
            raise ValueError(f"d={d} not divisible by block={block}")
        kernel = functools.partial(_wht_seq_kernel, n=n)
        return pl.pallas_call(
            kernel,
            grid=(b, d // block),
            in_specs=[pl.BlockSpec((1, s, block), lambda i, j: (i, 0, j))],
            out_specs=pl.BlockSpec((1, s, block), lambda i, j: (i, 0, j)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x)
    n = d
    if n & (n - 1):
        raise ValueError(f"feature dim {n} not a power of two")
    if s % block and s >= block:
        raise ValueError(f"seq {s} not divisible by block={block}")
    bs = min(block, s)
    kernel = functools.partial(_wht_feat_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b, s // bs),
        in_specs=[pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)

"""Pallas TPU kernel: decode attention over the block-paged mixed-precision
KV cache — the continuous-batching counterpart of `cache_attention.py`.

The contiguous kernel streams one dense packed cache per batch slot.  Here
each slot owns a **block table** into two shared page pools (int8 sink pages
for the first ``num_hi`` tokens, int4-nibble-packed pages for the rest — see
`serving/paged_kvcache.py`), so the kernel must *walk the table*: the page
fetched at grid step ``(slot, kv_head, logical_block)`` is chosen by a
scalar-prefetched table lookup inside the BlockSpec index map.  Mosaic
pipelines those dynamic fetches like any other block index; the pages are
dequantized in-VMEM (int8 codes / nibble unpack, f16 per-token scales) and
both attention matmuls run in the same residency:

    grid (S, G, NH + NL), scalar-prefetch (hi_table, lo_table, lengths):
      k < NH  → hi page  hi_table[s, k]   (bs, hd)  int8  → dequant
      k >= NH → lo page  lo_table[s, k−NH] (bs, hd/2) u8  → dequant
      scores (rep, bs) → online-softmax (m, l, acc) accumulated across
      logical blocks in the revisited output ref → out (rep, hd)

Unmapped logical blocks read the null page (the block table holds 0 for
them) and are masked by the slot length; a fully-masked block's
``m = −1e30`` makes its merge correction underflow to exactly zero, so no
validity branch is needed.  The branch that is *inactive* at a grid step
keeps an already-resident page index (its index map clamps into its own
phase rather than switching pages — see ``hi_idx``/``lo_idx``), so each
step fetches only the page its branch consumes and HBM traffic per layer
step is proportional to **allocated pages** (0.52 B/value average at the
64@8b + int4 setting), not to the engine-wide ``max_seq`` reservation the
contiguous layout streams.

**Ragged variant** (`paged_ragged_attention`) — the unified serving step
runs prefill chunks and the decode batch as ONE program, so the grid walks
*query spans* instead of slots: span i < n_pf is a prefill chunk (query
tile ``(C·rep, hd)``, per-row global positions ``start + row``), span
i ≥ n_pf a decode slot (the existing ``(rep, hd)`` tile).  One mask rule
covers both: ``kv_pos <= q_pos AND kv_pos < length`` — for the 1-token
decode span (``q_pos = length−1``) it reduces to the old ``kv_pos <
length``; for a chunk span it is causal masking within the chunk against
the span's own block-table prefix.  The page walk, in-VMEM dequant and
online-softmax merge are shared with the decode kernel.  The inactive
span type's query/output blocks clamp their index maps to a fully
constant block — span axis AND kv-head axis (outputs need both: a
cycling j would flush the stale VMEM buffer over already-written HBM
blocks; see the spec comment in `paged_ragged_attention`) — so the
inactive phase keeps one resident block whose eventual flush is
harmless.  Note the numerics choice: a chunk span
attends to its own tokens through the **just-written quantized pages**
(one layout, no raw re-read), where the XLA fallback attends to the raw
bf16 chunk — kernel-vs-oracle tests pin the kernel against its own
quantized-self reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _dequant_hi_page(qref, sref, zref):
    codes = qref[0, :, 0].astype(jnp.float32)              # (bs, hd)
    s = sref[0, :, 0].astype(jnp.float32)[:, None]
    z = zref[0, :, 0].astype(jnp.float32)[:, None]
    return (codes - z) * s


def _dequant_lo_page(qref, sref, zref, hd: int):
    packed = qref[0, :, 0]                                 # (bs, hd/2)
    hi_nib = (packed >> 4).astype(jnp.float32)
    lo_nib = (packed & 0xF).astype(jnp.float32)
    vals = jnp.stack([hi_nib, lo_nib], axis=-1).reshape(
        packed.shape[0], hd)
    s = sref[0, :, 0].astype(jnp.float32)[:, None]
    z = zref[0, :, 0].astype(jnp.float32)[:, None]
    return (vals - z) * s


def _kernel(ht_ref, lt_ref, len_ref, q_ref,
            khi_ref, vhi_ref, kshi_ref, kzhi_ref, vshi_ref, vzhi_ref,
            klo_ref, vlo_ref, kslo_ref, kzlo_ref, vslo_ref, vzlo_ref,
            o_ref, *, nh: int, block_s: int, num_hi: int, scale: float):
    slot = pl.program_id(0)
    blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (rep, hd)
    hd = q.shape[-1]
    length = len_ref[slot]

    def dequant_hi(qref, sref, zref):
        return _dequant_hi_page(qref, sref, zref)

    def dequant_lo(qref, sref, zref):
        return _dequant_lo_page(qref, sref, zref, hd)

    def block_stats(k_pg, v_pg, pos):
        s_blk = q @ k_pg.T                                 # (rep, bs)
        s_blk = jnp.where((pos < length)[None, :], s_blk, -1e30)
        m_blk = jnp.max(s_blk, axis=-1)
        p_blk = jnp.exp(s_blk - m_blk[:, None])
        l_blk = jnp.sum(p_blk, axis=-1)
        o_blk = p_blk @ v_pg                               # (rep, hd)
        return m_blk, l_blk, o_blk

    def merge(m_blk, l_blk, o_blk):
        prev = o_ref[0, 0].astype(jnp.float32)
        m_prev, l_prev, o_prev = prev[:, 0], prev[:, 1], prev[:, 2:]
        m_new = jnp.maximum(m_prev, m_blk)
        c_prev = jnp.exp(m_prev - m_new)
        c_blk = jnp.exp(m_blk - m_new)
        l_new = l_prev * c_prev + l_blk * c_blk
        o_new = o_prev * c_prev[:, None] + o_blk * c_blk[:, None]
        o_ref[0, 0] = jnp.concatenate(
            [m_new[:, None], l_new[:, None], o_new], axis=-1
        ).astype(o_ref.dtype)

    @pl.when(blk == 0)
    def _init():
        neg = jnp.full((q.shape[0], 1), -1e30, jnp.float32)
        o_ref[0, 0] = jnp.concatenate(
            [neg, jnp.zeros((q.shape[0], hd + 1), jnp.float32)], axis=-1
        ).astype(o_ref.dtype)

    @pl.when(blk < nh)
    def _hi_page():
        pos = blk * block_s + jnp.arange(block_s)
        k_pg = dequant_hi(khi_ref, kshi_ref, kzhi_ref)
        v_pg = dequant_hi(vhi_ref, vshi_ref, vzhi_ref)
        merge(*block_stats(k_pg, v_pg, pos))

    @pl.when(blk >= nh)
    def _lo_page():
        pos = num_hi + (blk - nh) * block_s + jnp.arange(block_s)
        k_pg = dequant_lo(klo_ref, kslo_ref, kzlo_ref)
        v_pg = dequant_lo(vlo_ref, vslo_ref, vzlo_ref)
        merge(*block_stats(k_pg, v_pg, pos))


def paged_decode_attention(entry: dict, q: jax.Array, lengths: jax.Array,
                           hi_table: jax.Array, lo_table: jax.Array,
                           block_size: int,
                           interpret: bool | None = None) -> jax.Array:
    """Fused attention over one layer's paged quantized pools.

    ``entry``: pool dict (no periods axis) — k_hi (NH, bs, g, hd) int8,
    k_lo (NL, bs, g, hd/2) uint8, *_scale/zp (N?, bs, g) f16;
    ``q``: (S, 1, h, hd); ``lengths``: (S,) int32 per-slot;
    ``hi_table``: (S, nh) int32; ``lo_table``: (S, nl) int32 — unmapped
    logical blocks hold 0 (the null page) and mask out via ``lengths``.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    s_slots, _, h, hd = q.shape
    g = entry["k_lo"].shape[2]
    rep = h // g
    bs = block_size
    nh = hi_table.shape[1]
    nl = lo_table.shape[1]
    num_hi = nh * bs
    if nh == 0:
        # no sink region: keep the table indexable (the hi branch of the
        # grid is empty, so only the clamp path ever reads it)
        hi_table = jnp.zeros((s_slots, 1), jnp.int32)
    scale = float(1.0 / np.sqrt(hd))
    qg = q.reshape(s_slots, h, hd).reshape(s_slots, g, rep, hd)

    # The inactive branch's operand is never read, so its index map CLAMPS
    # to the nearest in-phase entry instead of routing to the null page:
    # during lo steps the hi operand repeats the last hi page (index
    # unchanged between grid steps → Mosaic issues no copy), and during hi
    # steps the lo operand pins to the first lo page — the very block the
    # k == nh step needs, so its fetch is an early prefetch, not extra
    # traffic.  Each grid step therefore streams only the page its branch
    # consumes.
    def hi_idx(i, k, ht):
        return ht[i, jnp.clip(k, 0, max(nh - 1, 0))]

    def lo_idx(i, k, lt):
        return lt[i, jnp.clip(k - nh, 0, nl - 1)]

    hi_spec = pl.BlockSpec((1, bs, 1, hd),
                           lambda i, j, k, ht, lt, ln:
                           (hi_idx(i, k, ht), 0, j, 0))
    lo_spec = pl.BlockSpec((1, bs, 1, hd // 2),
                           lambda i, j, k, ht, lt, ln:
                           (lo_idx(i, k, lt), 0, j, 0))
    shi_spec = pl.BlockSpec((1, bs, 1),
                            lambda i, j, k, ht, lt, ln:
                            (hi_idx(i, k, ht), 0, j))
    slo_spec = pl.BlockSpec((1, bs, 1),
                            lambda i, j, k, ht, lt, ln:
                            (lo_idx(i, k, lt), 0, j))

    kernel = functools.partial(_kernel, nh=nh, block_s=bs, num_hi=num_hi,
                               scale=scale)
    stats = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(s_slots, g, nh + nl),
            in_specs=[
                pl.BlockSpec((1, 1, rep, hd),
                             lambda i, j, k, ht, lt, ln: (i, j, 0, 0)),
                hi_spec, hi_spec, shi_spec, shi_spec, shi_spec, shi_spec,
                lo_spec, lo_spec, slo_spec, slo_spec, slo_spec, slo_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, rep, hd + 2),
                                   lambda i, j, k, ht, lt, ln: (i, j, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s_slots, g, rep, hd + 2),
                                       jnp.float32),
        interpret=interpret,
    )(hi_table, lo_table, lengths, qg,
      entry["k_hi"], entry["v_hi"],
      entry["k_hi_scale"], entry["k_hi_zp"],
      entry["v_hi_scale"], entry["v_hi_zp"],
      entry["k_lo"], entry["v_lo"],
      entry["k_lo_scale"], entry["k_lo_zp"],
      entry["v_lo_scale"], entry["v_lo_zp"])

    l = stats[..., 1]
    o = stats[..., 2:]
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(s_slots, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# ragged variant: one grid walks prefill-chunk spans AND decode spans
# ---------------------------------------------------------------------------


def _merge_block(o_ref, blk, q, k_pg, v_pg, mask):
    """Masked block scores + online-softmax merge into the revisited output
    ref.  ``q``: (rows, hd) — rows is ``rep`` for a decode span and
    ``C·rep`` for a prefill span; ``mask``: (rows, bs)."""
    hd = q.shape[-1]
    s_blk = q @ k_pg.T                                     # (rows, bs)
    s_blk = jnp.where(mask, s_blk, -1e30)
    m_blk = jnp.max(s_blk, axis=-1)
    p_blk = jnp.exp(s_blk - m_blk[:, None])
    l_blk = jnp.sum(p_blk, axis=-1)
    o_blk = p_blk @ v_pg                                   # (rows, hd)

    @pl.when(blk == 0)
    def _init():
        neg = jnp.full((q.shape[0], 1), -1e30, jnp.float32)
        o_ref[0, 0] = jnp.concatenate(
            [neg, jnp.zeros((q.shape[0], hd + 1), jnp.float32)], axis=-1
        ).astype(o_ref.dtype)

    prev = o_ref[0, 0].astype(jnp.float32)
    m_prev, l_prev, o_prev = prev[:, 0], prev[:, 1], prev[:, 2:]
    m_new = jnp.maximum(m_prev, m_blk)
    c_prev = jnp.exp(m_prev - m_new)
    c_blk = jnp.exp(m_blk - m_new)
    l_new = l_prev * c_prev + l_blk * c_blk
    o_new = o_prev * c_prev[:, None] + o_blk * c_blk[:, None]
    o_ref[0, 0] = jnp.concatenate(
        [m_new[:, None], l_new[:, None], o_new], axis=-1
    ).astype(o_ref.dtype)


def _ragged_kernel(ht_ref, lt_ref, len_ref, qs_ref, q_pf_ref, q_dec_ref,
                   khi_ref, vhi_ref, kshi_ref, kzhi_ref, vshi_ref, vzhi_ref,
                   klo_ref, vlo_ref, kslo_ref, kzlo_ref, vslo_ref, vzlo_ref,
                   o_pf_ref, o_dec_ref, *, n_pf: int, rep: int, nh: int,
                   block_s: int, num_hi: int, scale: float):
    span = pl.program_id(0)
    blk = pl.program_id(2)
    length = len_ref[span]
    qstart = qs_ref[span]
    hd = q_dec_ref.shape[-1]

    def process(k_pg, v_pg, pos):
        in_len = pos < length                              # (bs,)

        @pl.when(span < n_pf)
        def _prefill_span():
            # chunk span: every query row has its own global position
            # qstart + row; causal within the chunk falls out of the same
            # rule that admits the block-table prefix (kv_pos <= q_pos)
            q = q_pf_ref[0, 0].astype(jnp.float32) * scale  # (C·rep, hd)
            row = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], 1), 0)
            qpos = qstart + row // rep                      # (C·rep, 1)
            mask = (pos[None, :] <= qpos) & in_len[None, :]
            _merge_block(o_pf_ref, blk, q, k_pg, v_pg, mask)

        @pl.when(span >= n_pf)
        def _decode_span():
            # 1-token span: the existing online-softmax decode path
            q = q_dec_ref[0, 0].astype(jnp.float32) * scale  # (rep, hd)
            mask = jnp.broadcast_to(in_len[None, :], (q.shape[0], block_s))
            _merge_block(o_dec_ref, blk, q, k_pg, v_pg, mask)

    @pl.when(blk < nh)
    def _hi_page():
        pos = blk * block_s + jnp.arange(block_s)
        process(_dequant_hi_page(khi_ref, kshi_ref, kzhi_ref),
                _dequant_hi_page(vhi_ref, vshi_ref, vzhi_ref), pos)

    @pl.when(blk >= nh)
    def _lo_page():
        pos = num_hi + (blk - nh) * block_s + jnp.arange(block_s)
        process(_dequant_lo_page(klo_ref, kslo_ref, kzlo_ref, hd),
                _dequant_lo_page(vlo_ref, vslo_ref, vzlo_ref, hd), pos)


def paged_ragged_attention(entry: dict, q_pf: jax.Array, q_dec: jax.Array,
                           q_starts: jax.Array, lengths: jax.Array,
                           hi_table: jax.Array, lo_table: jax.Array,
                           block_size: int,
                           interpret: bool | None = None) -> tuple:
    """Fused attention for one **unified ragged step**: ``n_pf`` prefill
    chunk spans followed by ``S`` decode spans share one grid, one
    scalar-prefetched table walk and one online-softmax structure.

    ``q_pf``: (n_pf, C, h, hd) — chunk queries, row padded to C;
    ``q_dec``: (S, 1, h, hd) — one query per decode slot;
    ``q_starts``: (n_pf+S,) int32 — global position of each span's first
    query row (decode spans: ``length-1``, informational);
    ``lengths``: (n_pf+S,) int32 — tokens materialized for the span's
    request *including this step's writes* (prefill: ``start + valid``);
    ``hi_table``/``lo_table``: (n_pf+S, ·) — span-ordered block tables.

    Grid ``(n_pf+S, G, NH+NL)``: per span the page fetch and dequant are
    the decode kernel's; the span type only changes the query tile and the
    mask, ``kv_pos <= q_pos  AND  kv_pos < length`` — for a decode span
    (``q_pos = length-1``) that reduces to the old ``kv_pos < length``,
    for a prefill span it is causal masking within the chunk against the
    request's own block-table prefix.  Prefill spans attend to their own
    chunk **through the just-written quantized pages** (the XLA fallback
    attends to the raw bf16 chunk instead — the kernel path trades that
    exactness for never re-reading the raw chunk; see the module notes).
    Pad query rows (beyond a chunk's valid length) attend to the full
    prefix and are discarded by the caller.

    Returns ``(out_pf (n_pf, C, h, hd), out_dec (S, 1, h, hd))``.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    n_pf, c_len, h, hd = q_pf.shape
    s_slots = q_dec.shape[0]
    if s_slots < 1:
        raise ValueError("the unified step always carries the decode slots")
    if n_pf == 0:
        out_dec = paged_decode_attention(entry, q_dec, lengths, hi_table,
                                         lo_table, block_size,
                                         interpret=interpret)
        return q_pf, out_dec
    g = entry["k_lo"].shape[2]
    rep = h // g
    bs = block_size
    nh = hi_table.shape[1]
    nl = lo_table.shape[1]
    num_hi = nh * bs
    n_spans = n_pf + s_slots
    if nh == 0:
        hi_table = jnp.zeros((n_spans, 1), jnp.int32)
    scale = float(1.0 / np.sqrt(hd))
    qg_pf = q_pf.reshape(n_pf, c_len, g, rep, hd).transpose(
        0, 2, 1, 3, 4).reshape(n_pf, g, c_len * rep, hd)
    qg_dec = q_dec.reshape(s_slots, h, hd).reshape(s_slots, g, rep, hd)

    def hi_idx(i, k, ht):
        return ht[i, jnp.clip(k, 0, max(nh - 1, 0))]

    def lo_idx(i, k, lt):
        return lt[i, jnp.clip(k - nh, 0, nl - 1)]

    hi_spec = pl.BlockSpec((1, bs, 1, hd),
                           lambda i, j, k, ht, lt, ln, qs:
                           (hi_idx(i, k, ht), 0, j, 0))
    lo_spec = pl.BlockSpec((1, bs, 1, hd // 2),
                           lambda i, j, k, ht, lt, ln, qs:
                           (lo_idx(i, k, lt), 0, j, 0))
    shi_spec = pl.BlockSpec((1, bs, 1),
                            lambda i, j, k, ht, lt, ln, qs:
                            (hi_idx(i, k, ht), 0, j))
    slo_spec = pl.BlockSpec((1, bs, 1),
                            lambda i, j, k, ht, lt, ln, qs:
                            (lo_idx(i, k, lt), 0, j))
    # The span type selects which query tile / output the kernel touches;
    # the inactive operand's index map CLAMPS to a fully CONSTANT block —
    # on BOTH axes.  Clamping only the span axis (the hi/lo page-spec
    # precedent) is not enough for outputs: the kv-head axis j still
    # cycles during the other span type's steps, and every index change
    # flushes the (unwritten, stale) VMEM buffer over an already-written
    # HBM block.  Pinning j as well means the inactive phase holds exactly
    # one resident block — the last one its own phase wrote (o_pf) or the
    # first one it is about to write (o_dec) — so the extra flush rewrites
    # correct data (o_pf) or bytes the active phase overwrites before any
    # read (o_dec).  Queries get the same pin purely to avoid redundant
    # fetches.
    def pf_idx(i, j):
        return jnp.minimum(i, n_pf - 1), jnp.where(i < n_pf, j, g - 1)

    def dec_idx(i, j):
        return (jnp.clip(i - n_pf, 0, s_slots - 1),
                jnp.where(i >= n_pf, j, 0))

    qpf_spec = pl.BlockSpec((1, 1, c_len * rep, hd),
                            lambda i, j, k, ht, lt, ln, qs:
                            (*pf_idx(i, j), 0, 0))
    qdec_spec = pl.BlockSpec((1, 1, rep, hd),
                             lambda i, j, k, ht, lt, ln, qs:
                             (*dec_idx(i, j), 0, 0))
    opf_spec = pl.BlockSpec((1, 1, c_len * rep, hd + 2),
                            lambda i, j, k, ht, lt, ln, qs:
                            (*pf_idx(i, j), 0, 0))
    odec_spec = pl.BlockSpec((1, 1, rep, hd + 2),
                             lambda i, j, k, ht, lt, ln, qs:
                             (*dec_idx(i, j), 0, 0))

    kernel = functools.partial(_ragged_kernel, n_pf=n_pf, rep=rep, nh=nh,
                               block_s=bs, num_hi=num_hi, scale=scale)
    stats_pf, stats_dec = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n_spans, g, nh + nl),
            in_specs=[
                qpf_spec, qdec_spec,
                hi_spec, hi_spec, shi_spec, shi_spec, shi_spec, shi_spec,
                lo_spec, lo_spec, slo_spec, slo_spec, slo_spec, slo_spec,
            ],
            out_specs=(opf_spec, odec_spec),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_pf, g, c_len * rep, hd + 2),
                                 jnp.float32),
            jax.ShapeDtypeStruct((s_slots, g, rep, hd + 2), jnp.float32),
        ),
        interpret=interpret,
    )(hi_table, lo_table, lengths, q_starts, qg_pf, qg_dec,
      entry["k_hi"], entry["v_hi"],
      entry["k_hi_scale"], entry["k_hi_zp"],
      entry["v_hi_scale"], entry["v_hi_zp"],
      entry["k_lo"], entry["v_lo"],
      entry["k_lo_scale"], entry["k_lo_zp"],
      entry["v_lo_scale"], entry["v_lo_zp"])

    def finalize(stats):
        l = stats[..., 1]
        o = stats[..., 2:]
        return o / jnp.maximum(l, 1e-30)[..., None]

    out_pf = finalize(stats_pf).reshape(n_pf, g, c_len, rep, hd).transpose(
        0, 2, 1, 3, 4).reshape(n_pf, c_len, h, hd).astype(q_pf.dtype)
    out_dec = finalize(stats_dec).reshape(
        s_slots, 1, h, hd).astype(q_dec.dtype)
    return out_pf, out_dec

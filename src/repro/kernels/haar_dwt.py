"""Pallas TPU kernel: multi-level Haar DWT along the sequence axis.

Hardware adaptation (vs. the paper's CUDA kernel, §B.3): the CUDA version
launches one kernel per DWT level, round-tripping HBM each time.  On TPU we
keep a (seq × 128-lane) activation tile resident in VMEM and run **all**
levels in one kernel — the op becomes exactly one HBM read + one HBM write
of the activation regardless of ``levels``.

Grid: (batch, d_model / block_d).  Each program handles the full sequence
for a 128-aligned feature block; the butterfly is unrolled over levels
(static, ≤ ~5), with even/odd pairing expressed as a (s/2, 2, block_d)
reshape which Mosaic lowers to sublane shuffles.

VMEM budget: s × block_d × 4 B (f32 compute copy); at s = 32k and
block_d = 128 that is 16 MiB — tight but within v5e's 128 MiB VMEM when
block_d is dropped to 32; ``ops.haar_dwt_seq`` picks block_d accordingly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_INV_SQRT2 = float(1.0 / np.sqrt(2.0))


def _dwt_kernel(x_ref, o_ref, *, levels: int, inverse: bool):
    x = x_ref[0].astype(jnp.float32)          # (s, bd)
    s = x.shape[0]
    if not inverse:
        lo = s
        for _ in range(levels):
            if lo < 2:
                break
            band = x[:lo]
            pairs = band.reshape(lo // 2, 2, band.shape[-1])
            approx = (pairs[:, 0] + pairs[:, 1]) * _INV_SQRT2
            detail = (pairs[:, 0] - pairs[:, 1]) * _INV_SQRT2
            x = jnp.concatenate([approx, detail, x[lo:]], axis=0)
            lo //= 2
    else:
        sizes = []
        lo = s
        for _ in range(levels):
            if lo < 2:
                break
            sizes.append(lo)
            lo //= 2
        for lo_sz in reversed(sizes):
            half = lo_sz // 2
            approx, detail = x[:half], x[half:lo_sz]
            even = (approx + detail) * _INV_SQRT2
            odd = (approx - detail) * _INV_SQRT2
            band = jnp.stack([even, odd], axis=1).reshape(lo_sz, x.shape[-1])
            x = jnp.concatenate([band, x[lo_sz:]], axis=0)
    o_ref[0] = x.astype(o_ref.dtype)


def haar_dwt_pallas(x: jax.Array, levels: int = 3, inverse: bool = False,
                    block_d: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """x: (batch, s, d) with s a multiple of 2**levels, d of block_d."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, s, d = x.shape
    if d % block_d:
        raise ValueError(f"d={d} not divisible by block_d={block_d}")
    if s % (1 << levels):
        raise ValueError(f"seq {s} not a multiple of 2**levels={1 << levels}")
    kernel = functools.partial(_dwt_kernel, levels=levels, inverse=inverse)
    return pl.pallas_call(
        kernel,
        grid=(b, d // block_d),
        in_specs=[pl.BlockSpec((1, s, block_d), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, s, block_d), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)

"""Pallas TPU kernels for STaMP's compute hot spots.

`<name>.py` holds the ``pl.pallas_call`` + BlockSpec tiling, `ops.py` the
jit'd wrappers (interpret-mode on CPU), `ref.py` the pure-jnp oracles.
"""

from repro.kernels.ops import (  # noqa: F401
    haar_dwt_seq,
    int8_matmul,
    quantize_pack,
    walsh_hadamard,
)
from repro.kernels.cache_attention import cache_decode_attention  # noqa: F401

"""Pallas TPU kernels for STaMP's compute hot spots.

`<name>.py` holds the ``pl.pallas_call`` + BlockSpec tiling, `ops.py` the
jit'd wrappers (interpret-mode on CPU), `ref.py` the pure-jnp oracles.

Reference vs. fused execution
-----------------------------
STaMP linears run in one of two modes, selected by
``repro.core.stamp.StampConfig.execution``:

* ``"reference"`` (default) — the pure-jnp path: ``L·X``, the fake-quantized
  activation, the bf16 matmul output and ``L⁻¹(·)`` each materialize as a
  separate XLA tensor (four HBM round trips of the activation per linear).
  This is the numerics oracle and the only path for dense-basis transforms
  (dct/klt/dwt2d), per-block granularity and activation feature rotations.
* ``"fused"`` — `stamp_matmul.stamp_quant_matmul` runs transform →
  mixed-precision quantize (first ``num_hi`` tokens at ``hi_bits``, rest at
  ``lo_bits``) → int8×int8 GEMM with per-row/per-column scale correction →
  inverse transform → bias in a single VMEM residency: one HBM read of X and
  one write of Y.  Weights are pre-quantized once into signed-int8 buffers
  (`repro.core.stamp.prepare_linear` /
  `repro.models.lm.prepare_fused_weights`) instead of being dequantized to
  bf16 on every call.  Supports dwt/wht/none transforms, per-token
  granularity; ineligible configs silently fall back to the reference path
  with identical semantics.
"""

from repro.kernels.ops import (  # noqa: F401
    haar_dwt_seq,
    int8_matmul,
    quantize_pack,
    stamp_quant_matmul,
    walsh_hadamard,
)
from repro.kernels.cache_attention import cache_decode_attention  # noqa: F401

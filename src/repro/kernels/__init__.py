"""Pallas TPU kernels for STaMP's compute hot spots.

`<name>.py` holds the ``pl.pallas_call`` + BlockSpec tiling, `ops.py` the
jit'd wrappers (interpret-mode on CPU), `ref.py` the pure-jnp oracles.

Reference vs. fused execution
-----------------------------
STaMP linears run in one of two modes, selected by
``repro.core.stamp.StampConfig.execution``:

* ``"reference"`` (default) — the pure-jnp path: ``L·X``, the fake-quantized
  activation, the bf16 matmul output and ``L⁻¹(·)`` each materialize as a
  separate XLA tensor (four HBM round trips of the activation per linear).
  This is the numerics oracle and the only path for dense-basis transforms
  (dct/klt/dwt2d), per-block granularity and activation feature rotations.
* ``"fused"`` — `stamp_matmul.stamp_quant_matmul` runs transform →
  mixed-precision quantize (first ``num_hi`` tokens at ``hi_bits``, rest at
  ``lo_bits``) → int8×int8 GEMM with per-row/per-column scale correction →
  inverse transform → bias in a single VMEM residency: one HBM read of X and
  one write of Y.  Weights are pre-quantized once into signed-int8 buffers
  (`repro.core.stamp.prepare_linear` /
  `repro.models.lm.prepare_fused_weights`) instead of being dequantized to
  bf16 on every call.  Supports dwt/wht/none transforms, per-token
  granularity; ineligible configs silently fall back to the reference path
  with identical semantics.

Every prefill-path model linear is wired through the fused family
(`repro.models.lm.FUSED_SITES`); two sites get dedicated treatment:

* **out-proj** — `stamp_quant_matmul` also accepts the raw head-split
  ``(b, s, nh, hd)`` attention output.  The BlockSpec maps the full
  head-split tile per batch row and the kernel merges ``(nh, hd)`` on the
  in-VMEM tile right before the transform, so the head-merge reshape is
  fused with the stamped quantize instead of materializing a merged
  activation in HBM between attention and the projection.
* **gate/up pair** — `stamp_matmul.stamp_quant_dual_matmul` executes the
  SwiGLU front half as ONE kernel.  Execution model: grid ``(batch,
  N/block_n)`` exactly like the single kernel; on the first output-block
  step the shared MLP input's transform + mixed-precision quantize run
  once into VMEM scratch (int8 codes + per-token scale/zp), and **both**
  the gate and up GEMMs of every output block consume those same codes —
  the transform+quantize cost is paid once, not twice.  Each GEMM's result
  is inverse-transformed separately (``L⁻¹`` commutes with the weight
  multiplication but not with the gating nonlinearity), biases apply in
  the token domain, and the optional ``silu·mul`` epilogue combines the
  pair in-VMEM so only the product is written: one HBM read of the shared
  input, two int8 weight streams, one output write.  With
  ``epilogue="none"`` both projections are written (two outputs), still
  off the single shared quantize.

Grouped MoE execution
---------------------
The MoE expert einsums don't fit the per-sequence tiling above: after
capacity routing the activation is ``(b, E, C, d)`` — expert buckets, not
sequence spans — and the sequence transform ``L`` does not commute with
the dispatch gather, so a per-bucket transform would change numerics.
`stamp_matmul.stamp_quant_grouped_matmul_pallas` (wrapper
`ops.stamp_quant_grouped_matmul`) instead splits the work at the token
boundary — the **dispatch-once-quantize-once invariant**:

* the stamped round trip (transform → mixed-precision fake-quant →
  inverse) runs ONCE per token in XLA, shared verbatim with the router
  input, so fused and reference paths route bit-identically by
  construction;
* `repro.core.stamp.token_quantize` then produces one int8 code + scale
  + zero point per token, and the *codes* are gathered into the capacity
  buckets — the dispatch buffer moves int8, not bf16;
* ONE kernel walks grid ``(b, E, C/block_c, f/block_f)`` with the
  per-``(b, E)`` occupancy counts as a scalar-prefetch table: index maps
  clamp the empty capacity tail of underfull buckets (routing keeps each
  bucket a contiguous prefix, so the count is exact), rows past the
  count are zeroed in-kernel, and gate + up GEMMs consume the same
  gathered codes with the silu·mul epilogue and the grouped down-proj in
  VMEM scratch — the ``(E, C, f)`` intermediates never reach HBM.

Expert weights prepare like every other site
(`prepare_fused_weights` stacks the scanned period as
``(nper, E, din, dout)`` int8) and shard expert-parallel over the
``'model'`` mesh axis through the existing suffix-strip rules
(`repro/sharding.py`).

The unified ragged serving step
-------------------------------
The paged engine dispatches ONE device program per step
(`repro.models.lm.paged_unified_step`): up to ``max_prefills`` prefill
chunk spans plus the decode slot array form a flattened token batch with
per-span ``(query_start, query_len)`` metadata from the scheduler.  Three
rules keep the kernels correct inside that program:

* **STaMP segment rule** — the sequence transform applies per sequence
  span, never across the flattened batch.  Spans are uniform (chunks pad
  to ``C`` tokens), so the unified step builds the prefill region
  **span-major** — ``(n_pf, C, d)``, one batch row per span — and the
  fused kernels see each span as its own grid row (whose
  transform+quantize scratch is already private).  Callers that do hold
  a flattened ``(b, n·C, d)`` carrier get the same rule through
  `repro.core.stamp.fold_segments` / the ``seg_len`` parameter on the
  stamp linears, and at the kernel level through
  `stamp_matmul.stamp_quant_segment_matmul_pallas`.  Decode spans are
  single tokens — their transform is the identity, which is why the
  decode region applies none.
* **Ragged attention grid** — `paged_attention.paged_ragged_attention`
  walks query spans: decode spans take the existing online-softmax path,
  prefill spans add causal masking within the chunk against their own
  block-table prefix (one mask rule, ``kv_pos <= q_pos AND kv_pos <
  length``).  See the paged layout section below.
* **Decode-matmul dispatch by shape** — both regions share one trace, so
  the single-token integer matmul (below) keys on the token dim being 1:
  decode sub-tensors ``(S, 1, d)`` take it, chunk rows ``(n_pf, C>1, d)``
  cannot, and the all-decode step (n_pf = 0) IS the old decode graph.

Decode-shaped execution
-----------------------
Decode has no sequence axis, so its two kernels drop the transform and keep
only the mixed-precision memory layout:

* `decode_matmul.stamp_decode_matmul` — one token per slot against the same
  cached int8 weight buffers the prefill kernel uses (8-bit per-token
  activation quantize + integer GEMM; no per-step bf16 weight
  re-materialization).  Enabled via ``ServeConfig.fused_decode_matmul``.
* `cache_attention.cache_decode_attention` — fused attention over the
  *contiguous* packed mixed-precision KV cache (per-slot dense layout).

Paged-attention block layout
----------------------------
`paged_attention.paged_decode_attention` serves the continuous-batching
engine (`serving/scheduler.py` + `serving/paged_kvcache.py`).  The cache is
two shared page pools instead of per-slot dense buffers:

* **hi pool** ``(NH, bs, kv, hd)`` int8 — pages holding the first
  ``num_hi`` logical tokens of each sequence (the attention-sink region)
  at 8 bits; ``num_hi % bs == 0`` so pages are single-precision.
* **lo pool** ``(NL, bs, kv, hd/2)`` uint8 — int4 nibble pairs packed along
  head_dim: one page holds ``bs`` tokens in half the bytes, and per-token
  f16 scale/zp pages ride alongside so a page is self-describing (swap /
  preemption moves one contiguous unit).

Each slot maps logical block ``k`` to a physical page through a
scalar-prefetched block table; the BlockSpec index map does the lookup, so
Mosaic pipelines page fetches exactly like dense block fetches.  Grid is
``(slots, kv_heads, NH_seq + NL_seq)`` with the online-softmax (m, l, acc)
accumulated across the logical-block axis in the revisited output ref.
Unmapped blocks clamp to page 0 (the null page) and mask out via the
per-slot length; HBM traffic per step is proportional to *allocated* pages,
not the engine-wide ``max_seq`` reservation.

Hybrid dense + paged layout
---------------------------
Hybrid stacks (Jamba-style Mamba + attention) split their serving state
across two layouts inside one engine step:

* **attention layers** — the paged pools above, written through the
  combined ragged scatter and read by `paged_ragged_attention` /
  `paged_decode_attention` exactly as in the attention-only case;
* **Mamba layers** — a **slot-dense** state pool
  (`serving/paged_kvcache.init_ssm_slots`): per slot, one f32
  ``(heads, head_dim, ssm_state)`` state matrix and a bf16 conv tail.
  Recurrent state is fixed-size per request, so paging buys nothing —
  there is nothing proportional to sequence length to reclaim — and the
  pool indexes by *slot*, with row ``num_slots`` as the null slot (the
  scatter target for unused prefill chunk rows, mirroring the null page).
  The SSM mixer itself stays XLA (`models/layers.ssd_chunked` carries
  ``init_state`` across chunk spans; decode is a batched one-token
  recurrence with inactive slots masked) — it reads no pages, so it needs
  no Pallas treatment; the Mamba in/out projections still route through
  the fused STaMP kernels above.

Kernel contract registry
------------------------
`specs.py` keeps a capture registry (``KERNEL_EXAMPLES`` /
``kernel_spec(name)``): one representative example call per kernel
family, captured by intercepting ``pallas_call`` so the grid, BlockSpecs,
scratch shapes and concrete scalar-prefetch tables are recorded without
executing the kernel.  The static contract checker
(``python -m repro.analysis.contracts``) evaluates every index map over
the full grid against the operand shapes, sums the VMEM footprint, and
re-traces the example for accumulator-dtype rules.  **The registry is
part of a kernel's interface**: a new kernel (or a new BlockSpec/grid
variant of an existing one — new index-map idiom, new prefetch table
layout) must add a registry example exercising it, and changing a
kernel's tiling means its example must still pass the checker at default
block sizes.

Telemetry hooks
---------------
Every STaMP linear — reference and fused — carries a ``site`` label
(``qkv``, ``wo``, ``gate_up``, ``wo_mlp``, ``moe``, ``in_proj``,
``out_proj``), and when `repro.models.lm.ServeConfig.quant_telemetry`
is on, records its transformed activation into
`repro.obs.quantstats` at trace time.  The stats are per-site scalar
reductions (clip/saturation counts, hi-token coverage, scale bounds)
computed in the SAME device program as the step — the fused kernels
themselves are untouched; the reductions read the kernel's *input*
activation, so telemetry never perturbs the integer path and adds zero
device dispatches.  The serving engines fold the scalars into their
metrics registry (``quant_*{site=…}``) and raise ``quant_clip_alert``
events past the configured threshold — see ``repro/obs/quantstats.py``
for the collection protocol (how records escape ``lax.scan``).
"""

from repro.kernels.ops import (  # noqa: F401
    haar_dwt_seq,
    int8_matmul,
    quantize_pack,
    stamp_decode_matmul,
    stamp_quant_dual_matmul,
    stamp_quant_grouped_matmul,
    stamp_quant_matmul,
    walsh_hadamard,
)
from repro.kernels.cache_attention import cache_decode_attention  # noqa: F401
from repro.kernels.paged_attention import (  # noqa: F401
    paged_decode_attention,
    paged_ragged_attention,
)

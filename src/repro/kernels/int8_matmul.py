"""Pallas TPU kernel: int8 × int8 GEMM with per-row / per-column scales.

The W4A4/W4A8 deployment matmul: activations quantized per token (row
scale/offset), weights per output channel (column scale/offset), integer
accumulation in int32 on the MXU (``preferred_element_type``), dequantized
once at the epilogue:

    Y[m,n] = (Σ_k (qx[m,k] − zx[m]) (qw[k,n] − zw[n])) · sx[m] · sw[n]
           = (Σ qx·qw − zx[m]·Σ qw − zw[n]·Σ qx + K·zx·zw) · sx·sw

The correction terms use the per-block column/row sums, also computed on
the fly, so the kernel reads each operand exactly once.  Blocks are
128-aligned for the MXU; the K loop accumulates into a VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _matmul_kernel(qx_ref, qw_ref, sx_ref, zx_ref, sw_ref, zw_ref, o_ref,
                   acc_ref, qw_sum_ref, qx_sum_ref, *, n_k: int, k_total: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        qw_sum_ref[...] = jnp.zeros_like(qw_sum_ref)
        qx_sum_ref[...] = jnp.zeros_like(qx_sum_ref)

    qx = qx_ref[...]                                  # (bm, bk) int8
    qw = qw_ref[...]                                  # (bk, bn) int8
    acc_ref[...] += jnp.dot(qx, qw, preferred_element_type=jnp.int32)
    qw_sum_ref[...] += jnp.sum(qw.astype(jnp.int32), axis=0, keepdims=True)
    qx_sum_ref[...] += jnp.sum(qx.astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(k_idx == n_k - 1)
    def _emit():
        sx = sx_ref[...].astype(jnp.float32)          # (bm, 1)
        zx = zx_ref[...].astype(jnp.float32)
        sw = sw_ref[...].astype(jnp.float32)          # (1, bn)
        zw = zw_ref[...].astype(jnp.float32)
        acc = acc_ref[...].astype(jnp.float32)
        corr = (acc
                - zx * qw_sum_ref[...].astype(jnp.float32)
                - zw * qx_sum_ref[...].astype(jnp.float32)
                + float(k_total) * zx * zw)
        o_ref[...] = (corr * sx * sw).astype(o_ref.dtype)


def int8_matmul_pallas(
    qx: jax.Array, qw: jax.Array,
    sx: jax.Array, zx: jax.Array,
    sw: jax.Array, zw: jax.Array,
    *, block_m: int = 128, block_n: int = 128, block_k: int = 128,
    out_dtype=jnp.bfloat16, interpret: bool | None = None,
) -> jax.Array:
    """qx: (M, K) int8; qw: (K, N) int8; sx/zx: (M, 1); sw/zw: (1, N)."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    m, k = qx.shape
    k2, n = qw.shape
    if k != k2:
        raise ValueError(f"activation K={k} does not match weight K={k2}")
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m}, {n}, {k}) not divisible by blocks "
                         f"({bm}, {bn}, {bk})")
    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k, k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((1, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        interpret=interpret,
    )(qx, qw, sx, zx, sw, zw)

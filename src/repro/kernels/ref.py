"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
for the interpret-mode shape/dtype sweeps in tests/test_kernels.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms as T


def haar_dwt_ref(x: jax.Array, levels: int = 3,
                 inverse: bool = False) -> jax.Array:
    fn = T.haar_idwt if inverse else T.haar_dwt
    return fn(x, levels=levels, axis=-2)


def wht_ref(x: jax.Array, axis: int = -2) -> jax.Array:
    return T.wht(x, axis=axis)


def quant_pack_ref(x: jax.Array, bits: int = 4):
    xf = x.astype(jnp.float32)
    n = float(2**bits - 1)
    mn = jnp.min(xf, axis=-1, keepdims=True)
    mx = jnp.max(xf, axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / n, 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(xf / scale) + zp, 0.0, n)
    if bits == 4:
        qi = q.astype(jnp.uint8)
        packed = (qi[..., 0::2] << 4) | qi[..., 1::2]
    else:
        packed = (q - 128.0).astype(jnp.int8)
        zp = zp - 128.0
    return packed, scale, zp


def unpack_dequant_ref(packed: jax.Array, scale: jax.Array, zp: jax.Array,
                       bits: int = 4, dtype=jnp.float32) -> jax.Array:
    if bits == 4:
        hi = (packed >> 4).astype(jnp.float32)
        lo = (packed & 0xF).astype(jnp.float32)
        q = jnp.stack([hi, lo], axis=-1).reshape(
            *packed.shape[:-1], packed.shape[-1] * 2)
    else:
        q = packed.astype(jnp.float32)
    return ((q - zp) * scale).astype(dtype)


def int8_matmul_ref(qx, qw, sx, zx, sw, zw, out_dtype=jnp.float32):
    x = (qx.astype(jnp.float32) - zx) * sx
    w = (qw.astype(jnp.float32) - zw) * sw
    return (x @ w).astype(out_dtype)


def stamp_decode_matmul_ref(x, qw, sw, zw, bias=None,
                            out_dtype=jnp.float32):
    """Unfused oracle for `stamp_decode_matmul`: per-row 8-bit fake quant of
    the token batch, then a dequantized-weight matmul."""
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=-1, keepdims=True)
    mx = jnp.max(xf, axis=-1, keepdims=True)
    sc = jnp.maximum((mx - mn) / 255.0, 1e-8)
    zp = jnp.round(-mn / sc)
    q = jnp.clip(jnp.round(xf / sc) + zp, 0.0, 255.0)
    xq = (q - zp) * sc
    wd = (qw.astype(jnp.float32) - zw) * sw
    y = xq @ wd
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    return y.astype(out_dtype)


def paged_attention_ref(entry, q, lengths, hi_table, lo_table, block_size,
                        num_hi):
    """Gather-based oracle for `paged_decode_attention`: densify the mapped
    pages per slot and run the segment-merged decode attention."""
    from repro.models.layers import decode_attention_segments
    from repro.serving import kvcache as KV

    def dense(codes, table):
        g = codes[table]
        return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])

    segs = []
    for region, table, offset in (("hi", hi_table, 0),
                                  ("lo", lo_table, num_hi)):
        pair = []
        for name in ("k", "v"):
            codes = dense(entry[f"{name}_{region}"], table)
            sc = dense(entry[f"{name}_{region}_scale"], table)
            zp = dense(entry[f"{name}_{region}_zp"], table)
            vals = codes.astype(jnp.float32) if region == "hi" \
                else KV.unpack_nibbles(codes)
            pair.append(KV.dequant_tokens(vals, sc, zp, jnp.float32))
        segs.append((pair[0], pair[1], offset))
    return decode_attention_segments(q.astype(jnp.float32), segs,
                                     length=lengths)


def paged_ragged_attention_ref(entry, q_pf, q_dec, q_starts, lengths,
                               hi_table, lo_table):
    """Dense oracle for `paged_ragged_attention`: densify each span's mapped
    pages and compute a direct (non-online) masked softmax per query row
    with the unified rule ``kv_pos <= q_pos AND kv_pos < length``."""
    from repro.serving import kvcache as KV

    n_pf, c_len, h, hd = q_pf.shape
    s_slots = q_dec.shape[0]
    g = entry["k_lo"].shape[2]
    rep = h // g

    def dense(codes, table):
        gathered = codes[table]
        return gathered.reshape(gathered.shape[0],
                                gathered.shape[1] * gathered.shape[2],
                                *gathered.shape[3:])

    def span_kv(table_row_hi, table_row_lo):
        pair = []
        for name in ("k", "v"):
            parts = []
            for region, row in (("hi", table_row_hi), ("lo", table_row_lo)):
                if row.shape[0] == 0:
                    continue
                codes = dense(entry[f"{name}_{region}"], row[None])
                sc = dense(entry[f"{name}_{region}_scale"], row[None])
                zp = dense(entry[f"{name}_{region}_zp"], row[None])
                vals = codes.astype(jnp.float32) if region == "hi" \
                    else KV.unpack_nibbles(codes)
                parts.append(KV.dequant_tokens(vals, sc, zp, jnp.float32)[0])
            pair.append(jnp.concatenate(parts, axis=0))    # (n_tok, g, hd)
        return pair

    def attend(q_rows, qpos, kd, vd, length):              # q_rows (r, g, hd)
        kv_pos = jnp.arange(kd.shape[0])
        scale = 1.0 / np.sqrt(hd)
        qg = q_rows.reshape(-1, g, rep, hd).astype(jnp.float32) * scale
        sc = jnp.einsum("rgpd,sgd->rgps", qg, kd.astype(jnp.float32))
        mask = (kv_pos[None, :] <= qpos[:, None]) & \
            (kv_pos[None, :] < length)
        sc = jnp.where(mask[:, None, None], sc, -1e30)
        m = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - m)
        o = jnp.einsum("rgps,sgd->rgpd", p, vd.astype(jnp.float32))
        l = jnp.sum(p, axis=-1, keepdims=True)
        return (o / jnp.maximum(l, 1e-30)).reshape(-1, h, hd)

    outs_pf = []
    for i in range(n_pf):
        kd, vd = span_kv(hi_table[i], lo_table[i])
        qpos = q_starts[i] + jnp.arange(c_len)
        outs_pf.append(attend(q_pf[i], qpos, kd, vd, lengths[i]))
    outs_dec = []
    for j in range(s_slots):
        i = n_pf + j
        kd, vd = span_kv(hi_table[i], lo_table[i])
        qpos = jnp.asarray([lengths[i] - 1])
        outs_dec.append(attend(q_dec[j], qpos, kd, vd, lengths[i]))
    out_pf = jnp.stack(outs_pf) if outs_pf else \
        jnp.zeros((0, c_len, h, hd), jnp.float32)
    return out_pf, jnp.stack(outs_dec)                     # (S, 1, h, hd)


def stamp_quant_matmul_ref(x, qw, sw, zw, bias=None, *, transform="dwt",
                           levels=3, skip_first=True, num_hi=64, hi_bits=8,
                           lo_bits=4, out_dtype=jnp.float32):
    """Unfused oracle for `stamp_quant_matmul`: transform → mixed-precision
    fake quant → dequantized matmul → inverse transform → bias, each step a
    separate jnp materialization (exactly the reference execution path).
    A head-split (b, s, nh, hd) input is merged up front (the kernel fuses
    that reshape with the quantize)."""
    from repro.core import quant as Q

    if x.ndim == 4:
        x = x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])
    xf = x.astype(jnp.float32)
    tx = T.sequence_transform(xf, transform, axis=-2, levels=levels,
                              skip_first=skip_first)
    bits = Q.mixed_precision_bits(tx.shape[-2], num_hi, hi_bits, lo_bits)
    tq = Q.fake_quant(tx, bits, axis=-1)
    wd = (qw.astype(jnp.float32) - zw) * sw
    y = tq @ wd
    y = T.inverse_sequence_transform(y, transform, axis=-2, levels=levels,
                                     skip_first=skip_first)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    return y.astype(out_dtype)


def stamp_quant_dual_matmul_ref(x, qw_g, sw_g, zw_g, qw_u, sw_u, zw_u,
                                bias_g=None, bias_u=None, *, transform="dwt",
                                levels=3, skip_first=True, num_hi=64,
                                hi_bits=8, lo_bits=4, epilogue="silu_mul",
                                out_dtype=jnp.float32):
    """Unfused oracle for `stamp_quant_dual_matmul`: ONE shared transform +
    fake quant, two dequantized matmuls, per-output inverse transforms, then
    the optional silu·mul combine in the original (token) domain."""
    from repro.core import quant as Q

    if x.ndim == 4:
        x = x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])
    xf = x.astype(jnp.float32)
    tx = T.sequence_transform(xf, transform, axis=-2, levels=levels,
                              skip_first=skip_first)
    bits = Q.mixed_precision_bits(tx.shape[-2], num_hi, hi_bits, lo_bits)
    tq = Q.fake_quant(tx, bits, axis=-1)

    def one(qw, sw, zw, bias):
        y = tq @ ((qw.astype(jnp.float32) - zw) * sw)
        y = T.inverse_sequence_transform(y, transform, axis=-2,
                                         levels=levels,
                                         skip_first=skip_first)
        if bias is not None:
            y = y + bias.reshape(1, -1).astype(jnp.float32)
        return y

    g = one(qw_g, sw_g, zw_g, bias_g)
    u = one(qw_u, sw_u, zw_u, bias_u)
    if epilogue == "silu_mul":
        return (jax.nn.silu(g) * u).astype(out_dtype)
    return g.astype(out_dtype), u.astype(out_dtype)


def stamp_quant_grouped_matmul_ref(qx, sx, zx, counts,
                                   qw_gate, sw_gate, zw_gate,
                                   qw_up, sw_up, zw_up,
                                   qw_down, sw_down, zw_down, *,
                                   block_f=512, out_dtype=jnp.float32):
    """Unfused oracle for `stamp_quant_grouped_matmul`: dequantize the
    gathered dispatch buffer and the stacked expert weights, run the
    gate/up einsums + silu·mul, then the down-proj per ``block_f`` slab
    with the same per-row 8-bit requantize the kernel applies in VMEM
    (group-wise scales — one row scale per f tile).  Slots at or past each
    expert bucket's kept-token count are zeroed, mirroring the reference
    dispatch einsum's exact zeros."""
    b, e, cap, d = qx.shape
    f = qw_gate.shape[-1]
    x = (qx.astype(jnp.float32) - zx) * sx                   # (b, E, C, d)
    wg = (qw_gate.astype(jnp.float32) - zw_gate) * sw_gate   # (E, d, f)
    wu = (qw_up.astype(jnp.float32) - zw_up) * sw_up
    wd = (qw_down.astype(jnp.float32) - zw_down) * sw_down   # (E, f, d)
    g = jnp.einsum("becd,edf->becf", x, wg)
    u = jnp.einsum("becd,edf->becf", x, wu)
    a = jax.nn.silu(g) * u
    bf = min(block_f, f)
    while f % bf:
        bf //= 2
    out = jnp.zeros((b, e, cap, d), jnp.float32)
    for j in range(f // bf):
        blk = a[..., j * bf:(j + 1) * bf]
        mn = jnp.min(blk, axis=-1, keepdims=True)
        mx = jnp.max(blk, axis=-1, keepdims=True)
        sa = jnp.maximum((mx - mn) / 255.0, 1e-8)
        za = jnp.round(-mn / sa)
        qa = jnp.clip(jnp.round(blk / sa) + za, 0.0, 255.0) - za
        out = out + jnp.einsum("becf,efd->becd", qa * sa,
                               wd[:, j * bf:(j + 1) * bf])
    slot = jnp.arange(cap)[None, None, :, None]
    out = jnp.where(slot < counts[:, :, None, None], out, 0.0)
    return out.astype(out_dtype)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
for the interpret-mode shape/dtype sweeps in tests/test_kernels.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms as T


def haar_dwt_ref(x: jax.Array, levels: int = 3,
                 inverse: bool = False) -> jax.Array:
    fn = T.haar_idwt if inverse else T.haar_dwt
    return fn(x, levels=levels, axis=-2)


def wht_ref(x: jax.Array, axis: int = -2) -> jax.Array:
    return T.wht(x, axis=axis)


def quant_pack_ref(x: jax.Array, bits: int = 4):
    xf = x.astype(jnp.float32)
    n = float(2**bits - 1)
    mn = jnp.min(xf, axis=-1, keepdims=True)
    mx = jnp.max(xf, axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / n, 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(xf / scale) + zp, 0.0, n)
    if bits == 4:
        qi = q.astype(jnp.uint8)
        packed = (qi[..., 0::2] << 4) | qi[..., 1::2]
    else:
        packed = (q - 128.0).astype(jnp.int8)
        zp = zp - 128.0
    return packed, scale, zp


def unpack_dequant_ref(packed: jax.Array, scale: jax.Array, zp: jax.Array,
                       bits: int = 4, dtype=jnp.float32) -> jax.Array:
    if bits == 4:
        hi = (packed >> 4).astype(jnp.float32)
        lo = (packed & 0xF).astype(jnp.float32)
        q = jnp.stack([hi, lo], axis=-1).reshape(
            *packed.shape[:-1], packed.shape[-1] * 2)
    else:
        q = packed.astype(jnp.float32)
    return ((q - zp) * scale).astype(dtype)


def int8_matmul_ref(qx, qw, sx, zx, sw, zw, out_dtype=jnp.float32):
    x = (qx.astype(jnp.float32) - zx) * sx
    w = (qw.astype(jnp.float32) - zw) * sw
    return (x @ w).astype(out_dtype)


def stamp_quant_matmul_ref(x, qw, sw, zw, bias=None, *, transform="dwt",
                           levels=3, skip_first=True, num_hi=64, hi_bits=8,
                           lo_bits=4, out_dtype=jnp.float32):
    """Unfused oracle for `stamp_quant_matmul`: transform → mixed-precision
    fake quant → dequantized matmul → inverse transform → bias, each step a
    separate jnp materialization (exactly the reference execution path)."""
    from repro.core import quant as Q

    xf = x.astype(jnp.float32)
    tx = T.sequence_transform(xf, transform, axis=-2, levels=levels,
                              skip_first=skip_first)
    bits = Q.mixed_precision_bits(tx.shape[-2], num_hi, hi_bits, lo_bits)
    tq = Q.fake_quant(tx, bits, axis=-1)
    wd = (qw.astype(jnp.float32) - zw) * sw
    y = tq @ wd
    y = T.inverse_sequence_transform(y, transform, axis=-2, levels=levels,
                                     skip_first=skip_first)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    return y.astype(out_dtype)

"""``kernel_spec()`` registry: the exact grid / BlockSpec / scratch metadata
every Pallas kernel in this package hands to ``pl.pallas_call``.

The static contract checker (`repro.analysis.contracts.kernel_contracts`)
must reason about the SAME specs the kernels execute with — not a parallel
hand-maintained description that drifts.  So instead of duplicating the
tiling here, each registry entry is a small *representative example call*
(concrete shapes at the kernel's default block sizes), and `kernel_spec()`
runs it under a capture shim: ``pallas_call`` is swapped for a recorder
that snapshots the grid, every BlockSpec's ``(block_shape, index_map)``,
the operand/output shapes and dtypes, the VMEM scratch allocations, and —
for `PrefetchScalarGridSpec` kernels — the concrete scalar-prefetch tables
(block tables, lengths, query starts), then returns zeros of the declared
``out_shape`` so the caller's epilogue still runs.  No kernel body ever
executes; a capture is pure metadata.

Index maps are captured as the live closures the kernel built, so the
checker can evaluate them over the full grid (including the
null-page/inactive-span clamp idioms of `paged_attention`) against the
recorded operand shapes.

Adding a kernel: give it an entry in ``KERNEL_EXAMPLES`` returning
``(fn, args, kwargs)`` with *small* concrete inputs (the grid is
enumerated exhaustively by the checker).  CI fails if a module under
``kernels/`` calls ``pallas_call`` with no registry coverage.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class BufferSpec:
    """One operand/output: its full shape+dtype and its BlockSpec halves."""
    shape: tuple
    dtype: Any
    block_shape: Optional[tuple]        # None => no BlockSpec (whole array)
    index_map: Optional[Callable]


@dataclasses.dataclass
class KernelCapture:
    """One recorded ``pallas_call`` invocation."""
    name: str
    grid: tuple
    inputs: list            # list[BufferSpec] — non-prefetch operands
    outputs: list           # list[BufferSpec]
    scratch: list           # list[(shape, dtype)] — VMEM allocations
    num_scalar_prefetch: int
    prefetch: tuple         # concrete numpy tables fed to the index maps
    interpret: bool


@dataclasses.dataclass
class KernelExample:
    """A registry entry after capture: the example call + its captures."""
    name: str
    fn: Callable
    args: tuple
    kwargs: dict
    captures: list          # list[KernelCapture] (delegation may emit >1)


def _flatten_specs(specs):
    from jax.experimental import pallas as pl
    if specs is None:
        return [None]
    if isinstance(specs, pl.BlockSpec):
        return [specs]
    out = []
    for s in specs:
        out.extend(_flatten_specs(s))
    return out


def _shape_dtype(x):
    return tuple(x.shape), jnp.asarray(x).dtype if not hasattr(x, "dtype") \
        else x.dtype


@contextlib.contextmanager
def _capture_pallas(records: list, name: str):
    """Swap ``jax.experimental.pallas.pallas_call`` for a recorder.  Kernel
    modules resolve ``pl.pallas_call`` at call time through the module
    object, so patching the module attribute intercepts every call."""
    import jax.experimental.pallas as pl_mod

    real = pl_mod.pallas_call

    def fake(kernel, *, grid=None, grid_spec=None, in_specs=None,
             out_specs=None, out_shape=None, scratch_shapes=(),
             interpret=False, **kw):
        if grid_spec is not None:
            g = tuple(grid_spec.grid)
            ins = _flatten_specs(grid_spec.in_specs)
            outs = _flatten_specs(grid_spec.out_specs)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
            scratch = list(getattr(grid_spec, "scratch_shapes", ()) or ())
        else:
            g = tuple(grid) if grid is not None else ()
            ins = _flatten_specs(in_specs)
            outs = _flatten_specs(out_specs)
            nsp = 0
            scratch = list(scratch_shapes or ())

        out_leaves = jax.tree_util.tree_leaves(out_shape)

        def runner(*operands):
            prefetch = tuple(np.asarray(o) for o in operands[:nsp])
            data = operands[nsp:]
            inputs = []
            for spec, op in zip(ins, data):
                inputs.append(BufferSpec(
                    shape=tuple(op.shape), dtype=jnp.asarray(op).dtype
                    if not hasattr(op, "dtype") else op.dtype,
                    block_shape=tuple(spec.block_shape) if spec else None,
                    index_map=spec.index_map if spec else None))
            outputs = []
            for spec, sd in zip(outs, out_leaves):
                outputs.append(BufferSpec(
                    shape=tuple(sd.shape), dtype=sd.dtype,
                    block_shape=tuple(spec.block_shape) if spec else None,
                    index_map=spec.index_map if spec else None))
            records.append(KernelCapture(
                name=name, grid=g, inputs=inputs, outputs=outputs,
                scratch=[(tuple(s.shape), s.dtype) for s in scratch],
                num_scalar_prefetch=nsp, prefetch=prefetch,
                interpret=bool(interpret)))
            return jax.tree_util.tree_map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype), out_shape)

        return runner

    pl_mod.pallas_call = fake
    try:
        yield
    finally:
        pl_mod.pallas_call = real


# ---------------------------------------------------------------------------
# representative examples — concrete shapes at the DEFAULT block sizes
# ---------------------------------------------------------------------------


def _rng():
    return np.random.default_rng(0)


def _prepared_weight(r, k, n):
    qw = r.integers(-128, 128, size=(k, n), dtype=np.int8)
    sw = r.uniform(1e-3, 1e-2, size=(1, n)).astype(np.float32)
    zw = r.integers(-8, 8, size=(1, n)).astype(np.float32)
    bias = r.standard_normal((1, n)).astype(np.float32)
    return qw, sw, zw, bias


def _ex_stamp_single():
    from repro.kernels.stamp_matmul import stamp_quant_matmul_pallas
    r = _rng()
    x = r.standard_normal((2, 16, 32)).astype(np.float32)
    qw, sw, zw, bias = _prepared_weight(r, 32, 256)
    return stamp_quant_matmul_pallas, (x, qw, sw, zw, bias), dict(num_hi=4)


def _ex_stamp_single_headsplit():
    from repro.kernels.stamp_matmul import stamp_quant_matmul_pallas
    r = _rng()
    x = r.standard_normal((2, 16, 4, 16)).astype(np.float32)  # K = 64
    qw, sw, zw, bias = _prepared_weight(r, 64, 256)
    return stamp_quant_matmul_pallas, (x, qw, sw, zw, bias), dict(num_hi=4)


def _ex_stamp_dual():
    from repro.kernels.stamp_matmul import stamp_quant_dual_matmul_pallas
    r = _rng()
    x = r.standard_normal((2, 16, 32)).astype(np.float32)
    qg, sg, zg, bg = _prepared_weight(r, 32, 256)
    qu, su, zu, bu = _prepared_weight(r, 32, 256)
    return stamp_quant_dual_matmul_pallas, \
        (x, qg, sg, zg, bg, qu, su, zu, bu), dict(num_hi=4)


def _ex_stamp_segment():
    from repro.kernels.stamp_matmul import stamp_quant_segment_matmul_pallas
    r = _rng()
    x = r.standard_normal((1, 32, 32)).astype(np.float32)  # 2 spans of 16
    qw, sw, zw, bias = _prepared_weight(r, 32, 256)
    return stamp_quant_segment_matmul_pallas, (x, qw, sw, zw, bias), \
        dict(seg_len=16, num_hi=4)


def _ex_stamp_grouped():
    from repro.kernels.stamp_matmul import stamp_quant_grouped_matmul_pallas
    r = _rng()
    b, e, cap, d, f = 1, 4, 8, 32, 64
    qx = r.integers(-128, 128, size=(b, e, cap, d), dtype=np.int8)
    sx = r.uniform(1e-3, 1e-2, size=(b, e, cap, 1)).astype(np.float32)
    zx = r.integers(-8, 8, size=(b, e, cap, 1)).astype(np.float32)
    # occupancy prefetch table: full, partial and EMPTY buckets — the
    # checker proves the clamped capacity-tile index maps in-bounds on
    # exactly this table (KC001)
    counts = np.array([[8, 5, 0, 8]], np.int32)

    def expert_w(k, n):
        qw = r.integers(-128, 128, size=(e, k, n), dtype=np.int8)
        sw = r.uniform(1e-3, 1e-2, size=(e, 1, n)).astype(np.float32)
        zw = r.integers(-8, 8, size=(e, 1, n)).astype(np.float32)
        return qw, sw, zw

    qg, sg, zg = expert_w(d, f)
    qu, su, zu = expert_w(d, f)
    qd, sd, zd = expert_w(f, d)
    return stamp_quant_grouped_matmul_pallas, \
        (qx, sx, zx, counts, qg, sg, zg, qu, su, zu, qd, sd, zd), \
        dict(block_c=8, block_f=32)


def _ex_decode_matmul():
    from repro.kernels.decode_matmul import stamp_decode_matmul_pallas
    r = _rng()
    x = r.standard_normal((4, 32)).astype(np.float32)
    qw, sw, zw, bias = _prepared_weight(r, 32, 512)
    return stamp_decode_matmul_pallas, (x, qw, sw, zw, bias), {}


def _ex_int8_matmul():
    from repro.kernels.int8_matmul import int8_matmul_pallas
    r = _rng()
    m, k, n = 128, 128, 128           # defaults: one (128, 128, 128) block
    qx = r.integers(-128, 128, size=(m, k), dtype=np.int8)
    qw = r.integers(-128, 128, size=(k, n), dtype=np.int8)
    sx = r.uniform(1e-3, 1e-2, size=(m, 1)).astype(np.float32)
    zx = r.integers(-8, 8, size=(m, 1)).astype(np.float32)
    sw = r.uniform(1e-3, 1e-2, size=(1, n)).astype(np.float32)
    zw = r.integers(-8, 8, size=(1, n)).astype(np.float32)
    return int8_matmul_pallas, (qx, qw, sx, zx, sw, zw), {}


def _ex_haar_dwt():
    from repro.kernels.haar_dwt import haar_dwt_pallas
    x = _rng().standard_normal((2, 16, 256)).astype(np.float32)
    return haar_dwt_pallas, (x,), {}


def _ex_wht_seq():
    from repro.kernels.wht import wht_pallas
    x = _rng().standard_normal((2, 16, 256)).astype(np.float32)
    return wht_pallas, (x,), dict(axis=-2)


def _ex_wht_feat():
    from repro.kernels.wht import wht_pallas
    x = _rng().standard_normal((2, 256, 128)).astype(np.float32)
    return wht_pallas, (x,), dict(axis=-1)


def _ex_quant_pack():
    from repro.kernels.quant_pack import quant_pack_pallas
    x = _rng().standard_normal((2, 256, 64)).astype(np.float32)
    return quant_pack_pallas, (x,), dict(bits=4)


def _ex_cache_attention():
    from repro.kernels.cache_attention import cache_decode_attention
    r = _rng()
    b, h, g, hd, hi, s_lo = 2, 4, 2, 32, 16, 64
    s = hi + s_lo
    entry = {
        "k_hi": r.integers(-128, 128, size=(b, hi, g, hd), dtype=np.int8),
        "v_hi": r.integers(-128, 128, size=(b, hi, g, hd), dtype=np.int8),
        "k_lo": r.integers(0, 256, size=(b, s_lo, g, hd // 2),
                           dtype=np.uint8),
        "v_lo": r.integers(0, 256, size=(b, s_lo, g, hd // 2),
                           dtype=np.uint8),
        "k_scale": r.uniform(1e-3, 1e-2, size=(b, s, g)).astype(np.float32),
        "k_zp": r.integers(0, 8, size=(b, s, g)).astype(np.float32),
        "v_scale": r.uniform(1e-3, 1e-2, size=(b, s, g)).astype(np.float32),
        "v_zp": r.integers(0, 8, size=(b, s, g)).astype(np.float32),
    }
    q = r.standard_normal((b, 1, h, hd)).astype(np.float32)
    lengths = np.array([20, 70], np.int32)
    return cache_decode_attention, (entry, q, lengths), dict(block_s=32)


def _paged_pools(r, g, hd, bs, n_hi_pages, n_lo_pages):
    return {
        "k_hi": r.integers(-128, 128, size=(n_hi_pages, bs, g, hd),
                           dtype=np.int8),
        "v_hi": r.integers(-128, 128, size=(n_hi_pages, bs, g, hd),
                           dtype=np.int8),
        "k_hi_scale": r.uniform(1e-3, 1e-2, size=(n_hi_pages, bs, g)
                                ).astype(np.float32),
        "k_hi_zp": r.integers(0, 8, size=(n_hi_pages, bs, g)
                              ).astype(np.float32),
        "v_hi_scale": r.uniform(1e-3, 1e-2, size=(n_hi_pages, bs, g)
                                ).astype(np.float32),
        "v_hi_zp": r.integers(0, 8, size=(n_hi_pages, bs, g)
                              ).astype(np.float32),
        "k_lo": r.integers(0, 256, size=(n_lo_pages, bs, g, hd // 2),
                           dtype=np.uint8),
        "v_lo": r.integers(0, 256, size=(n_lo_pages, bs, g, hd // 2),
                           dtype=np.uint8),
        "k_lo_scale": r.uniform(1e-3, 1e-2, size=(n_lo_pages, bs, g)
                                ).astype(np.float32),
        "k_lo_zp": r.integers(0, 8, size=(n_lo_pages, bs, g)
                              ).astype(np.float32),
        "v_lo_scale": r.uniform(1e-3, 1e-2, size=(n_lo_pages, bs, g)
                                ).astype(np.float32),
        "v_lo_zp": r.integers(0, 8, size=(n_lo_pages, bs, g)
                              ).astype(np.float32),
    }


def _ex_paged_decode():
    from repro.kernels.paged_attention import paged_decode_attention
    r = _rng()
    g, h, hd, bs = 2, 4, 32, 16
    entry = _paged_pools(r, g, hd, bs, n_hi_pages=4, n_lo_pages=6)
    q = r.standard_normal((3, 1, h, hd)).astype(np.float32)
    lengths = np.array([20, 40, 9], np.int32)
    # unmapped logical blocks hold 0 — the null page — and mask via lengths
    hi_table = np.array([[1], [2], [0]], np.int32)
    lo_table = np.array([[1, 2, 0], [3, 4, 5], [0, 0, 0]], np.int32)
    return paged_decode_attention, \
        (entry, q, lengths, hi_table, lo_table, bs), {}


def _ex_paged_ragged():
    from repro.kernels.paged_attention import paged_ragged_attention
    r = _rng()
    g, h, hd, bs, c_len = 2, 4, 32, 16, 8
    n_pf, s_slots = 2, 3
    entry = _paged_pools(r, g, hd, bs, n_hi_pages=4, n_lo_pages=6)
    q_pf = r.standard_normal((n_pf, c_len, h, hd)).astype(np.float32)
    q_dec = r.standard_normal((s_slots, 1, h, hd)).astype(np.float32)
    q_starts = np.array([0, 16, 19, 39, 8], np.int32)
    lengths = np.array([8, 24, 20, 40, 9], np.int32)
    hi_table = np.array([[1], [3], [1], [2], [0]], np.int32)
    lo_table = np.array([[0, 0, 0], [1, 2, 0],
                         [1, 2, 0], [3, 4, 5], [0, 0, 0]], np.int32)
    return paged_ragged_attention, \
        (entry, q_pf, q_dec, q_starts, lengths, hi_table, lo_table, bs), {}


KERNEL_EXAMPLES: dict = {
    "stamp_matmul.single": _ex_stamp_single,
    "stamp_matmul.single_headsplit": _ex_stamp_single_headsplit,
    "stamp_matmul.dual": _ex_stamp_dual,
    "stamp_matmul.segment": _ex_stamp_segment,
    "stamp_matmul.grouped": _ex_stamp_grouped,
    "decode_matmul": _ex_decode_matmul,
    "int8_matmul": _ex_int8_matmul,
    "haar_dwt": _ex_haar_dwt,
    "wht.seq": _ex_wht_seq,
    "wht.feat": _ex_wht_feat,
    "quant_pack": _ex_quant_pack,
    "cache_attention": _ex_cache_attention,
    "paged_attention.decode": _ex_paged_decode,
    "paged_attention.ragged": _ex_paged_ragged,
}


def kernel_spec(name: str) -> KernelExample:
    """Run one registry example under the capture shim and return its
    recorded ``pallas_call`` metadata (no kernel body executes)."""
    builder = KERNEL_EXAMPLES[name]
    fn, args, kwargs = builder()
    records: list = []
    with _capture_pallas(records, name):
        fn(*args, **kwargs)
    if not records:
        raise RuntimeError(f"kernel example {name!r} made no pallas_call")
    return KernelExample(name=name, fn=fn, args=args, kwargs=kwargs,
                         captures=records)


def all_kernel_specs() -> dict:
    """Capture every registered kernel example: {name: KernelExample}."""
    return {name: kernel_spec(name) for name in KERNEL_EXAMPLES}

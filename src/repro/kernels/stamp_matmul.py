"""Pallas TPU kernel: the fused STaMP deployment linear (Fig. 2a, one pass).

The reference path (`repro.core.stamp.stamp_linear` with
``execution="reference"``) materializes four HBM-sized intermediates per
linear: the sequence-transformed activation ``T = L·X``, the fake-quantized
``Tq``, the matmul output ``Tq·W`` and the inverse-transformed ``L⁻¹(Tq·W)``.
This kernel runs the whole chain in one VMEM residency:

    1. ``T = L · X``          — multi-level Haar DWT / WHT butterflies on the
                                in-VMEM tile (sequence axis fully resident);
    2. ``Q(T)``               — per-token asymmetric min-max quantize, first
                                ``num_hi`` rows at ``hi_bits`` and the rest at
                                ``lo_bits`` (the paper's mixed precision,
                                §3.3), codes shifted into signed int8;
    3. ``Q(T) · Wq``          — int8 × int8 MXU GEMM, int32 accumulation,
                                with the same per-row/per-column zero-point
                                correction epilogue as `int8_matmul.py`:
                                ``(Σ qx·qw − zx·Σqw − zw·Σqx + K·zx·zw)·sx·sw``;
    4. ``L⁻¹ · (…) + 1βᵀ``    — inverse transform then bias (exact per Eq. 7).

The activation therefore makes exactly **one** HBM round trip (read ``X``,
write ``Y``) per output-block program instead of four full materializations.
Weights arrive pre-quantized (signed int8 codes + per-output-channel
scale/zero-point) — see `repro.core.stamp.prepare_linear` — so no bf16
re-materialization of ``W`` happens per call either.

Grid: ``(batch, N / block_n)``.  Each program holds the full ``(s, K)``
activation tile plus a ``(K, block_n)`` weight block in VMEM; at s = 4k,
K = 4k f32 that is 64 MiB + 2 MiB — within v5p VMEM budgets for serving
shapes; shrink ``block_n`` (weight block) for larger K.  The transform +
quantize run **once per batch row** (on the first output-block grid step)
into VMEM scratch; subsequent output blocks reuse the int8 codes and
per-token scales, so widening N (e.g. a concatenated QKV weight) adds only
GEMM + epilogue work.  The activation block index is constant across the N
grid axis, so the pipeline fetches X from HBM once per row (Mosaic skips
re-copying revisited blocks).  The transform butterflies reuse the pure-jnp
orthonormal helpers from `repro.core.transforms` — static shapes, so they
trace into sublane shuffles the same way `haar_dwt.py` / `wht.py` do,
including the identity-tail handling for non-power-of-two sequence lengths
and the first-token (attention sink) exception.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import transforms as T

# transforms the fused kernel can run in-VMEM; dct/klt/dwt2d fall back to
# the reference path (dense O(s²) bases / latent-grid reads don't tile).
FUSABLE_TRANSFORMS = ("none", "dwt", "wht")


def _seq_fwd(x, kind: str, levels: int, skip_first: bool):
    if kind == "none":
        return x
    if kind == "dwt":
        return T.haar_dwt(x, levels=levels, axis=-2, skip_first=skip_first)
    if kind == "wht":
        return T.wht(x, axis=-2, skip_first=skip_first)
    raise ValueError(f"transform {kind!r} not fusable")


def _seq_inv(y, kind: str, levels: int, skip_first: bool):
    if kind == "none":
        return y
    if kind == "dwt":
        return T.haar_idwt(y, levels=levels, axis=-2, skip_first=skip_first)
    if kind == "wht":
        return T.iwht(y, axis=-2, skip_first=skip_first)
    raise ValueError(f"transform {kind!r} not fusable")


def _stamp_kernel(x_ref, qw_ref, sw_ref, zw_ref, b_ref, o_ref,
                  qx_ref, sx_ref, zx_ref, *,
                  transform: str, levels: int, skip_first: bool,
                  num_hi: int, hi_bits: int, lo_bits: int, k_total: int):
    @pl.when(pl.program_id(1) == 0)
    def _transform_and_quantize():
        # runs once per batch row; later output blocks reuse the scratch
        x = x_ref[0].astype(jnp.float32)               # (s, K)
        tx = _seq_fwd(x, transform, levels, skip_first)
        s = tx.shape[0]
        # mixed-precision per-token min-max quantize (Eq. 1 with b_ij = b_i)
        row = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)
        n_lev = jnp.where(row < num_hi, 2.0 ** hi_bits - 1.0,
                          2.0 ** lo_bits - 1.0)
        mn = jnp.min(tx, axis=-1, keepdims=True)
        mx = jnp.max(tx, axis=-1, keepdims=True)
        sx = jnp.maximum((mx - mn) / n_lev, 1e-8)
        zx = jnp.round(-mn / sx)
        q = jnp.clip(jnp.round(tx / sx) + zx, 0.0, n_lev)
        qx_ref[...] = (q - 128.0).astype(jnp.int8)  # unsigned → signed codes
        sx_ref[...] = sx
        zx_ref[...] = zx - 128.0           # shift zp identically (exact)

    qx = qx_ref[...]                                   # (s, K) int8
    sx = sx_ref[...]
    zxs = zx_ref[...]

    # integer GEMM with on-the-fly correction sums (reads each operand once)
    qw = qw_ref[...]                                   # (K, bn) int8
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    qw_sum = jnp.sum(qw.astype(jnp.int32), axis=0,
                     keepdims=True).astype(jnp.float32)
    qx_sum = jnp.sum(qx.astype(jnp.int32), axis=1,
                     keepdims=True).astype(jnp.float32)
    sw = sw_ref[...].astype(jnp.float32)               # (1, bn)
    zw = zw_ref[...].astype(jnp.float32)
    corr = acc - zxs * qw_sum - zw * qx_sum + float(k_total) * zxs * zw
    y = corr * sx * sw                                 # (s, bn) f32

    # inverse transform commutes with the right-multiplication by W, so it
    # applies per output block; bias afterwards is exact (Eq. 7).
    y = _seq_inv(y, transform, levels, skip_first)
    o_ref[0] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def stamp_quant_matmul_pallas(
    x: jax.Array,            # (b, s, K) float
    qw: jax.Array,           # (K, N) int8 signed codes
    sw: jax.Array,           # (1, N) f32 per-output-channel scale
    zw: jax.Array,           # (1, N) f32 signed-shifted zero point
    bias: jax.Array,         # (1, N) f32 (zeros when the layer has no bias)
    *,
    transform: str = "dwt",
    levels: int = 3,
    skip_first: bool = True,
    num_hi: int = 64,
    hi_bits: int = 8,
    lo_bits: int = 4,
    block_n: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Fused STaMP linear: ``L⁻¹(Q(L·x) · Wq_deq) + bias`` in one kernel."""
    assert transform in FUSABLE_TRANSFORMS, transform
    b, s, k = x.shape
    k2, n = qw.shape
    assert k == k2, (k, k2)
    # halve until the block divides N — never fall back to a full-width
    # block (a concatenated QKV width like 3200 would otherwise force the
    # whole (K, N) weight + (s, N) f32 output into one VMEM residency)
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    kernel = functools.partial(
        _stamp_kernel, transform=transform, levels=levels,
        skip_first=skip_first, num_hi=num_hi, hi_bits=hi_bits,
        lo_bits=lo_bits, k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(b, n // bn),
        in_specs=[
            pl.BlockSpec((1, s, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, s, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, n), out_dtype or x.dtype),
        scratch_shapes=[
            pltpu.VMEM((s, k), jnp.int8),      # quantized activation codes
            pltpu.VMEM((s, 1), jnp.float32),   # per-token scale
            pltpu.VMEM((s, 1), jnp.float32),   # per-token (shifted) zp
        ],
        interpret=interpret,
    )(x, qw, sw, zw, bias)

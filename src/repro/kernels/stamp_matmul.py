"""Pallas TPU kernels: the fused STaMP deployment linears (Fig. 2a, one pass).

The reference path (`repro.core.stamp.stamp_linear` with
``execution="reference"``) materializes four HBM-sized intermediates per
linear: the sequence-transformed activation ``T = L·X``, the fake-quantized
``Tq``, the matmul output ``Tq·W`` and the inverse-transformed ``L⁻¹(Tq·W)``.
The kernels here run the whole chain in one VMEM residency:

    1. ``T = L · X``          — multi-level Haar DWT / WHT butterflies on the
                                in-VMEM tile (sequence axis fully resident);
    2. ``Q(T)``               — per-token asymmetric min-max quantize, first
                                ``num_hi`` rows at ``hi_bits`` and the rest at
                                ``lo_bits`` (the paper's mixed precision,
                                §3.3), codes shifted into signed int8;
    3. ``Q(T) · Wq``          — int8 × int8 MXU GEMM, int32 accumulation,
                                with the same per-row/per-column zero-point
                                correction epilogue as `int8_matmul.py`:
                                ``(Σ qx·qw − zx·Σqw − zw·Σqx + K·zx·zw)·sx·sw``;
    4. ``L⁻¹ · (…) + 1βᵀ``    — inverse transform then bias (exact per Eq. 7).

The activation therefore makes exactly **one** HBM round trip (read ``X``,
write ``Y``) per output-block program instead of four full materializations.
Weights arrive pre-quantized (signed int8 codes + per-output-channel
scale/zero-point) — see `repro.core.stamp.prepare_linear` — so no bf16
re-materialization of ``W`` happens per call either.

Grid: ``(batch, N / block_n)``.  Each program holds the full ``(s, K)``
activation tile plus a ``(K, block_n)`` weight block in VMEM; at s = 4k,
K = 4k f32 that is 64 MiB + 2 MiB — within v5p VMEM budgets for serving
shapes; shrink ``block_n`` (weight block) for larger K.  The transform +
quantize run **once per batch row** (on the first output-block grid step)
into VMEM scratch; subsequent output blocks reuse the int8 codes and
per-token scales, so widening N (e.g. a concatenated QKV weight) adds only
GEMM + epilogue work.  The activation block index is constant across the N
grid axis, so the pipeline fetches X from HBM once per row (Mosaic skips
re-copying revisited blocks).  The transform butterflies reuse the pure-jnp
orthonormal helpers from `repro.core.transforms` — static shapes, so they
trace into sublane shuffles the same way `haar_dwt.py` / `wht.py` do,
including the identity-tail handling for non-power-of-two sequence lengths
and the first-token (attention sink) exception.

Three call-site variants share that structure:

* `stamp_quant_matmul_pallas` — the single-output kernel.  ``x`` may be
  ``(b, s, K)`` or, for the attention out-proj, the *raw head-split*
  ``(b, s, nh, hd)`` attention output: the head-merge reshape happens on
  the in-VMEM tile right before the transform, so no merged ``(b, s,
  nh·hd)`` activation ever materializes in HBM between attention and the
  projection.
* `stamp_quant_dual_matmul_pallas` — the dual-output (gate/up) kernel.
  Two weight sets with the same output width share ONE transform+quantize
  of the common activation (the scratch codes drive both GEMMs); the
  optional ``silu·mul`` epilogue combines the two inverse-transformed
  results in-VMEM, writing a single output — the down-proj input — so the
  whole SwiGLU front half costs one activation read and one write.
* `decode_matmul.stamp_decode_matmul_pallas` (sibling module) — the
  transform-free single-token variant for decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import transforms as T

# transforms the fused kernel can run in-VMEM; dct/klt/dwt2d fall back to
# the reference path (dense O(s²) bases / latent-grid reads don't tile).
FUSABLE_TRANSFORMS = ("none", "dwt", "wht")


def _seq_fwd(x, kind: str, levels: int, skip_first: bool):
    if kind == "none":
        return x
    if kind == "dwt":
        return T.haar_dwt(x, levels=levels, axis=-2, skip_first=skip_first)
    if kind == "wht":
        return T.wht(x, axis=-2, skip_first=skip_first)
    raise ValueError(f"transform {kind!r} not fusable")


def _seq_inv(y, kind: str, levels: int, skip_first: bool):
    if kind == "none":
        return y
    if kind == "dwt":
        return T.haar_idwt(y, levels=levels, axis=-2, skip_first=skip_first)
    if kind == "wht":
        return T.iwht(y, axis=-2, skip_first=skip_first)
    raise ValueError(f"transform {kind!r} not fusable")


def _transform_quantize(x_ref, qx_ref, sx_ref, zx_ref, *,
                        transform: str, levels: int, skip_first: bool,
                        num_hi: int, hi_bits: int, lo_bits: int):
    """Transform + mixed-precision quantize the in-VMEM activation tile into
    scratch.  Runs on the first output-block grid step of each batch row;
    later blocks (and, in the dual kernel, the second GEMM) reuse the codes.
    A head-split ``(s, nh, hd)`` tile is merged to ``(s, nh·hd)`` here — the
    head-merge reshape is fused with the quantize, entirely in VMEM."""
    x = x_ref[0].astype(jnp.float32)
    x = x.reshape(x.shape[0], -1)                      # (s, K) head merge
    tx = _seq_fwd(x, transform, levels, skip_first)
    s = tx.shape[0]
    # mixed-precision per-token min-max quantize (Eq. 1 with b_ij = b_i)
    row = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)
    n_lev = jnp.where(row < num_hi, 2.0 ** hi_bits - 1.0,
                      2.0 ** lo_bits - 1.0)
    mn = jnp.min(tx, axis=-1, keepdims=True)
    mx = jnp.max(tx, axis=-1, keepdims=True)
    sx = jnp.maximum((mx - mn) / n_lev, 1e-8)
    zx = jnp.round(-mn / sx)
    q = jnp.clip(jnp.round(tx / sx) + zx, 0.0, n_lev)
    qx_ref[...] = (q - 128.0).astype(jnp.int8)      # unsigned → signed codes
    sx_ref[...] = sx
    zx_ref[...] = zx - 128.0               # shift zp identically (exact)


def _int_gemm(qx, sx, zxs, qw, sw, zw, *, k_total: int):
    """int8×int8 GEMM with the zero-point-correction epilogue; reads each
    operand once.  Takes in-VMEM *values* (``(K, bn)`` int8 codes plus
    ``(1, bn)`` scale / shifted zp) so callers can slice away leading
    block axes first.  Returns the dequantized (s, bn) f32 partial
    product."""
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    qw_sum = jnp.sum(qw.astype(jnp.int32), axis=0,
                     keepdims=True).astype(jnp.float32)
    qx_sum = jnp.sum(qx.astype(jnp.int32), axis=1,
                     keepdims=True).astype(jnp.float32)
    sw = sw.astype(jnp.float32)                        # (1, bn)
    zw = zw.astype(jnp.float32)
    corr = acc - zxs * qw_sum - zw * qx_sum + float(k_total) * zxs * zw
    return corr * sx * sw                              # (s, bn) f32


def _stamp_kernel(x_ref, qw_ref, sw_ref, zw_ref, b_ref, o_ref,
                  qx_ref, sx_ref, zx_ref, *,
                  transform: str, levels: int, skip_first: bool,
                  num_hi: int, hi_bits: int, lo_bits: int, k_total: int):
    @pl.when(pl.program_id(1) == 0)
    def _tq():
        _transform_quantize(x_ref, qx_ref, sx_ref, zx_ref,
                            transform=transform, levels=levels,
                            skip_first=skip_first, num_hi=num_hi,
                            hi_bits=hi_bits, lo_bits=lo_bits)

    y = _int_gemm(qx_ref[...], sx_ref[...], zx_ref[...],
                  qw_ref[...], sw_ref[...], zw_ref[...], k_total=k_total)
    # inverse transform commutes with the right-multiplication by W, so it
    # applies per output block; bias afterwards is exact (Eq. 7).
    y = _seq_inv(y, transform, levels, skip_first)
    o_ref[0] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _stamp_dual_kernel(x_ref, qwg_ref, swg_ref, zwg_ref, bg_ref,
                       qwu_ref, swu_ref, zwu_ref, bu_ref, *refs,
                       transform: str, levels: int, skip_first: bool,
                       num_hi: int, hi_bits: int, lo_bits: int, k_total: int,
                       epilogue: str):
    """Two GEMMs (gate/up) off ONE scratch-resident quantized activation.

    With ``epilogue="silu_mul"`` the inverse-transformed pair combines to
    ``silu(g)·u`` in-VMEM and a single output block is written; with
    ``epilogue="none"`` both projections are written separately."""
    if epilogue == "silu_mul":
        o_ref, qx_ref, sx_ref, zx_ref = refs
    else:
        og_ref, ou_ref, qx_ref, sx_ref, zx_ref = refs

    @pl.when(pl.program_id(1) == 0)
    def _tq():
        _transform_quantize(x_ref, qx_ref, sx_ref, zx_ref,
                            transform=transform, levels=levels,
                            skip_first=skip_first, num_hi=num_hi,
                            hi_bits=hi_bits, lo_bits=lo_bits)

    qx, sx, zxs = qx_ref[...], sx_ref[...], zx_ref[...]
    yg = _int_gemm(qx, sx, zxs, qwg_ref[...], swg_ref[...], zwg_ref[...],
                   k_total=k_total)
    yu = _int_gemm(qx, sx, zxs, qwu_ref[...], swu_ref[...], zwu_ref[...],
                   k_total=k_total)
    # both outputs return to the original domain before the gating
    # nonlinearity — silu does NOT commute with L⁻¹, the element-wise
    # product must happen on tokens, not wavelet coefficients.
    yg = _seq_inv(yg, transform, levels, skip_first) \
        + bg_ref[...].astype(jnp.float32)
    yu = _seq_inv(yu, transform, levels, skip_first) \
        + bu_ref[...].astype(jnp.float32)
    if epilogue == "silu_mul":
        o_ref[0] = (jax.nn.silu(yg) * yu).astype(o_ref.dtype)
    else:
        og_ref[0] = yg.astype(og_ref.dtype)
        ou_ref[0] = yu.astype(ou_ref.dtype)


def _pick_block_n(block_n: int, n: int) -> int:
    # halve until the block divides N — never fall back to a full-width
    # block (a concatenated QKV width like 3200 would otherwise force the
    # whole (K, N) weight + (s, N) f32 output into one VMEM residency)
    bn = min(block_n, n)
    while n % bn:
        bn //= 2
    return bn


def _x_spec(x: jax.Array) -> tuple[pl.BlockSpec, int, int, int]:
    """Activation BlockSpec for a (b, s, K) or raw head-split (b, s, nh, hd)
    input.  The 4-D case maps the full (s, nh, hd) tile per batch row; the
    kernel merges heads in VMEM (`_transform_quantize`), so the out-proj
    consumes the attention output without a merged HBM intermediate."""
    if x.ndim == 4:
        b, s, nh, hd = x.shape
        return pl.BlockSpec((1, s, nh, hd), lambda i, j: (i, 0, 0, 0)), \
            b, s, nh * hd
    b, s, k = x.shape
    return pl.BlockSpec((1, s, k), lambda i, j: (i, 0, 0)), b, s, k


def stamp_quant_matmul_pallas(
    x: jax.Array,            # (b, s, K) float — or (b, s, nh, hd) head-split
    qw: jax.Array,           # (K, N) int8 signed codes
    sw: jax.Array,           # (1, N) f32 per-output-channel scale
    zw: jax.Array,           # (1, N) f32 signed-shifted zero point
    bias: jax.Array,         # (1, N) f32 (zeros when the layer has no bias)
    *,
    transform: str = "dwt",
    levels: int = 3,
    skip_first: bool = True,
    num_hi: int = 64,
    hi_bits: int = 8,
    lo_bits: int = 4,
    block_n: int = 256,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused STaMP linear: ``L⁻¹(Q(L·x) · Wq_deq) + bias`` in one kernel."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    if transform not in FUSABLE_TRANSFORMS:
        raise ValueError(f"transform {transform!r} is not fusable "
                         f"(expected one of {FUSABLE_TRANSFORMS})")
    x_spec, b, s, k = _x_spec(x)
    k2, n = qw.shape
    if k != k2:
        raise ValueError(f"activation K={k} does not match weight K={k2}")
    bn = _pick_block_n(block_n, n)
    kernel = functools.partial(
        _stamp_kernel, transform=transform, levels=levels,
        skip_first=skip_first, num_hi=num_hi, hi_bits=hi_bits,
        lo_bits=lo_bits, k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(b, n // bn),
        in_specs=[
            x_spec,
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, s, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, n), out_dtype or x.dtype),
        scratch_shapes=[
            pltpu.VMEM((s, k), jnp.int8),      # quantized activation codes
            pltpu.VMEM((s, 1), jnp.float32),   # per-token scale
            pltpu.VMEM((s, 1), jnp.float32),   # per-token (shifted) zp
        ],
        interpret=interpret,
    )(x, qw, sw, zw, bias)


def stamp_quant_dual_matmul_pallas(
    x: jax.Array,            # (b, s, K) float
    qw_g: jax.Array,         # (K, N) int8 gate codes
    sw_g: jax.Array,         # (1, N) f32
    zw_g: jax.Array,         # (1, N) f32
    bias_g: jax.Array,       # (1, N) f32
    qw_u: jax.Array,         # (K, N) int8 up codes
    sw_u: jax.Array,
    zw_u: jax.Array,
    bias_u: jax.Array,
    *,
    transform: str = "dwt",
    levels: int = 3,
    skip_first: bool = True,
    num_hi: int = 64,
    hi_bits: int = 8,
    lo_bits: int = 4,
    block_n: int = 256,
    epilogue: str = "silu_mul",   # "silu_mul" | "none"
    out_dtype=None,
    interpret: bool | None = None,
):
    """Fused STaMP gate/up pair: ONE transform+quantize of the shared input
    drives both integer GEMMs.  ``epilogue="silu_mul"`` returns
    ``silu(L⁻¹(Q·Wg)+bg) · (L⁻¹(Q·Wu)+bu)`` as a single array;
    ``epilogue="none"`` returns the ``(gate, up)`` tuple."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    if transform not in FUSABLE_TRANSFORMS:
        raise ValueError(f"transform {transform!r} is not fusable "
                         f"(expected one of {FUSABLE_TRANSFORMS})")
    if epilogue not in ("silu_mul", "none"):
        raise ValueError(f"unknown epilogue {epilogue!r}")
    x_spec, b, s, k = _x_spec(x)
    k2, n = qw_g.shape
    if k != k2:
        raise ValueError(f"activation K={k} does not match weight K={k2}")
    if qw_u.shape != qw_g.shape:
        raise ValueError(f"gate/up weight shapes differ: "
                         f"{qw_g.shape} vs {qw_u.shape}")
    bn = _pick_block_n(block_n, n)
    kernel = functools.partial(
        _stamp_dual_kernel, transform=transform, levels=levels,
        skip_first=skip_first, num_hi=num_hi, hi_bits=hi_bits,
        lo_bits=lo_bits, k_total=k, epilogue=epilogue)
    w_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    c_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((1, s, bn), lambda i, j: (i, 0, j))
    o_shape = jax.ShapeDtypeStruct((b, s, n), out_dtype or x.dtype)
    single = epilogue == "silu_mul"
    out = pl.pallas_call(
        kernel,
        grid=(b, n // bn),
        in_specs=[x_spec,
                  w_spec, c_spec, c_spec, c_spec,
                  w_spec, c_spec, c_spec, c_spec],
        out_specs=o_spec if single else (o_spec, o_spec),
        out_shape=o_shape if single else (o_shape, o_shape),
        scratch_shapes=[
            pltpu.VMEM((s, k), jnp.int8),      # shared quantized codes
            pltpu.VMEM((s, 1), jnp.float32),   # per-token scale
            pltpu.VMEM((s, 1), jnp.float32),   # per-token (shifted) zp
        ],
        interpret=interpret,
    )(x, qw_g, sw_g, zw_g, bias_g, qw_u, sw_u, zw_u, bias_u)
    return out


def stamp_quant_segment_matmul_pallas(
    x: jax.Array,            # (b, n_seg·seg_len, K) flattened uniform spans
    qw: jax.Array,
    sw: jax.Array,
    zw: jax.Array,
    bias: jax.Array,
    *,
    seg_len: int,
    **kwargs,
) -> jax.Array:
    """Segment-aware fused STaMP linear for the unified ragged serving step.

    ``x`` is a flattened batch of uniform ``seg_len``-token sequence spans
    (several requests' prefill chunks concatenated along axis 1).  The
    sequence transform must run **per span, never across the flattened
    batch** — so spans fold into the kernel's batch grid axis (each grid
    row's transform+quantize scratch is private), and the output unfolds
    back to the flattened layout.  Identical math to calling
    `stamp_quant_matmul_pallas` once per span."""
    b, t = x.shape[0], x.shape[1]
    if t % seg_len:
        raise ValueError(f"flattened length {t} is not a whole number of "
                         f"{seg_len}-token segments")
    xf = x.reshape(b * (t // seg_len), seg_len, *x.shape[2:])
    y = stamp_quant_matmul_pallas(xf, qw, sw, zw, bias, **kwargs)
    return y.reshape(b, t, y.shape[-1])


# ---------------------------------------------------------------------------
# Grouped MoE expert GEMMs over the quantized dispatch buffer
# ---------------------------------------------------------------------------


def _rowwise_quantize(a):
    """Per-row 8-bit asymmetric min-max quantize of an in-VMEM f32 tile —
    the same quantizer `_transform_quantize` applies per token, without the
    transform (the grouped down-proj input lives in the token domain).
    Returns signed int8 codes plus (rows, 1) f32 scale / shifted zp."""
    mn = jnp.min(a, axis=-1, keepdims=True)
    mx = jnp.max(a, axis=-1, keepdims=True)
    sa = jnp.maximum((mx - mn) / 255.0, 1e-8)
    za = jnp.round(-mn / sa)
    qa = (jnp.clip(jnp.round(a / sa) + za, 0.0, 255.0) - 128.0) \
        .astype(jnp.int8)
    return qa, sa, za - 128.0


def _grouped_moe_kernel(counts_ref, qx_ref, sx_ref, zx_ref,
                        qwg_ref, swg_ref, zwg_ref,
                        qwu_ref, swu_ref, zwu_ref,
                        qwd_ref, swd_ref, zwd_ref,
                        o_ref, acc_ref, *,
                        num_experts: int, block_c: int, block_f: int,
                        nf: int, d: int):
    """One (batch, expert, capacity-tile, f-tile) grid step of the grouped
    MoE FFN: dual gate/up int8 GEMMs off the SHARED quantized dispatch
    tile, silu·mul epilogue in VMEM, per-row requantize of the activation
    slab, and the partial down-proj accumulated over the f axis into
    scratch.  ``counts_ref`` is the scalar-prefetched per-(batch, expert)
    occupancy table: rows at or past the expert's kept-token count are
    zeroed on the final write (capacity-dropped / empty slots contribute
    exactly zero, matching the reference dispatch einsum)."""
    i, e, c, j = (pl.program_id(0), pl.program_id(1),
                  pl.program_id(2), pl.program_id(3))
    cnt = counts_ref[i * num_experts + e]

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qx = qx_ref[0, 0]                                  # (bc, d) int8
    sx = sx_ref[0, 0]                                  # (bc, 1) f32
    zxs = zx_ref[0, 0]
    g = _int_gemm(qx, sx, zxs, qwg_ref[0], swg_ref[0], zwg_ref[0],
                  k_total=d)
    u = _int_gemm(qx, sx, zxs, qwu_ref[0], swu_ref[0], zwu_ref[0],
                  k_total=d)
    a = jax.nn.silu(g) * u                             # (bc, bf) f32
    # the down-proj consumes the activation slab as int8 too: per-row
    # quantize within this f block (group-wise scales — each f tile gets
    # its own row scale, so the partial products dequantize exactly)
    qa, sa, zas = _rowwise_quantize(a)
    acc_ref[...] += _int_gemm(qa, sa, zas, qwd_ref[0], swd_ref[0],
                              zwd_ref[0], k_total=block_f)

    @pl.when(j == nf - 1)
    def _write():
        row = c * block_c + jax.lax.broadcasted_iota(
            jnp.int32, (block_c, 1), 0)
        o_ref[0, 0] = jnp.where(row < cnt, acc_ref[...],
                                0.0).astype(o_ref.dtype)


def stamp_quant_grouped_matmul_pallas(
    qx: jax.Array,           # (b, E, C, d) int8 gathered dispatch codes
    sx: jax.Array,           # (b, E, C, 1) f32 per-token scale
    zx: jax.Array,           # (b, E, C, 1) f32 per-token shifted zp
    counts: jax.Array,       # (b, E) int32 kept tokens per expert bucket
    qw_gate: jax.Array,      # (E, d, f) int8 stacked expert gate codes
    sw_gate: jax.Array,      # (E, 1, f) f32
    zw_gate: jax.Array,      # (E, 1, f) f32
    qw_up: jax.Array,        # (E, d, f) int8
    sw_up: jax.Array,
    zw_up: jax.Array,
    qw_down: jax.Array,      # (E, f, d) int8
    sw_down: jax.Array,      # (E, 1, d) f32
    zw_down: jax.Array,
    *,
    block_c: int = 128,
    block_f: int = 512,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Grouped STaMP MoE FFN: the full expert stack in ONE kernel.

    The walk is ``(batch, E, C/block_c, f/block_f)`` over the
    capacity-bucketed dispatch buffer — tokens were transformed +
    mixed-precision quantized ONCE per sequence span *before* dispatch, so
    each grid step streams int8 codes and int8 expert weights only.  Per
    step: gate and up GEMMs share the one quantized dispatch tile, the
    silu·mul epilogue runs in VMEM, and the grouped down-proj consumes the
    requantized activation slab with its partial products accumulated in
    f32 scratch across the f axis.  The per-(batch, expert) occupancy
    ``counts`` rides as a scalar-prefetch table: index maps clamp the
    capacity-tile fetch for empty bucket tails (no dead code streams), and
    slots past the count write exact zeros.

    Returns the (b, E, C, d) expert outputs ready for the combine einsum.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, e, cap, d = qx.shape
    f = qw_gate.shape[-1]
    bc = min(block_c, cap)
    pad_c = -cap % bc
    if pad_c:
        padc = [(0, 0), (0, 0), (0, pad_c), (0, 0)]
        qx = jnp.pad(qx, padc)
        sx = jnp.pad(sx, padc, constant_values=1.0)
        zx = jnp.pad(zx, padc)
    bf = _pick_block_n(block_f, f)
    nc, nf = (cap + pad_c) // bc, f // bf
    counts = counts.reshape(-1).astype(jnp.int32)

    def occ_idx(i, eg, c, cnt):
        # last capacity tile this expert bucket actually occupies; empty
        # tail tiles re-fetch it (index unchanged between steps → no copy)
        nblk = (cnt[i * e + eg] + bc - 1) // bc
        return jnp.minimum(c, jnp.maximum(nblk - 1, 0))

    x_spec = pl.BlockSpec((1, 1, bc, d),
                          lambda i, eg, c, j, cnt:
                          (i, eg, occ_idx(i, eg, c, cnt), 0))
    s_spec = pl.BlockSpec((1, 1, bc, 1),
                          lambda i, eg, c, j, cnt:
                          (i, eg, occ_idx(i, eg, c, cnt), 0))
    win_spec = pl.BlockSpec((1, d, bf),
                            lambda i, eg, c, j, cnt: (eg, 0, j))
    cin_spec = pl.BlockSpec((1, 1, bf),
                            lambda i, eg, c, j, cnt: (eg, 0, j))
    wdn_spec = pl.BlockSpec((1, bf, d),
                            lambda i, eg, c, j, cnt: (eg, j, 0))
    cdn_spec = pl.BlockSpec((1, 1, d),
                            lambda i, eg, c, j, cnt: (eg, 0, 0))
    kernel = functools.partial(
        _grouped_moe_kernel, num_experts=e, block_c=bc, block_f=bf,
        nf=nf, d=d)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, e, nc, nf),
            in_specs=[
                x_spec, s_spec, s_spec,
                win_spec, cin_spec, cin_spec,
                win_spec, cin_spec, cin_spec,
                wdn_spec, cdn_spec, cdn_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, bc, d),
                                   lambda i, eg, c, j, cnt: (i, eg, c, 0)),
            scratch_shapes=[
                pltpu.VMEM((bc, d), jnp.float32),   # down-proj accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, e, cap + pad_c, d), out_dtype),
        interpret=interpret,
    )(counts, qx, sx, zx,
      qw_gate, sw_gate, zw_gate,
      qw_up, sw_up, zw_up,
      qw_down, sw_down, zw_down)
    return out[:, :, :cap] if pad_c else out

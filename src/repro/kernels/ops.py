"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True`` — the
kernel body runs in Python, which validates BlockSpec indexing and kernel
math against the `ref.py` oracles.  On TPU the same call sites compile to
Mosaic.  ``force_interpret`` exists so tests pin the mode explicitly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_matmul import stamp_decode_matmul_pallas
from repro.kernels.haar_dwt import haar_dwt_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.quant_pack import quant_pack_pallas
from repro.kernels.stamp_matmul import (stamp_quant_dual_matmul_pallas,
                                        stamp_quant_grouped_matmul_pallas,
                                        stamp_quant_matmul_pallas)
from repro.kernels.wht import wht_pallas


def default_interpret() -> bool:
    """Shared ``interpret=`` default for every Pallas kernel in this package:
    interpret-mode everywhere except on a real TPU backend.  Kernel entry
    points accept ``interpret=None`` and resolve it through this one switch,
    so tests can still pin the mode explicitly."""
    return jax.default_backend() != "tpu"


_interpret_default = default_interpret  # back-compat alias


@functools.partial(jax.jit, static_argnames=("levels", "inverse", "block_d",
                                             "interpret"))
def haar_dwt_seq(x, levels: int = 3, inverse: bool = False,
                 block_d: int = 128, interpret: bool | None = None):
    """Multi-level sequence-axis Haar DWT, fused over levels.  x: (b, s, d)."""
    if interpret is None:
        interpret = default_interpret()
    d = x.shape[2]
    block_d = min(block_d, d)
    while d % block_d:
        block_d //= 2
    # keep the per-program VMEM tile (s × block_d × 4B) under ~8 MiB
    while x.shape[1] * block_d * 4 > 8 * 2**20 and block_d > 8:
        block_d //= 2
    while d % block_d:
        block_d //= 2
    return haar_dwt_pallas(x, levels=levels, inverse=inverse,
                           block_d=max(block_d, 1), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("axis", "interpret"))
def walsh_hadamard(x, axis: int = -2, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return wht_pallas(x, axis=axis, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pack(x, bits: int = 4, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return quant_pack_pallas(x, bits=bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def int8_matmul(qx, qw, sx, zx, sw, zw, out_dtype=jnp.bfloat16,
                interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return int8_matmul_pallas(qx, qw, sx, zx, sw, zw, out_dtype=out_dtype,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "transform", "levels", "skip_first", "num_hi", "hi_bits", "lo_bits",
    "out_dtype", "interpret"))
def stamp_quant_matmul(x, qw, sw, zw, bias=None, *, transform: str = "dwt",
                       levels: int = 3, skip_first: bool = True,
                       num_hi: int = 64, hi_bits: int = 8, lo_bits: int = 4,
                       out_dtype=None, interpret: bool | None = None):
    """Fused STaMP deployment linear (see `stamp_matmul.py`).

    x: (b, s, K) float — or the raw head-split (b, s, nh, hd) attention
    output (out-proj site: the head-merge reshape fuses with the in-VMEM
    quantize); qw: (K, N) signed int8 codes; sw/zw: (1, N) f32.
    ``bias=None`` lowers a zero bias block (the add is free inside the
    epilogue's VMEM residency).
    """
    if interpret is None:
        interpret = default_interpret()
    if bias is None:
        bias = jnp.zeros((1, qw.shape[1]), jnp.float32)
    return stamp_quant_matmul_pallas(
        x, qw, sw, zw, bias.reshape(1, -1).astype(jnp.float32),
        transform=transform, levels=levels, skip_first=skip_first,
        num_hi=num_hi, hi_bits=hi_bits, lo_bits=lo_bits,
        out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "transform", "levels", "skip_first", "num_hi", "hi_bits", "lo_bits",
    "epilogue", "out_dtype", "interpret"))
def stamp_quant_dual_matmul(x, qw_g, sw_g, zw_g, qw_u, sw_u, zw_u,
                            bias_g=None, bias_u=None, *,
                            transform: str = "dwt", levels: int = 3,
                            skip_first: bool = True, num_hi: int = 64,
                            hi_bits: int = 8, lo_bits: int = 4,
                            epilogue: str = "silu_mul", out_dtype=None,
                            interpret: bool | None = None):
    """Fused STaMP gate/up pair (see `stamp_matmul.py`): the shared input's
    sequence transform + mixed-precision quantize run ONCE into VMEM scratch
    and feed both integer GEMMs.  ``epilogue="silu_mul"`` (the SwiGLU front
    half) returns one array; ``"none"`` returns the (gate, up) tuple.
    """
    if interpret is None:
        interpret = default_interpret()
    if bias_g is None:
        bias_g = jnp.zeros((1, qw_g.shape[1]), jnp.float32)
    if bias_u is None:
        bias_u = jnp.zeros((1, qw_u.shape[1]), jnp.float32)
    return stamp_quant_dual_matmul_pallas(
        x, qw_g, sw_g, zw_g, bias_g.reshape(1, -1).astype(jnp.float32),
        qw_u, sw_u, zw_u, bias_u.reshape(1, -1).astype(jnp.float32),
        transform=transform, levels=levels, skip_first=skip_first,
        num_hi=num_hi, hi_bits=hi_bits, lo_bits=lo_bits, epilogue=epilogue,
        out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def stamp_decode_matmul(x, qw, sw, zw, bias=None, *, out_dtype=None,
                        interpret: bool | None = None):
    """Fused single-token decode linear (see `decode_matmul.py`).

    x: (B, K) float — one token per slot; qw: (K, N) signed int8 codes from
    `prepare_linear`; sw/zw: (1, N) f32.  No sequence transform: a lone
    decode token is its own (trivially Toeplitz) sequence, so STaMP reduces
    to the 8-bit per-token quantize + integer GEMM.
    """
    if interpret is None:
        interpret = default_interpret()
    if bias is None:
        bias = jnp.zeros((1, qw.shape[1]), jnp.float32)
    return stamp_decode_matmul_pallas(
        x, qw, sw, zw, bias.reshape(1, -1).astype(jnp.float32),
        out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "out_dtype", "interpret"))
def stamp_quant_grouped_matmul(qx, sx, zx, counts,
                               qw_gate, sw_gate, zw_gate,
                               qw_up, sw_up, zw_up,
                               qw_down, sw_down, zw_down, *,
                               block_c: int = 128, block_f: int = 512,
                               out_dtype=jnp.float32,
                               interpret: bool | None = None):
    """Grouped MoE expert FFN over the quantized dispatch buffer (see
    `stamp_matmul.py`).

    qx/sx/zx: (b, E, C, d) int8 dispatch codes + per-token scale/shifted zp
    — each token was transformed + mixed-precision quantized ONCE per
    sequence span before dispatch; counts: (b, E) int32 occupancy
    (scalar-prefetched); qw/sw/zw triplets: stacked (E, d, f) gate/up and
    (E, f, d) down expert buffers from `prepare_linear`.  Returns the
    (b, E, C, d) expert outputs for the combine einsum.
    """
    if interpret is None:
        interpret = default_interpret()
    return stamp_quant_grouped_matmul_pallas(
        qx, sx, zx, counts,
        qw_gate, sw_gate, zw_gate, qw_up, sw_up, zw_up,
        qw_down, sw_down, zw_down,
        block_c=block_c, block_f=block_f, out_dtype=out_dtype,
        interpret=interpret)

"""Pallas TPU kernel: fused decode attention over the packed int4/int8 KV
cache — the deployment form of STaMP's mixed-precision cache.

The XLA path (see §Perf decode iters) must materialize dequantized bf16
K/V in HBM (~67 MB/layer/device at 32k) before the attention einsums.  This
kernel reads the *packed* cache (0.52 B/value average) into VMEM,
dequantizes in-register, and runs both attention matmuls in one residency:

    per-(batch, kv-head, lo-block) program:
      k_hi (64, hd) int8 + k_lo (block_s, hd/2) u8 → dequant in VMEM
      scores (rep, ·) → online-softmax (m, l, acc) accumulated across
      lo-blocks in the revisited output ref → out (rep, hd)

HBM traffic per layer ≈ packed cache + scales + q + out ≈ 19 MB/device —
the ~34× memory-term headroom quantified in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(q_ref, khi_ref, klo_ref, kshi_ref, kzhi_ref, kslo_ref, kzlo_ref,
            vhi_ref, vlo_ref, vshi_ref, vzhi_ref, vslo_ref, vzlo_ref,
            len_ref, o_ref, *, hi_len: int, block_s: int, scale: float):
    blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (rep, hd)
    hd = q.shape[-1]
    length = len_ref[0]          # this batch row's length (per-slot block)

    def dequant_hi(qref, sref, zref):
        codes = qref[0, :, 0].astype(jnp.float32)          # (hi, hd)
        s = sref[0, :, 0].astype(jnp.float32)[:, None]
        z = zref[0, :, 0].astype(jnp.float32)[:, None]
        return (codes - z) * s

    def dequant_lo(qref, sref, zref):
        packed = qref[0, :, 0]                             # (bs, hd/2)
        hi_nib = (packed >> 4).astype(jnp.float32)
        lo_nib = (packed & 0xF).astype(jnp.float32)
        vals = jnp.stack([hi_nib, lo_nib], axis=-1).reshape(
            packed.shape[0], hd)
        s = sref[0, :, 0].astype(jnp.float32)[:, None]
        z = zref[0, :, 0].astype(jnp.float32)[:, None]
        return (vals - z) * s

    k_lo = dequant_lo(klo_ref, kslo_ref, kzlo_ref)
    v_lo = dequant_lo(vlo_ref, vslo_ref, vzlo_ref)
    pos_lo = hi_len + blk * block_s + jnp.arange(block_s)
    s_lo = q @ k_lo.T                                      # (rep, bs)
    s_lo = jnp.where((pos_lo < length)[None, :], s_lo, -1e30)
    m_blk = jnp.max(s_lo, axis=-1)
    p_lo = jnp.exp(s_lo - m_blk[:, None])
    l_blk = jnp.sum(p_lo, axis=-1)
    o_blk = p_lo @ v_lo                                    # (rep, hd)

    @pl.when(blk == 0)
    def _first():
        k_hi = dequant_hi(khi_ref, kshi_ref, kzhi_ref)
        v_hi = dequant_hi(vhi_ref, vshi_ref, vzhi_ref)
        pos_hi = jnp.arange(hi_len)
        s_hi = q @ k_hi.T
        s_hi = jnp.where((pos_hi < length)[None, :], s_hi, -1e30)
        m0 = jnp.maximum(jnp.max(s_hi, axis=-1), m_blk)
        p_hi = jnp.exp(s_hi - m0[:, None])
        corr = jnp.exp(m_blk - m0)
        l0 = jnp.sum(p_hi, axis=-1) + l_blk * corr
        o0 = p_hi @ v_hi + o_blk * corr[:, None]
        o_ref[0, 0] = jnp.concatenate(
            [m0[:, None], l0[:, None], o0], axis=-1).astype(o_ref.dtype)

    @pl.when(blk > 0)
    def _rest():
        prev = o_ref[0, 0].astype(jnp.float32)
        m_prev, l_prev, o_prev = prev[:, 0], prev[:, 1], prev[:, 2:]
        m_new = jnp.maximum(m_prev, m_blk)
        c_prev = jnp.exp(m_prev - m_new)
        c_blk = jnp.exp(m_blk - m_new)
        l_new = l_prev * c_prev + l_blk * c_blk
        o_new = o_prev * c_prev[:, None] + o_blk * c_blk[:, None]
        o_ref[0, 0] = jnp.concatenate(
            [m_new[:, None], l_new[:, None], o_new], axis=-1
        ).astype(o_ref.dtype)


def cache_decode_attention(entry: dict, q: jax.Array, length: jax.Array,
                           block_s: int = 2048,
                           interpret: bool | None = None) -> jax.Array:
    """Fused attention over one layer's quantized cache.

    ``entry``: kvcache layer dict (no periods axis) — k_hi (b, hi, g, hd)
    int8, k_lo (b, S−hi, g, hd/2) uint8, *_scale/zp (b, S, g) f32;
    ``q``: (b, 1, h, hd); ``length``: (1,) int32 shared or (b,) per-slot.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    b, _, h, hd = q.shape
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    hi_len = entry["k_hi"].shape[1]
    g = entry["k_hi"].shape[2]
    rep = h // g
    s_lo = entry["k_lo"].shape[1]
    bs = min(block_s, s_lo)
    while s_lo % bs:
        bs //= 2
    bs = max(bs, 1)
    n_blocks = s_lo // bs
    scale = float(1.0 / np.sqrt(hd))
    qg = q.reshape(b, h, hd).reshape(b, g, rep, hd)

    def split(name):
        full = entry[name]
        return full[:, :hi_len], full[:, hi_len:]

    kshi, kslo = split("k_scale")
    kzhi, kzlo = split("k_zp")
    vshi, vslo = split("v_scale")
    vzhi, vzlo = split("v_zp")

    kernel = functools.partial(_kernel, hi_len=hi_len, block_s=bs,
                               scale=scale)
    hi_spec = pl.BlockSpec((1, hi_len, 1, hd), lambda i, j, k: (i, 0, j, 0))
    lo_spec = pl.BlockSpec((1, bs, 1, hd // 2), lambda i, j, k: (i, k, j, 0))
    shi_spec = pl.BlockSpec((1, hi_len, 1), lambda i, j, k: (i, 0, j))
    slo_spec = pl.BlockSpec((1, bs, 1), lambda i, j, k: (i, k, j))

    stats = pl.pallas_call(
        kernel,
        grid=(b, g, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda i, j, k: (i, j, 0, 0)),
            hi_spec, lo_spec, shi_spec, shi_spec, slo_spec, slo_spec,
            hi_spec, lo_spec, shi_spec, shi_spec, slo_spec, slo_spec,
            pl.BlockSpec((1,), lambda i, j, k: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd + 2),
                               lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, rep, hd + 2), jnp.float32),
        interpret=interpret,
    )(qg, entry["k_hi"], entry["k_lo"], kshi, kzhi, kslo, kzlo,
      entry["v_hi"], entry["v_lo"], vshi, vzhi, vslo, vzlo, length)

    l = stats[..., 1]
    o = stats[..., 2:]
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(q.dtype)

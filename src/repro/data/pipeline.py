"""Deterministic, shardable synthetic data pipeline.

No internet on this container, so corpora are synthesized with the property
the paper's method depends on: **strong local correlation along the
sequence** (Fig. 3a's Toeplitz autocorrelation).  Two generators:

* ``markov_tokens`` — an order-1 Markov chain over the vocabulary with a
  banded transition kernel (adjacent ids likely follow each other) + jump
  noise: gives a learnable LM task whose activations show the local
  correlation STaMP exploits;
* ``ar_features`` — AR(1) feature sequences for LVM-style latent grids and
  calibration sets.

The iterator is *stateful and restorable*: batch ``i`` depends only on
``(seed, i)``, so restarts resume bit-exactly from the checkpointed step,
and each data-parallel host could slice its shard by rank (host_id, hosts).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bandwidth: int = 8        # Markov band width (locality strength)
    jump_prob: float = 0.1    # probability of a non-local jump


def _batch_rng(cfg: DataConfig, step: int, host: int = 0) -> np.random.Generator:
    # calibration batches use negative step ids; SeedSequence wants uint32
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed & 0xFFFFFFFF,
                                (step + 2**31) & 0xFFFFFFFF,
                                host & 0xFFFFFFFF]))


def markov_batch(cfg: DataConfig, step: int, host: int = 0,
                 hosts: int = 1) -> dict:
    """One (tokens, labels) batch; labels are next-token shifted."""
    rng = _batch_rng(cfg, step, host)
    b = cfg.global_batch // hosts
    s = cfg.seq_len
    v = cfg.vocab_size
    jumps = rng.random((b, s)) < cfg.jump_prob
    steps = rng.integers(-cfg.bandwidth, cfg.bandwidth + 1, size=(b, s))
    jump_targets = rng.integers(0, v, size=(b, s))
    tokens = np.empty((b, s + 1), np.int32)
    tokens[:, 0] = rng.integers(0, v, size=b)
    for i in range(1, s + 1):
        walk = (tokens[:, i - 1] + steps[:, i - 1]) % v
        tokens[:, i] = np.where(jumps[:, i - 1], jump_targets[:, i - 1], walk)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def ar_features(shape: tuple, rho: float = 0.95, seed: int = 0,
                axis: int = -2) -> np.ndarray:
    """AR(1) process along ``axis`` — locally-correlated activations used by
    calibration sets and LVM latent stand-ins."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    x = np.moveaxis(x, axis, 0)
    out = np.empty_like(x)
    out[0] = x[0]
    scale = np.sqrt(1 - rho**2)
    for i in range(1, x.shape[0]):
        out[i] = rho * out[i - 1] + scale * x[i]
    return np.moveaxis(out, 0, axis)


def ar_grid_features(batch: int, hw: tuple[int, int], d: int,
                     rho: float = 0.9, seed: int = 0) -> np.ndarray:
    """2-D locally-correlated latent grid flattened to a sequence — matches
    the block-Toeplitz structure of DiT activations (Fig. 3a)."""
    h, w = hw
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, h, w, d)).astype(np.float32)
    for i in range(1, h):
        x[:, i] = rho * x[:, i - 1] + np.sqrt(1 - rho**2) * x[:, i]
    for j in range(1, w):
        x[:, :, j] = rho * x[:, :, j - 1] + np.sqrt(1 - rho**2) * x[:, :, j]
    return x.reshape(batch, h * w, d)


@dataclasses.dataclass
class DataIterator:
    """Restorable iterator: ``state`` is just the step counter."""

    cfg: DataConfig
    step: int = 0
    host: int = 0
    hosts: int = 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = markov_batch(self.cfg, self.step, self.host, self.hosts)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])


def calibration_batches(cfg: DataConfig, num_batches: int = 8,
                        host: int = 0) -> list:
    """Held-out batches (negative step ids) for the PTQ calibration pass."""
    return [markov_batch(cfg, -(i + 1), host) for i in range(num_batches)]

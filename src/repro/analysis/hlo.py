"""Post-SPMD HLO text analysis for the roofline report.

``compiled.cost_analysis()`` counts `while` (scan) bodies **once**, which
would under-report a scanned-80-layer model by 80×.  This parser walks the
optimized HLO text instead:

* splits it into computations (two-pass: ops first, then analysis);
* counts dot FLOPs (2·M·N·K from output shape × contracting dims);
* sums collective bytes per primitive with standard ring multipliers;
* sums an HBM-traffic proxy: post-fusion HLO ops are kernel boundaries, so
  their operands/outputs are the real HBM reads/writes.  Two accuracy fixes:
  (a) a fusion parameter consumed *only* by ``dynamic-slice`` ops counts the
  slice bytes, not the whole array (scanned weight stacks!), and (b)
  ``dynamic-update-slice`` (top-level or as fusion root) counts the update
  bytes — XLA updates aliased buffers in place (decode KV-cache writes);
* scales everything through the call graph: `while` bodies multiply by the
  compiler-annotated ``known_trip_count`` (exact for `lax.scan`).

All quantities are **per device** (the HLO is the post-partitioning module).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "call", "conditional", "after-all",
                   "partition-id", "replica-id", "iota", "reshape",
                   "custom-call", "copy-start", "copy-done", "domain",
                   "all-gather-done", "all-reduce-done", "send", "recv",
                   "send-done", "recv-done", "opt-barrier"}

_NO_FLOP_OPS = _SKIP_BYTES_OPS | {
    "copy", "broadcast", "transpose", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "reverse", "pad", "gather",
    "scatter", "convert", "reduce", "fusion", "dot", "rng-bit-generator",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
    "select-and-scatter", "sort", "compare", "select"}


def shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    kind: str          # base kind (no .suffix)
    out_type: str
    operands: List[str]
    attrs: str
    is_root: bool


@dataclasses.dataclass
class Comp:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    symbols: Dict[str, str] = dataclasses.field(default_factory=dict)
    params: List[str] = dataclasses.field(default_factory=list)
    root: Optional[str] = None


def _parse_operands(rest: str) -> tuple[List[str], str]:
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                ops = re.findall(r"%([\w\.\-]+)", inner)
                return ops, attrs
            depth -= 1
    return re.findall(r"%([\w\.\-]+)", rest), ""


def parse_computations(text: str) -> tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry: Optional[str] = None
    current: Optional[Comp] = None
    for line in text.splitlines():
        if current is None or (line and not line[0].isspace()
                               and "{" in line and "->" in line):
            mc = _COMP_RE.match(line)
            if mc:
                current = Comp(name=mc.group(2))
                comps[current.name] = current
                if mc.group(1):
                    entry = current.name
                continue
        if current is None:
            continue
        if line.startswith("}"):
            current = None
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, out_type, kind, rest = mo.groups()
        operands, attrs = _parse_operands(rest)
        base = kind.split(".")[0]
        op = Op(name=name, kind=base, out_type=out_type, operands=operands,
                attrs=attrs, is_root=line.lstrip().startswith("ROOT"))
        current.ops.append(op)
        current.symbols[name] = out_type
        if base == "parameter":
            # positional index lives in `parameter(N)` — fusion operands map
            # by N, not by textual appearance order
            m_idx = re.match(r"\s*(\d+)", rest)
            idx = int(m_idx.group(1)) if m_idx else len(current.params)
            while len(current.params) <= idx:
                current.params.append("")
            current.params[idx] = name
        if op.is_root:
            current.root = name
    return comps, entry


# ---------------------------------------------------------------------------
# per-computation stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0
    calls: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)


def _dot_flops(op: Op, comp: Comp) -> float:
    out_dims = _shape_dims(op.out_type) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    k = 1
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if mcd and op.operands:
        lhs_dims = _shape_dims(comp.symbols.get(op.operands[0], ""))
        if lhs_dims and mcd.group(1):
            for idx in mcd.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _fusion_hbm_bytes(op: Op, comp: Comp, comps: Dict[str, Comp]) -> float:
    """Fusion kernel HBM traffic with slice-aware parameter reads and
    in-place dynamic-update-slice writes."""
    callee = None
    for c in _CALL_RE.findall(op.attrs):
        callee = comps.get(c)
        break
    # reads — slice-aware, following pass-through chains (convert/copy/
    # bitcast) down to dynamic-slice: a fusion only materializes what its
    # root needs, so `param -> convert -> dynamic-slice` reads slice bytes.
    _PASS = {"convert", "copy", "bitcast", "reshape", "transpose"}

    def _sliced_bytes(callee: Comp, name: str, depth: int = 0):
        """Bytes actually read from `name` inside `callee`, or None if the
        full array is consumed."""
        if depth > 4:
            return None
        uses = [o for o in callee.ops if name in o.operands]
        if not uses:
            return 0.0
        total = 0.0
        for u in uses:
            if u.kind == "dynamic-slice" or u.kind == "slice":
                total += shape_bytes(u.out_type)
            elif u.kind in _PASS:
                sub = _sliced_bytes(callee, u.name, depth + 1)
                if sub is None:
                    return None
                total += sub
            else:
                return None
        return total

    # in-place aliasing: the buffer a root dynamic-update-slice writes into
    # is not re-read (XLA aliases loop-carried buffers)
    aliased_param = None
    if callee is not None and callee.root is not None:
        root_op = next((o for o in callee.ops if o.name == callee.root), None)
        if root_op is not None and root_op.kind == "dynamic-update-slice" \
                and root_op.operands:
            tgt = root_op.operands[0]
            # walk pass-through chain back to a parameter
            for _ in range(4):
                defs = next((o for o in callee.ops if o.name == tgt), None)
                if defs is None:
                    break
                if defs.kind == "parameter":
                    aliased_param = tgt
                    break
                if defs.kind in _PASS and defs.operands:
                    tgt = defs.operands[0]
                else:
                    break

    reads = 0.0
    if callee is not None and len(callee.params) == len(op.operands):
        for pname, operand in zip(callee.params, op.operands):
            if pname == aliased_param:
                continue
            full = shape_bytes(comp.symbols.get(operand, ""))
            sliced = _sliced_bytes(callee, pname)
            if sliced is not None and sliced < full:
                reads += sliced
            else:
                reads += full
    else:
        reads = sum(shape_bytes(comp.symbols.get(o, "")) for o in op.operands)
    # writes
    writes = shape_bytes(op.out_type)
    if callee is not None and callee.root is not None:
        root_op = next((o for o in callee.ops if o.name == callee.root), None)
        if root_op is not None and root_op.kind == "dynamic-update-slice" \
                and len(root_op.operands) >= 2:
            writes = shape_bytes(callee.symbols.get(root_op.operands[1], ""))
    return reads + writes


# ops a TPU backend would fuse into maximal elementwise kernels — HBM
# traffic is counted only at group boundaries (the CPU HLO used for the
# dry-run fuses far less aggressively than the TPU backend would).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "select",
    "compare", "exponential", "exponential-minus-one", "tanh", "log",
    "log-plus-one", "negate", "abs", "convert", "broadcast", "and", "or",
    "not", "xor", "power", "rsqrt", "sqrt", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "copy", "transpose",
    "reverse", "slice", "concatenate", "pad", "reduce", "map", "atan2",
    "is-finite", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "clz", "popcnt", "cosine", "sine", "logistic", "cbrt",
    "expm1", "log1p", "erf",
}


def comp_stats(comp: Comp, comps: Dict[str, Comp]) -> CompStats:
    st = CompStats()
    # --- use map for elementwise-fusion simulation -------------------------
    users: Dict[str, List[Op]] = {}
    for op in comp.ops:
        for o in op.operands:
            users.setdefault(o, []).append(op)
    is_ew = {op.name: op.kind in _ELEMENTWISE for op in comp.ops}

    for op in comp.ops:
        kind = op.kind

        for coll in _COLLECTIVES:
            if kind == coll or kind == coll + "-start":
                payload = shape_bytes(op.out_type)
                op_bytes = sum(shape_bytes(comp.symbols.get(o, ""))
                               for o in op.operands)
                if coll != "all-gather":
                    payload = max(payload, op_bytes)
                st.coll_bytes[coll] = (st.coll_bytes.get(coll, 0.0)
                                       + payload * _COLL_FACTOR[coll])
                st.coll_count[coll] = st.coll_count.get(coll, 0) + 1
                st.hbm_bytes += op_bytes + shape_bytes(op.out_type)
                break
        else:
            if kind == "dot":
                st.dot_flops += _dot_flops(op, comp)
                st.hbm_bytes += (sum(shape_bytes(comp.symbols.get(o, ""))
                                     for o in op.operands)
                                 + shape_bytes(op.out_type))
            elif kind == "fusion":
                st.hbm_bytes += _fusion_hbm_bytes(op, comp, comps)
            elif kind == "dynamic-slice":
                st.hbm_bytes += 2 * shape_bytes(op.out_type)
            elif kind == "dynamic-update-slice":
                upd = (shape_bytes(comp.symbols.get(op.operands[1], ""))
                       if len(op.operands) > 1 else 0.0)
                st.hbm_bytes += 2 * upd
            elif kind in _ELEMENTWISE:
                # fusion-group boundary accounting: write the output only if
                # some consumer is non-elementwise (or it is the root); read
                # operands only if produced by a non-elementwise op.
                use = users.get(op.name, [])
                externally_used = op.is_root or any(
                    not is_ew.get(u.name, False) for u in use) or not use
                if externally_used:
                    st.hbm_bytes += shape_bytes(op.out_type)
                for o in op.operands:
                    if not is_ew.get(o, False):
                        # produced outside the elementwise group — counted as
                        # that producer's write; re-read here is free only if
                        # it fuses, which XLA does for single-use producers.
                        if len(users.get(o, [])) > 1:
                            st.hbm_bytes += shape_bytes(
                                comp.symbols.get(o, ""))
            elif kind not in _SKIP_BYTES_OPS:
                st.hbm_bytes += (sum(shape_bytes(comp.symbols.get(o, ""))
                                     for o in op.operands)
                                 + shape_bytes(op.out_type))

        if kind not in _NO_FLOP_OPS:
            dims = _shape_dims(op.out_type)
            if dims is not None:
                n = 1
                for d in dims:
                    n *= d
                st.elem_flops += n

        if kind == "while":
            trip = 1.0
            mt = _TRIP_RE.search(op.attrs)
            if mt:
                trip = float(mt.group(1))
            for callee in _CALL_RE.findall(op.attrs):
                st.calls.append((callee, trip, False))
        elif kind in ("call", "conditional", "async-start"):
            for callee in _CALL_RE.findall(op.attrs):
                st.calls.append((callee, 1.0, False))
        elif kind == "fusion":
            for callee in _CALL_RE.findall(op.attrs):
                st.calls.append((callee, 1.0, True))
    return st


@dataclasses.dataclass
class ModuleTotals:
    dot_flops: float
    elem_flops: float
    coll_bytes: Dict[str, float]
    coll_count: Dict[str, int]
    hbm_bytes: float


def aggregate(comps: Dict[str, Comp], entry: Optional[str]) -> ModuleTotals:
    stats = {name: comp_stats(c, comps) for name, c in comps.items()}
    if entry is None:
        entry = next((n for n in comps if "main" in n), next(iter(comps)))
    memo: Dict[Tuple[str, bool], tuple] = {}

    def visit(name: str, fused: bool, depth=0):
        if depth > 64 or name not in stats:
            return (0.0, 0.0, {}, {}, 0.0)
        key = (name, fused)
        if key in memo:
            return memo[key]
        st = stats[name]
        dot, elem = st.dot_flops, st.elem_flops
        coll = {} if fused else dict(st.coll_bytes)
        cnt = {} if fused else dict(st.coll_count)
        hbm = 0.0 if fused else st.hbm_bytes
        for callee, mult, callee_fused in st.calls:
            d, e, c, cc, h = visit(callee, fused or callee_fused, depth + 1)
            dot += d * mult
            elem += e * mult
            for k, v in c.items():
                coll[k] = coll.get(k, 0.0) + v * mult
            for k, v in cc.items():
                cnt[k] = cnt.get(k, 0) + int(v * mult)
            hbm += h * mult
        memo[key] = (dot, elem, coll, cnt, hbm)
        return memo[key]

    dot, elem, coll, cnt, hbm = visit(entry, False)
    return ModuleTotals(dot, elem, coll, cnt, hbm)


def analyze_hlo_text(text: str) -> dict:
    comps, entry = parse_computations(text)
    totals = aggregate(comps, entry)
    return {
        "dot_flops_per_device": totals.dot_flops,
        "elem_flops_per_device": totals.elem_flops,
        "collective_bytes_per_device": sum(totals.coll_bytes.values()),
        "collective_bytes_by_kind": totals.coll_bytes,
        "collective_counts": totals.coll_count,
        "hbm_bytes_per_device": totals.hbm_bytes,
    }

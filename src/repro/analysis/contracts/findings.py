"""Finding records and their stable ratchet keys.

A finding's identity must survive unrelated edits: keys deliberately
contain NO line numbers — ``CODE:path:scope#ordinal``, where ``scope`` is
the enclosing function/kernel/config and ``ordinal`` numbers repeat
findings of the same (code, path, scope) in source order.  Moving a
function within a file keeps its findings' keys; adding a *new* violation
to the same scope mints a new ordinal and fails the ratchet.

Code registry (stable — tests pin these):

========  ===========================================================
``KC001``  BlockSpec index map reaches out of bounds for some grid cell
``KC002``  summed VMEM footprint exceeds the budget
``KC003``  operand shape not divisible by its block shape
``KC004``  GEMM accumulates in f16
``KC005``  int8×int8 GEMM without an int32 accumulator
``EL001``  reference-path site with no structured reason
``JX001``  f64 value in a traced entry point
``JX002``  f16-accumulated dot in a traced entry point
``JX003``  convert_element_type round trip through a narrower dtype
``JX004``  host callback inside the one-dispatch step
``RR001``  bare ``assert`` in library code
``RR002``  mutable dataclass default
``RR003``  ``interpret=True`` committed as a parameter default
``RR004``  direct ``time.time()`` outside the injectable clocks
========  ===========================================================
"""

from __future__ import annotations

import dataclasses

CODES = {
    "KC001": "index map out of bounds",
    "KC002": "VMEM footprint over budget",
    "KC003": "block shape does not divide operand shape",
    "KC004": "f16 GEMM accumulator",
    "KC005": "int8 GEMM without int32 accumulator",
    "EL001": "reference-path site without a structured reason",
    "JX001": "f64 leak in traced entry point",
    "JX002": "f16-accumulated dot in traced entry point",
    "JX003": "convert_element_type round trip through narrower dtype",
    "JX004": "host callback breaks the 1-dispatch contract",
    "RR001": "bare assert in library code",
    "RR002": "mutable dataclass default",
    "RR003": "interpret=True committed as default",
    "RR004": "time.time() outside injectable clocks",
}


@dataclasses.dataclass
class Finding:
    code: str       # one of CODES
    path: str       # repo-relative source path, or a pseudo-path like
                    # "kernels/<example-name>" for captured-spec findings
    scope: str      # enclosing function / kernel example / config name
    message: str    # human detail (shapes, grid cell, dtype chain, ...)
    key: str = ""   # CODE:path:scope#ordinal — set by assign_keys

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown finding code {self.code!r}")


def assign_keys(findings: list) -> list:
    """Assign stable ratchet keys in source/emission order (mutates and
    returns ``findings``)."""
    seen: dict = {}
    for f in findings:
        base = f"{f.code}:{f.path}:{f.scope}"
        n = seen.get(base, 0)
        seen[base] = n + 1
        f.key = f"{base}#{n}"
    return findings

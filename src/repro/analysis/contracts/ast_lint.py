"""Pass 4 — repo-rule AST lint over library (non-test) sources.

Four rules, each a bug class this repo has actually shipped or explicitly
guards against:

* ``RR001`` bare ``assert`` in library code — stripped under ``python -O``
  (the PR-2 ``BlockAllocator.free`` class of bug); validation must raise
  typed exceptions.  ``assert`` in tests/benchmarks is idiomatic and
  exempt.
* ``RR002`` mutable dataclass defaults — ``field: list = []`` shares one
  instance across every config object.
* ``RR003`` ``interpret=True`` committed as a parameter default — forces
  interpret mode on TPU; defaults must be ``None`` (resolved through
  `repro.kernels.ops.default_interpret`) or ``False``.
* ``RR004`` direct ``time.time()`` calls outside the injectable clocks —
  the serving/obs stack threads an explicit ``clock`` so tests and
  deadline logic are deterministic; a stray ``time.time()`` bypasses it
  (wall-clock benchmarking scripts are grandfathered via the baseline,
  not exempted here).
"""

from __future__ import annotations

import ast
import os

from repro.analysis.contracts.findings import Finding

_MUTABLE_CALLS = ("list", "dict", "set")


def _scopes(tree: ast.AST):
    """Attach a dotted scope name to every node (module-level = <module>)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]

    def scope_of(node) -> str:
        parts = []
        cur = getattr(node, "_parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = getattr(cur, "_parent", None)
        return ".".join(reversed(parts)) or "<module>"

    return scope_of


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else \
            getattr(target, "id", None)
        if name == "dataclass":
            return True
    return False


def _mutable_default(value) -> bool:
    if isinstance(value, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name) \
            and value.func.id in _MUTABLE_CALLS and not value.args \
            and not value.keywords:
        return True
    return False


def lint_source(source: str, relpath: str) -> list:
    tree = ast.parse(source, filename=relpath)
    scope_of = _scopes(tree)
    out: list = []

    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            out.append(Finding(
                "RR001", relpath, scope_of(node),
                f"bare assert at line {node.lineno} is stripped under "
                f"python -O; raise a typed exception"))
        elif isinstance(node, ast.ClassDef) and _is_dataclass(node):
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and stmt.value is not \
                        None and _mutable_default(stmt.value):
                    out.append(Finding(
                        "RR002", relpath, f"{scope_of(stmt)}.{node.name}"
                        if scope_of(stmt) != "<module>" else node.name,
                        f"mutable dataclass default for "
                        f"{getattr(stmt.target, 'id', '?')!r} at line "
                        f"{stmt.lineno}"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            pairs = list(zip(reversed(args.args), reversed(args.defaults)))
            pairs += [(a, d) for a, d in zip(args.kwonlyargs,
                                             args.kw_defaults)
                      if d is not None]
            for arg, default in pairs:
                if arg.arg == "interpret" and \
                        isinstance(default, ast.Constant) and \
                        default.value is True:
                    out.append(Finding(
                        "RR003", relpath, scope_of(node) + "." + node.name
                        if scope_of(node) != "<module>" else node.name,
                        f"interpret=True committed as default at line "
                        f"{node.lineno}"))
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "time" and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "time":
            out.append(Finding(
                "RR004", relpath, scope_of(node),
                f"direct time.time() at line {node.lineno}; thread the "
                f"injectable clock instead"))
    return out


def lint_tree(root: str, subdir: str = "src/repro") -> list:
    """Lint every library source under ``root/subdir`` (tests excluded by
    construction — they live under ``tests/``)."""
    out: list = []
    base = os.path.join(root, subdir)
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root)
            with open(path) as f:
                out.extend(lint_source(f.read(), rel))
    return out

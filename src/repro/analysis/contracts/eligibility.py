"""Pass 2 — fused-path eligibility auditor.

Builds the STaMP site × config matrix from
`repro.models.lm.fused_site_matrix` for every registered architecture
(``repro.configs.ARCHS``) under the paper's fused deployment setting, and
emits it as machine-readable JSON.  The check itself is a completeness
invariant: every reference-path cell must carry at least one structured
reason code (``EL001`` otherwise) — the ROADMAP's "silently fall back"
gaps become a diffable artifact instead of a latency surprise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.analysis.contracts.findings import Finding


def default_stamp():
    """The paper's headline fused deployment config (W4A4-style: dwt, 64
    hi tokens at 8 bits, rest at 4, fused Pallas execution)."""
    from repro.core.stamp import StampConfig
    return StampConfig(execution="fused")


def audit_config(name: str, stamp=None) -> dict:
    from repro.configs import get_config
    from repro.models import lm
    return lm.fused_site_matrix(get_config(name),
                                stamp if stamp is not None
                                else default_stamp())


def audit_all(config_names=None, stamp=None) -> dict:
    """{config_name: {site: cell}} for every (or the named) architectures."""
    from repro.configs import ARCHS
    names = config_names or list(ARCHS)
    return {n: audit_config(n, stamp=stamp) for n in names}


def matrix_document(matrix: dict, stamp=None) -> dict:
    """The committed/uploaded JSON shape (schema-checked by
    ``benchmarks/check_schema.py --eligibility``)."""
    st = stamp if stamp is not None else default_stamp()
    return {
        "version": 1,
        "stamp": dataclasses.asdict(st),
        "configs": matrix,
    }


def check_eligibility(config_names=None, stamp=None,
                      matrix_out: Optional[dict] = None) -> list:
    """Run the audit; ``EL001`` for any unexplained reference cell.  Pass a
    dict as ``matrix_out`` to receive the full matrix by side effect."""
    matrix = audit_all(config_names, stamp=stamp)
    if matrix_out is not None:
        matrix_out.update(matrix)
    out: list = []
    for cfg_name, sites in matrix.items():
        for site, cell in sites.items():
            if cell["status"] == "reference" and not cell["reasons"]:
                out.append(Finding(
                    "EL001", f"configs/{cfg_name}", site,
                    f"site {site!r} runs the reference path with no "
                    f"structured reason"))
            if cell["status"] == "fused" and cell["reasons"]:
                out.append(Finding(
                    "EL001", f"configs/{cfg_name}", site,
                    f"site {site!r} claims fused but carries reasons "
                    f"{cell['reasons']}"))
    return out

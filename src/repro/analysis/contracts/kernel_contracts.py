"""Pass 1 — Pallas kernel contract checker.

Consumes the capture registry (`repro.kernels.specs`): for every registered
kernel example this pass

* **proves in-bounds access** (``KC001``): each BlockSpec index map is
  evaluated at every grid cell with the example's *concrete*
  scalar-prefetch tables (block tables, lengths), and the selected block
  ``idx·block … idx·block+block`` must sit inside the operand.  This is
  exactly the property the null-page and inactive-span clamp idioms in
  `paged_attention` exist to uphold — a table entry past the pool, or a
  clamp off by one, fails here without running the kernel;
* **checks divisibility** (``KC003``): every blocked dimension must tile
  its operand exactly (Pallas pads reads but a partial tail block means
  the kernel math sees garbage rows);
* **sums the VMEM footprint** (``KC002``): one block per operand and
  output (×2 for Mosaic's double buffering) plus every scratch allocation
  must fit the budget (default 64 MiB);
* **checks accumulator dtypes** (``KC004``/``KC005``): the example is
  re-traced with ``jax.make_jaxpr`` (tracing only — no kernel executes on
  device) and every ``dot_general`` in the program, including the kernel
  jaxprs carried in ``pallas_call`` params, must not accumulate in f16,
  and int8×int8 GEMMs must accumulate in int32.
"""

from __future__ import annotations

import functools
import itertools

import jax
import numpy as np

from repro.analysis.contracts.findings import Finding

DEFAULT_VMEM_BUDGET = 64 * 2**20      # bytes; v5e carries 128 MiB/core
_MAX_GRID_CELLS = 200_000             # exhaustive-enumeration backstop


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def _block_bytes(buf) -> int:
    shape = buf.block_shape if buf.block_shape is not None else buf.shape
    n = 1
    for d in shape:
        n *= int(d)
    return n * _itemsize(buf.dtype)


def _check_capture(cap, vmem_budget: int, out: list) -> None:
    pseudo = f"kernels/{cap.name}"
    buffers = [("in", i, b) for i, b in enumerate(cap.inputs)] + \
              [("out", i, b) for i, b in enumerate(cap.outputs)]

    # -- divisibility ----------------------------------------------------
    for role, i, buf in buffers:
        if buf.block_shape is None:
            continue
        if len(buf.block_shape) != len(buf.shape):
            out.append(Finding(
                "KC003", pseudo, cap.name,
                f"{role}[{i}]: block rank {len(buf.block_shape)} != operand "
                f"rank {len(buf.shape)}"))
            continue
        for d, (blk, dim) in enumerate(zip(buf.block_shape, buf.shape)):
            if blk is None:
                continue
            if dim % blk:
                out.append(Finding(
                    "KC003", pseudo, cap.name,
                    f"{role}[{i}] dim {d}: shape {dim} % block {blk} != 0"))

    # -- VMEM footprint --------------------------------------------------
    resident = sum(_block_bytes(b) for _, _, b in buffers) * 2  # dbl-buffer
    resident += sum(int(np.prod(shape)) * _itemsize(dt)
                    for shape, dt in cap.scratch)
    if resident > vmem_budget:
        out.append(Finding(
            "KC002", pseudo, cap.name,
            f"VMEM footprint {resident} B exceeds budget {vmem_budget} B "
            f"(blocks ×2 + scratch)"))

    # -- in-bounds index maps over the full grid -------------------------
    total = 1
    for g in cap.grid:
        total *= int(g)
    if total > _MAX_GRID_CELLS:
        out.append(Finding(
            "KC001", pseudo, cap.name,
            f"grid {cap.grid} has {total} cells — example too large to "
            f"enumerate; shrink the registry example"))
        return
    prefetch = cap.prefetch
    for ids in itertools.product(*(range(int(g)) for g in cap.grid)):
        for role, i, buf in buffers:
            if buf.index_map is None:
                continue
            idx = buf.index_map(*ids, *prefetch)
            if not isinstance(idx, tuple):
                idx = (idx,)
            try:
                idx = tuple(int(v) for v in idx)
            except TypeError:
                out.append(Finding(
                    "KC001", pseudo, cap.name,
                    f"{role}[{i}] index map returned non-integer {idx!r} "
                    f"at grid cell {ids}"))
                continue
            for d, (bi, blk, dim) in enumerate(
                    zip(idx, buf.block_shape, buf.shape)):
                if blk is None:
                    blk = 1
                if bi < 0 or (bi + 1) * blk > dim:
                    out.append(Finding(
                        "KC001", pseudo, cap.name,
                        f"{role}[{i}] dim {d}: block index {bi} × block "
                        f"{blk} reaches past shape {dim} at grid cell "
                        f"{ids}"))
                    return  # one cell is proof enough for this capture


def _iter_subjaxprs(params: dict):
    from jax.core import Jaxpr, ClosedJaxpr
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if isinstance(item, ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, Jaxpr):
                yield item


def _walk_dots(jaxpr, visit, seen=None):
    seen = seen if seen is not None else set()
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        visit(eqn)
        for sub in _iter_subjaxprs(eqn.params):
            _walk_dots(sub, visit, seen)


def check_accumulators(fn, args, kwargs, name: str, out: list) -> None:
    """``KC004``/``KC005`` over a traced example (kernel jaxprs included)."""
    pseudo = f"kernels/{name}"
    # trace with python scalars (block sizes &c.) kept static
    dyn_idx = [i for i, a in enumerate(args)
               if not isinstance(a, (bool, int, float, str))]

    def wrapper(*dyn):
        full = list(args)
        for i, v in zip(dyn_idx, dyn):
            full[i] = v
        return fn(*full, **kwargs)

    try:
        closed = jax.make_jaxpr(wrapper)(*[args[i] for i in dyn_idx])
    except Exception as e:  # pragma: no cover - registry example broke
        out.append(Finding("KC005", pseudo, name,
                           f"could not trace example: {e!r}"))
        return

    def visit(eqn):
        if eqn.primitive.name != "dot_general":
            return
        in_dt = [v.aval.dtype for v in eqn.invars]
        out_dt = eqn.outvars[0].aval.dtype
        if out_dt == np.float16:
            out.append(Finding(
                "KC004", pseudo, name,
                f"dot_general accumulates in f16 (inputs "
                f"{[str(d) for d in in_dt]})"))
        if all(d == np.int8 for d in in_dt) and out_dt != np.int32:
            out.append(Finding(
                "KC005", pseudo, name,
                f"int8×int8 dot_general accumulates in {out_dt}, not int32"))

    _walk_dots(closed.jaxpr, visit)


def check_kernels(vmem_budget: int = DEFAULT_VMEM_BUDGET,
                  names=None) -> list:
    """Run the kernel contract pass over the capture registry."""
    from repro.kernels import specs as KS
    out: list = []
    for name in (names or KS.KERNEL_EXAMPLES):
        ex = KS.kernel_spec(name)
        for cap in ex.captures:
            _check_capture(cap, vmem_budget, out)
        check_accumulators(ex.fn, ex.args, ex.kwargs, name, out)
    return out


def check_capture(cap, vmem_budget: int = DEFAULT_VMEM_BUDGET) -> list:
    """Check a single externally-built capture (test fixtures use this)."""
    out: list = []
    _check_capture(cap, vmem_budget, out)
    return out

"""CLI: ``python -m repro.analysis.contracts``.

Runs the four static-analysis passes and ratchets the findings against the
committed baseline:

    PYTHONPATH=src python -m repro.analysis.contracts \\
        [--passes kernels,eligibility,jaxpr,ast] [--configs a,b,...] \\
        [--vmem-budget BYTES] [--baseline STATIC_ANALYSIS.json] \\
        [--eligibility-out eligibility_matrix.json] [--update-baseline]

Exit code 0 when every finding is grandfathered (or none exist); 1 on any
non-allowlisted finding.  ``--update-baseline`` rewrites the allowlist to
exactly the current findings (dropping stale keys) and exits 0.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.contracts import ast_lint, eligibility, jaxpr_lint, \
    kernel_contracts, ratchet
from repro.analysis.contracts.findings import CODES

PASSES = ("kernels", "eligibility", "jaxpr", "ast")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.contracts")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma list from {PASSES}")
    ap.add_argument("--configs", default=None,
                    help="comma list of config names (default: all)")
    ap.add_argument("--vmem-budget", type=int,
                    default=kernel_contracts.DEFAULT_VMEM_BUDGET,
                    metavar="BYTES")
    ap.add_argument("--baseline", default="STATIC_ANALYSIS.json",
                    metavar="PATH")
    ap.add_argument("--eligibility-out", default=None, metavar="PATH",
                    help="write the site × config matrix JSON here")
    ap.add_argument("--root", default=".", metavar="DIR",
                    help="repo root for the AST pass")
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = set(passes) - set(PASSES)
    if unknown:
        ap.error(f"unknown passes: {sorted(unknown)}")
    config_names = [c.strip() for c in args.configs.split(",")] \
        if args.configs else None

    findings: list = []
    if "kernels" in passes:
        ks = kernel_contracts.check_kernels(vmem_budget=args.vmem_budget)
        print(f"[contracts] kernels: {len(ks)} finding(s)")
        findings += ks
    if "eligibility" in passes:
        matrix: dict = {}
        el = eligibility.check_eligibility(config_names, matrix_out=matrix)
        n_ref = sum(1 for sites in matrix.values()
                    for c in sites.values() if c["status"] == "reference")
        n_cells = sum(len(s) for s in matrix.values())
        print(f"[contracts] eligibility: {len(matrix)} configs, "
              f"{n_cells} site cells ({n_ref} reference, all explained: "
              f"{not el}), {len(el)} finding(s)")
        if args.eligibility_out:
            with open(args.eligibility_out, "w") as f:
                json.dump(eligibility.matrix_document(matrix), f, indent=2,
                          sort_keys=True)
                f.write("\n")
            print(f"[contracts] eligibility matrix -> "
                  f"{args.eligibility_out}")
        findings += el
    if "jaxpr" in passes:
        jx = jaxpr_lint.check_entry_points()
        print(f"[contracts] jaxpr: {len(jx)} finding(s)")
        findings += jx
    if "ast" in passes:
        rr = ast_lint.lint_tree(args.root)
        print(f"[contracts] ast: {len(rr)} finding(s)")
        findings += rr

    if args.update_baseline:
        ratchet.write_baseline(args.baseline, findings, args.vmem_budget)
        print(f"[contracts] baseline rewritten: {args.baseline} "
              f"({len(findings)} grandfathered key(s))")
        return 0

    baseline = ratchet.load_baseline(args.baseline)
    new, grandfathered, stale = ratchet.ratchet(findings, baseline)
    for f in grandfathered:
        print(f"[contracts] grandfathered {f.key}: {f.message}")
    for key in stale:
        print(f"[contracts] stale allowlist entry (fixed? run "
              f"--update-baseline): {key}")
    for f in new:
        print(f"[contracts] NEW {f.key} [{CODES[f.code]}] {f.message}",
              file=sys.stderr)
    verdict = "FAIL" if new else "OK"
    print(f"[contracts] {verdict}: {len(new)} new, "
          f"{len(grandfathered)} grandfathered, {len(stale)} stale")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())

"""Pass 3 — jaxpr dispatch & dtype lint.

Traces the serving entry points — dense ``prefill`` (reference and fused
execution), contiguous ``decode_step`` and the paged ``paged_decode_step``
(the graph the unified step's all-decode steady state delegates to) — on a
reduced representative config with ``jax.make_jaxpr`` and walks every
equation (sub-jaxprs included) for dtype-discipline violations:

* ``JX001`` — any f64 value: the serving stack is bf16/f32 + integer
  codes; a float64 means an accidental Python-float promotion doubling
  HBM traffic;
* ``JX002`` — a ``dot_general`` producing f16: GEMMs accumulate in f32 or
  int32, never half precision (the KC004 rule, applied to the whole
  program rather than one kernel);
* ``JX003`` — ``convert_element_type`` round trips ``A → B → A`` with a
  *narrower* B: the value silently lost precision in transit — exactly
  the class of bug ResQ-style bf16-residual-over-int4 schemes introduce
  at each new dtype boundary;
* ``JX004`` — host callback primitives inside the step program: one
  device dispatch per engine step is a load-bearing serving contract
  (PR 4), and a ``pure_callback``/``io_callback`` breaks it silently.

Tracing executes no device code; the pass costs a few seconds of Python.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.contracts.findings import Finding

_CALLBACK_PRIMS = ("pure_callback", "io_callback", "python_callback",
                   "outside_call", "host_callback_call", "debug_callback")

REPRESENTATIVE_CONFIG = "llama3_8b"


def _iter_subjaxprs(params: dict):
    from jax.core import Jaxpr, ClosedJaxpr
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if isinstance(item, ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, Jaxpr):
                yield item


def lint_jaxpr(closed, entry_name: str, path: str = "models/lm.py") -> list:
    """Walk one traced entry point; returns its findings."""
    out: list = []
    reported: set = set()

    def report(code, msg):
        if (code, msg) in reported:      # one finding per distinct defect
            return
        reported.add((code, msg))
        out.append(Finding(code, path, entry_name, msg))

    def walk(jaxpr, conv_src, seen):
        # conv_src: var -> source dtype of the convert that produced it
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "dtype", None) is not \
                        None and aval.dtype == np.float64:
                    report("JX001", f"{prim} produces f64")
            if prim == "dot_general":
                if eqn.outvars[0].aval.dtype == np.float16:
                    report("JX002",
                           f"dot_general accumulates in f16 (inputs "
                           f"{[str(v.aval.dtype) for v in eqn.invars]})")
            if prim == "convert_element_type":
                src_v = eqn.invars[0]
                src_dt = src_v.aval.dtype
                dst_dt = eqn.outvars[0].aval.dtype
                origin = conv_src.get(id(src_v))
                if origin is not None:
                    import jax.numpy as jnp
                    a, b = origin, src_dt
                    # jnp.issubdtype, not np: bfloat16 is an ml_dtypes
                    # extension outside numpy's floating hierarchy
                    if a == dst_dt and jnp.issubdtype(a, jnp.floating) and \
                            jnp.issubdtype(b, jnp.floating) and \
                            np.dtype(b).itemsize < np.dtype(a).itemsize:
                        report("JX003",
                               f"convert round trip {a} -> {b} -> {dst_dt} "
                               f"loses precision in transit")
                conv_src[id(eqn.outvars[0])] = src_dt
            if any(prim == c or prim.endswith(c) for c in _CALLBACK_PRIMS):
                report("JX004", f"host callback primitive {prim!r}")
            for sub in _iter_subjaxprs(eqn.params):
                walk(sub, {}, seen)

    walk(closed.jaxpr, {}, set())
    return out


def _traced_entry_points(config_name: str = REPRESENTATIVE_CONFIG):
    """Yield (entry_name, closed_jaxpr) for the representative traces."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.stamp import StampConfig
    from repro.models import lm
    from repro.serving import kvcache as KV
    from repro.serving import paged_kvcache as PKV

    cfg = get_reduced(config_name)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16)
    tokens = jnp.zeros((1, 16), jnp.int32)

    for execution in ("reference", "fused"):
        stamp = StampConfig(execution=execution, num_hi_tokens=4)
        serve = lm.ServeConfig(stamp=stamp, kv=KV.KVCacheConfig())
        p = lm.prepare_fused_weights(params, stamp) \
            if execution == "fused" else params
        yield (f"prefill[{config_name}:{execution}]",
               jax.make_jaxpr(lambda pp, t, s=serve: lm.prefill(
                   pp, {"tokens": t}, cfg, s))(p, tokens))
        if execution == "fused":
            serve_dec = lm.ServeConfig(
                stamp=stamp,
                kv=KV.KVCacheConfig(quantized=True, num_hi=16),
                cache_capacity=48, fused_decode_matmul=True)
            toks_dec = jnp.zeros((1, 32), jnp.int32)
            _, cache = lm.prefill(p, {"tokens": toks_dec}, cfg, serve_dec)
            yield (f"decode_step[{config_name}:{execution}]",
                   jax.make_jaxpr(lambda pp, c, t, pos, s=serve_dec:
                                  lm.decode_step(pp, c, t, pos, cfg, s))
                   (p, cache, jnp.zeros((1,), jnp.int32),
                    jnp.int32(32)))

    # paged decode step — the unified step's all-decode steady state
    stamp = StampConfig(execution="fused", num_hi_tokens=4)
    pcfg = PKV.PagedCacheConfig(
        block_size=8, num_lo_blocks=8, num_hi_blocks=4,
        max_blocks_per_seq=4,
        quant=KV.KVCacheConfig(quantized=True, num_hi=8))
    serve = lm.ServeConfig(stamp=stamp, kv=pcfg.quant, paged=pcfg,
                           fused_decode_matmul=True)
    p = lm.prepare_fused_weights(params, stamp)
    pools = lm.init_paged_cache(cfg, pcfg)
    s_slots = 2
    yield (f"paged_decode_step[{config_name}:fused]",
           jax.make_jaxpr(lambda pp, pls, t, pos, ht, lt, pg, off, ih:
                          lm.paged_decode_step(pp, pls, t, pos, ht, lt,
                                               pg, off, ih, cfg, serve))
           (p, pools,
            jnp.zeros((s_slots,), jnp.int32),
            jnp.array([9, 12], jnp.int32),
            jnp.zeros((s_slots, pcfg.hi_blocks_per_seq), jnp.int32),
            jnp.zeros((s_slots, pcfg.max_blocks_per_seq), jnp.int32),
            jnp.zeros((s_slots,), jnp.int32),
            jnp.zeros((s_slots,), jnp.int32),
            jnp.zeros((s_slots,), bool)))


def check_entry_points(config_name: str = REPRESENTATIVE_CONFIG) -> list:
    out: list = []
    for entry_name, closed in _traced_entry_points(config_name):
        out.extend(lint_jaxpr(closed, entry_name))
    return out

"""Static program-contract checker: four passes, one ratcheted gate.

The repo's hardest-won invariants — in-bounds Pallas tiling inside the VMEM
budget, int8×int8→int32 accumulation, no silent fused→reference fallbacks,
no ``-O``-stripped validation — are proved at trace/AST time here, before
any device step runs:

* ``kernel_contracts`` — evaluates every registered kernel's BlockSpec
  index maps over the full grid against the operand shapes (including the
  null-page / inactive-span clamp idioms), sums per-buffer VMEM footprints
  against a configurable budget, and checks grid/block divisibility and
  GEMM accumulator-dtype rules.  Finding codes ``KC``.
* ``eligibility`` — the fused-path audit: every STaMP site × config cell is
  ``fused`` or ``reference(reason)`` with structured reason codes (from
  `repro.core.stamp.fused_ineligibility` + the site-structural reasons in
  `repro.models.lm.fused_site_matrix`).  Finding codes ``EL``.
* ``jaxpr_lint`` — traces the prefill/decode entry points per representative
  config and flags f64 leaks, f16-accumulated GEMMs, information-losing
  ``convert_element_type`` round trips, and host callbacks that would break
  the 1-dispatch contract.  Finding codes ``JX``.
* ``ast_lint`` — repo-rule lint over library (non-test) sources: bare
  ``assert``, mutable dataclass defaults, committed ``interpret=True``
  defaults, direct ``time.time()`` outside the injectable clocks.  Finding
  codes ``RR``.

Run ``python -m repro.analysis.contracts`` (see ``__main__``); findings
ratchet against the committed ``STATIC_ANALYSIS.json`` — grandfathered
keys pass, anything new fails CI.
"""

from repro.analysis.contracts.findings import Finding, assign_keys  # noqa: F401

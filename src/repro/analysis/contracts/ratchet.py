"""Ratchet semantics against the committed ``STATIC_ANALYSIS.json``.

Day-one findings are *grandfathered*: their stable keys live in the
baseline's allowlist and keep passing.  Any finding whose key is not
allowlisted fails the gate — the count only ratchets down.  Fixing a
grandfathered finding leaves a stale allowlist entry, which is reported
(and dropped by ``--update-baseline``) so the baseline tracks reality.
"""

from __future__ import annotations

import json
from typing import Optional

from repro.analysis.contracts.findings import assign_keys

BASELINE_VERSION = 1


def empty_baseline(vmem_budget: int) -> dict:
    return {"version": BASELINE_VERSION,
            "vmem_budget_bytes": vmem_budget,
            "allowlist": []}


def load_baseline(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return None
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{doc.get('version')!r}")
    return doc


def ratchet(findings: list, baseline: Optional[dict]) -> tuple:
    """Returns ``(new_findings, grandfathered, stale_keys)``; findings get
    their stable keys assigned here."""
    assign_keys(findings)
    allow = set(baseline.get("allowlist", ())) if baseline else set()
    new = [f for f in findings if f.key not in allow]
    grandfathered = [f for f in findings if f.key in allow]
    stale = sorted(allow - {f.key for f in findings})
    return new, grandfathered, stale


def write_baseline(path: str, findings: list, vmem_budget: int) -> dict:
    assign_keys(findings)
    doc = empty_baseline(vmem_budget)
    doc["allowlist"] = sorted(f.key for f in findings)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc

"""CLI: where do the roofline bytes/flops of a dry-run cell come from?

    PYTHONPATH=src python -m repro.analysis.inspect_hlo \
        experiments/dryrun/qwen2-72b_decode_32k_singlepod.hlo.zst [--ops N]
"""

from __future__ import annotations

import argparse
import collections
import pathlib

import zstandard

from repro.analysis import hlo as H


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--ops", type=int, default=12)
    ap.add_argument("--comp", default="",
                    help="show top ops of this computation")
    args = ap.parse_args()

    raw = pathlib.Path(args.path).read_bytes()
    text = (zstandard.ZstdDecompressor().decompress(raw).decode()
            if args.path.endswith(".zst") else raw.decode())
    comps, entry = H.parse_computations(text)
    stats = {n: H.comp_stats(c, comps) for n, c in comps.items()}

    mult = collections.defaultdict(float)

    def walk(name, m, fused):
        if name not in comps:
            return
        if not fused:
            mult[name] += m
        for callee, k, cf in stats[name].calls:
            walk(callee, m * k, fused or cf)

    walk(entry, 1.0, False)

    rows = sorted(((stats[n].hbm_bytes * m, stats[n].dot_flops * m,
                    sum(stats[n].coll_bytes.values()) * m, n, m)
                   for n, m in mult.items()), reverse=True)
    print(f"{'GB(hbm)':>10s} {'GF(dot)':>10s} {'GB(coll)':>10s} "
          f"{'mult':>6s}  computation")
    for b, f, c, n, m in rows[:args.ops]:
        print(f"{b/1e9:10.2f} {f/1e9:10.2f} {c/1e9:10.2f} {m:6.0f}  {n}")

    target = args.comp or rows[0][3]
    c = comps[target]
    users: dict = {}
    for op in c.ops:
        for o in op.operands:
            users.setdefault(o, []).append(op)
    is_ew = {op.name: op.kind in H._ELEMENTWISE for op in c.ops}

    def opbytes(op):
        k = op.kind
        if k == "fusion":
            return H._fusion_hbm_bytes(op, c, comps)
        if k in H._SKIP_BYTES_OPS:
            return 0
        if k == "dynamic-slice":
            return 2 * H.shape_bytes(op.out_type)
        if k == "dynamic-update-slice":
            return (2 * H.shape_bytes(c.symbols.get(op.operands[1], ""))
                    if len(op.operands) > 1 else 0)
        if k in H._ELEMENTWISE:
            b = 0.0
            use = users.get(op.name, [])
            if op.is_root or not use or any(not is_ew.get(u.name, False)
                                            for u in use):
                b += H.shape_bytes(op.out_type)
            for o in op.operands:
                if not is_ew.get(o, False) and len(users.get(o, [])) > 1:
                    b += H.shape_bytes(c.symbols.get(o, ""))
            return b
        return (sum(H.shape_bytes(c.symbols.get(o, "")) for o in op.operands)
                + H.shape_bytes(op.out_type))

    print(f"\ntop ops in {target} (mult={mult.get(target, 0):.0f}):")
    sizes = sorted(((opbytes(op), op.kind, op.name, op.out_type[:70])
                    for op in c.ops), reverse=True)
    for s, k, n, t in sizes[:args.ops]:
        print(f"  {s/1e9:9.3f} GB {k:24s} {n[:42]:42s} {t}")


if __name__ == "__main__":
    main()

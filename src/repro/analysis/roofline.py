"""Roofline terms for TPU v5e from the dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

All inputs from :mod:`repro.analysis.hlo` are *per device*, so the per-chip
division is already done; the terms below are seconds-per-step on the
slowest (uniform) device.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE), ×3 for training (fwd+bwd), ×1 for prefill, with D = tokens processed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig, ShapeConfig

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (≈ effective per-chip)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs × chips)
    step_time_s: float           # max of the three terms
    roofline_fraction: float     # compute term / step time (→1 = compute-bound)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D convention (N = active params, D = tokens)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def compute_roofline(
    hlo_stats: dict,
    cfg: ModelConfig,
    shape: ShapeConfig,
    chips: int,
) -> Roofline:
    flops_dev = (hlo_stats["dot_flops_per_device"]
                 + hlo_stats.get("elem_flops_per_device", 0.0))
    bytes_dev = hlo_stats["hbm_bytes_per_device"]
    coll_dev = hlo_stats["collective_bytes_per_device"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_hlo = hlo_stats["dot_flops_per_device"] * chips
    useful = mf / total_hlo if total_hlo else 0.0
    step = max(terms.values())
    frac = compute_s / step if step else 0.0
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf,
        hlo_flops_per_device=flops_dev, useful_ratio=useful,
        step_time_s=step, roofline_fraction=frac)


def summarize(r: Roofline) -> dict:
    return {
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "bottleneck": r.bottleneck,
        "model_flops": r.model_flops,
        "useful_flops_ratio": r.useful_ratio,
        "step_time_s": r.step_time_s,
        "roofline_fraction": r.roofline_fraction,
    }

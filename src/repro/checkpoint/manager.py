"""Fault-tolerant checkpointing: atomic, async, elastic.

* **Atomic** — writes go to ``step_XXXX.tmp/`` and are renamed into place
  only after every array + the msgpack index land on disk; a crash mid-write
  never corrupts the latest checkpoint.
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with the next steps.
* **Elastic** — arrays are stored *unsharded* (per-leaf ``.npy``); restore
  re-shards onto whatever mesh the restarted job brings up, so the job can
  resume on a different topology (scale up/down) — re-sharding is a single
  device_put with the new NamedSharding.
* **Integrity** — every leaf records a CRC32; ``restore`` verifies before
  handing parameters back, and falls back to the previous step on mismatch
  (torn writes from a dying host).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_STEP_RE = re.compile(r"step_(\d+)$")


def _flatten_with_names(tree: Pytree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


@dataclasses.dataclass
class CheckpointManager:
    directory: pathlib.Path
    keep: int = 3

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Pytree, extra: Optional[dict] = None):
        """Synchronous atomic save."""
        self._write(step, jax.tree.map(np.asarray, tree), extra or {})

    def save_async(self, step: int, tree: Pytree,
                   extra: Optional[dict] = None):
        """Snapshot now, write in the background."""
        snapshot = jax.tree.map(np.asarray, tree)   # host copy
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, snapshot, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, snapshot: Pytree, extra: dict):
        final = self.directory / f"step_{step:08d}"
        tmp = self.directory / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = {"step": step, "extra": extra, "leaves": {}}
        for name, leaf in _flatten_with_names(snapshot):
            arr = np.asarray(leaf)
            fname = name.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr, allow_pickle=False)
            index["leaves"][name] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        (tmp / "index.json").write_text(json.dumps(index))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)        # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.directory.iterdir():
            m = _STEP_RE.search(p.name)
            if m and p.is_dir() and (p / "index.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None,
                verify: bool = True) -> tuple[Pytree, dict]:
        """Restore into the structure of ``template``; re-shard with
        ``shardings`` if given (elastic restore).  Falls back one step on
        integrity failure."""
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                return self._restore_step(template, s, shardings, verify)
            except Exception as e:      # torn checkpoint → try previous
                last_err = e
                continue
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.directory}: {last_err}")

    def _restore_step(self, template, step, shardings, verify):
        d = self.directory / f"step_{step:08d}"
        index = json.loads((d / "index.json").read_text())
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        # None means "host array" — keep it as a leaf or the zip misaligns
        sh_flat = (jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)[0]
                   if shardings is not None else [None] * len(flat))
        assert len(sh_flat) == len(flat), (len(sh_flat), len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, sh_flat):
            parts = []
            for k in path:
                parts.append(str(getattr(k, "key",
                                         getattr(k, "idx", k))))
            name = "/".join(parts)
            meta = index["leaves"][name]
            arr = np.load(d / meta["file"], allow_pickle=False)
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"crc mismatch for {name} at step {step}")
            if sh is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, index["extra"]

"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable stand-ins
for the model inputs (no device allocation); companion helpers build the
matching NamedShardings from a :class:`ShardingPolicy`.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig, ShapeConfig
from repro.serving.kvcache import KVCacheConfig
from repro.core.stamp import StampConfig
from repro.sharding import ShardingPolicy
from repro.optim import AdamWConfig, adamw_init

Pytree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Stand-ins for the data inputs of one (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((b,), jnp.int32),
                "pos": _sds((), jnp.int32)}
    batch: dict = {}
    if cfg.frontend == "patch":
        s_txt = s - cfg.num_patches
        batch["tokens"] = _sds((b, s_txt), jnp.int32)
        batch["patches"] = _sds((b, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "frames" or cfg.encoder_layers:
        batch["frames"] = _sds((b, max(s // cfg.frame_ratio, 1), cfg.d_model),
                               jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def _data_size(policy: ShardingPolicy) -> int:
    n = 1
    for ax in policy.batch_axes:
        n *= policy.mesh.shape[ax]
    return n


def batch_shardings(batch: dict, policy: ShardingPolicy,
                    global_batch: Optional[int] = None) -> dict:
    ba = policy.batch_axes
    if global_batch is not None and global_batch < _data_size(policy):
        ba = None   # tiny batch (long-context decode): replicate it
    out = {}
    for k, v in batch.items():
        if v.ndim == 0:
            out[k] = policy.named(P())
        elif v.ndim == 1:
            out[k] = policy.named(P(ba))
        elif v.ndim == 2:
            out[k] = policy.named(P(ba, None))
        else:
            out[k] = policy.named(P(ba, None, None))
    return out


def param_struct(cfg: ModelConfig, dtype=jnp.float32) -> Pytree:
    return jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, dtype))


def serve_param_struct(cfg: ModelConfig, weight_bits: Optional[int] = 4
                       ) -> Pytree:
    def build():
        p = lm.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
        if weight_bits:
            p = lm.quantize_weights_for_serving(p, weight_bits)
        return p
    return jax.eval_shape(build)


def opt_struct(params: Pytree, opt_cfg: AdamWConfig) -> Pytree:
    return jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), params)


def opt_shardings(opt_struct_tree: Pytree, params_sh: Pytree,
                  policy: ShardingPolicy) -> Pytree:
    return {
        "step": policy.named(P()),
        "m": params_sh,
        "v": params_sh,
    }


def cache_struct(cfg: ModelConfig, shape: ShapeConfig,
                 serve: lm.ServeConfig) -> Pytree:
    return jax.eval_shape(
        functools.partial(lm.init_cache, cfg, shape.global_batch,
                          shape.seq_len, serve))


_SEQ_KEYS = ("k_hi", "v_hi", "k_lo", "v_lo", "k", "v", "xk", "xv")
_SCALE_KEYS = ("k_scale", "k_zp", "v_scale", "v_zp")


def cache_shardings(cache: Pytree, policy: ShardingPolicy,
                    global_batch: Optional[int] = None) -> Pytree:
    ba = policy.batch_axes
    seq_pref = ("model",)
    if global_batch is not None and global_batch < _data_size(policy):
        # long-context decode (batch=1): context-parallel over ALL axes —
        # the cache sequence is the only parallel dimension left.
        seq_pref = tuple(ba) + ("model",)
        ba = None

    def axes_size(axes) -> int:
        n = 1
        for ax in axes:
            n *= policy.mesh.shape[ax]
        return n

    def fit_seq(dim: int):
        """Largest seq sharding that divides `dim` (the 64-token hi region
        of the mixed-precision cache is tiny — replicate if needed)."""
        if dim % axes_size(seq_pref) == 0:
            return seq_pref
        if dim % policy.mesh.shape["model"] == 0:
            return "model"
        return None

    def spec_for(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if name in _SEQ_KEYS:           # (..., b, s, kv, hd)
            base = [ba, fit_seq(leaf.shape[-3]), None, None]
        elif name in _SCALE_KEYS:       # (..., b, s, kv)
            base = [ba, fit_seq(leaf.shape[-2]), None]
        elif name == "state":           # (..., b, h, p, n)
            base = [ba, "model", None, None]
        elif name == "conv":            # (..., b, w, c)
            base = [ba, None, "model"]
        else:
            base = [None] * nd
        lead = nd - len(base)
        return policy.named(P(*([None] * lead), *base))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def make_serve_config(cfg: ModelConfig, quantize_acts: bool = True,
                      weight_bits: Optional[int] = 4) -> lm.ServeConfig:
    stamp = None
    if quantize_acts:
        stamp = StampConfig(seq_transform="dwt", levels=None,  # auto
                            num_hi_tokens=64, skip_first_token=True)
    return lm.ServeConfig(stamp=stamp, kv=KVCacheConfig(quantized=True),
                          weight_bits=weight_bits)

"""Production training driver: sharded train loop with fault tolerance.

Features exercised by the integration tests and the quickstart example:

* mesh over local devices (data × model), pjit'd train step with the same
  sharding rules as the 512-chip dry run;
* WSD or cosine schedule (per-arch: MiniCPM trains with WSD);
* checkpoint/restart: atomic async checkpoints every ``--ckpt-every`` steps,
  bit-exact resume (data iterator state included), `--fail-at-step` injects
  a hard crash to exercise the restart path;
* elastic restore: a restart may use a different mesh shape — parameters are
  re-sharded at load;
* straggler watchdog: per-step wall times tracked, steps slower than
  μ + 4σ are logged (on a real fleet this feeds the replacement policy);
* optional int8 gradient compression with error feedback across the
  data-parallel axis (`--compress-grads`).

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
        --steps 50 --global-batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, DataIterator
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         make_schedule)
from repro.optim.compression import error_feedback_update, init_error_state
from repro.sharding import ShardingPolicy


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq: int = 256
    lr: float = 3e-4
    warmup: int = 20
    ckpt_every: int = 20
    log_every: int = 10
    compress_grads: bool = False
    fail_at_step: int = -1
    model_parallel: int = 1
    seed: int = 0


def build_step(cfg: ModelConfig, policy, opt_cfg: AdamWConfig,
               compress: bool):
    def step(params, opt_state, err_state, batch):
        loss, grads = jax.value_and_grad(lm.train_loss)(params, batch, cfg,
                                                        policy)
        if compress:
            grads, err_state = error_feedback_update(grads, err_state)
        new_p, new_s, metrics = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return new_p, new_s, err_state, {"loss": loss, **metrics}
    return step


def train(cfg: ModelConfig, tc: TrainConfig,
          ckpt_dir: str | None = None, verbose: bool = True) -> dict:
    mesh = make_local_mesh(tc.model_parallel)
    policy = ShardingPolicy(mesh=mesh)
    sched = make_schedule(cfg.schedule, tc.lr, tc.warmup, tc.steps)
    opt_cfg = AdamWConfig(lr=tc.lr, schedule=sched)

    params = lm.init_params(jax.random.PRNGKey(tc.seed), cfg)
    opt_state = adamw_init(params, opt_cfg)
    err_state = (init_error_state(params) if tc.compress_grads
                 else {"_": jnp.zeros(())})

    params_sh = policy.params_shardings(params)
    step_fn = jax.jit(build_step(cfg, policy, opt_cfg, tc.compress_grads),
                      in_shardings=(params_sh,
                                    {"step": None, "m": params_sh,
                                     "v": params_sh},
                                    None, None),
                      out_shardings=(params_sh,
                                     {"step": None, "m": params_sh,
                                      "v": params_sh},
                                     None, None),
                      donate_argnums=(0, 1, 2))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq,
                      global_batch=tc.global_batch, seed=tc.seed)
    data = DataIterator(dcfg)

    mgr = CheckpointManager(pathlib.Path(ckpt_dir)) if ckpt_dir else None
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        state, extra = mgr.restore({"params": params, "opt": opt_state},
                                   shardings={"params": params_sh,
                                              "opt": {"step": None,
                                                      "m": params_sh,
                                                      "v": params_sh}})
        params, opt_state = state["params"], state["opt"]
        data.restore(extra["data"])
        start_step = int(extra["step"])
        if verbose:
            print(f"[restore] resumed from step {start_step}", flush=True)

    losses = []
    step_times = []
    for step in range(start_step, tc.steps):
        if step == tc.fail_at_step:
            if mgr is not None:
                # the async writer is a separate failure domain: a compute
                # crash must not retroactively lose an already-initiated
                # checkpoint write (otherwise resume is timing-dependent)
                mgr.wait()
            print(f"[fault] injected failure at step {step}", flush=True)
            os._exit(17)        # hard crash: no atexit, no new checkpoint
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, err_state, metrics = step_fn(
            params, opt_state, err_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        step_times.append(dt)
        losses.append(loss)
        # straggler watchdog
        if len(step_times) > 10:
            mu = float(np.mean(step_times[-50:-1]))
            sd = float(np.std(step_times[-50:-1]) + 1e-9)
            if verbose and dt > mu + 4 * sd and dt > 1.5 * mu:
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(µ={mu:.2f}s σ={sd:.2f}s) — flagged for "
                      f"reallocation", flush=True)
        if verbose and step % tc.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if mgr is not None and (step + 1) % tc.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                           extra={"step": step + 1, "data": data.state()})
    if mgr is not None:
        mgr.wait()
        mgr.save(tc.steps, {"params": params, "opt": opt_state},
                 extra={"step": tc.steps, "data": data.state()})
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "step_times": step_times}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tc = TrainConfig(steps=args.steps, global_batch=args.global_batch,
                     seq=args.seq, lr=args.lr, ckpt_every=args.ckpt_every,
                     compress_grads=args.compress_grads,
                     fail_at_step=args.fail_at_step,
                     model_parallel=args.model_parallel)
    out = train(cfg, tc, ckpt_dir=args.ckpt_dir or None)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()

"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
recorded JSONs.  Usage:
    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[3]

ARCHS = ["minicpm-2b", "deepseek-7b", "mistral-nemo-12b", "qwen2-72b",
         "llava-next-mistral-7b", "jamba-1.5-large-398b",
         "seamless-m4t-large-v2", "kimi-k2-1t-a32b", "arctic-480b",
         "mamba2-1.3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, n=3):
    return f"{x:.{n}f}"


def roofline_table(d: pathlib.Path, mesh: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck |"
        " roofline frac | useful FLOPs |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            p = d / f"{arch}_{shape}_{mesh}.json"
            if not p.exists():
                lines.append(f"| {arch} | {shape} | — | — | — | missing | |")
                continue
            rec = json.loads(p.read_text())
            if rec.get("status") == "skipped":
                lines.append(f"| {arch} | {shape} | | | | "
                             f"*{rec['reason']}* | | |")
                continue
            if rec.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | | | | ERROR | | |")
                continue
            r = rec["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt(r['compute_s'])} | "
                f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
                f"{r['bottleneck']} | {fmt(r['roofline_fraction'], 4)} | "
                f"{fmt(r['useful_flops_ratio'], 2)} |")
    return "\n".join(lines)


def dryrun_table(d: pathlib.Path) -> str:
    lines = [
        "| arch | shape | mesh | chips | arg GB/dev | temp GB/dev | "
        "HLO GF/dev | coll GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("singlepod", "multipod"):
                p = d / f"{arch}_{shape}_{mesh}.json"
                if not p.exists():
                    continue
                rec = json.loads(p.read_text())
                if rec.get("status") != "ok":
                    if mesh == "singlepod" and rec.get("status") == "skipped":
                        lines.append(f"| {arch} | {shape} | both | | | | "
                                     f"*skipped (long_500k rule)* | | |")
                    continue
                m = rec["memory"]
                h = rec["hlo_stats"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {rec['chips']} | "
                    f"{m['argument_bytes_per_device']/1e9:.2f} | "
                    f"{m['temp_bytes_per_device']/1e9:.2f} | "
                    f"{h['dot_flops_per_device']/1e9:.0f} | "
                    f"{h['collective_bytes_per_device']/1e9:.1f} | "
                    f"{rec['t_compile_s']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(ROOT / "experiments" / "dryrun"))
    ap.add_argument("--section", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    d = pathlib.Path(args.dir)
    if args.section == "roofline":
        print(roofline_table(d, args.mesh))
    else:
        print(dryrun_table(d))


if __name__ == "__main__":
    main()

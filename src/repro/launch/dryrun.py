import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (JAX locks the device
# count at first initialization).  Everything below is ordinary code.

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:

* builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
* lowers `train_step` (train shapes) or `prefill`/`decode_step`
  (serve shapes) with full production shardings,
* compiles, prints ``memory_analysis()`` (fits?) and ``cost_analysis()``
  (FLOPs/bytes),
* parses the optimized HLO for collective bytes / scan-scaled FLOPs,
* writes a JSON record (+ zstd-compressed HLO) under ``experiments/dryrun/``.

Usage:  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
            --shape train_4k [--multi-pod] [--seq-sharded] [--tag name]
"""

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.analysis import hlo as hlo_analysis
from repro.analysis import roofline as rl
from repro.configs import get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import SHAPES, shape_applicable
from repro.optim import AdamWConfig, adamw_update
from repro.sharding import ShardingPolicy

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def build_train_step(cfg, policy, opt_cfg):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.train_loss)(params, batch, cfg,
                                                        policy)
        new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_s, {"loss": loss, **metrics}
    return train_step


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               seq_sharded: bool = False, quantize_acts: bool = True,
               weight_bits=4, remat: bool = True,
               serve_replicated_weights: bool = False,
               bf16_params: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = ShardingPolicy(
        mesh=mesh, multi_pod=multi_pod, seq_sharded=seq_sharded,
        serve_replicated_weights=(serve_replicated_weights
                                  and shape.kind == "decode"))
    # replicating weights over 'data' trades the FSDP all-gather for 16×
    # weight HBM reads — a win only when each step reads weights once per
    # token (decode); prefill amortizes the gather over 32k tokens.

    params = S.param_struct(cfg, jnp.bfloat16 if bf16_params else jnp.float32)
    params_sh = policy.params_shardings(params)
    batch = S.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        opt = S.opt_struct(params, opt_cfg)
        opt_sh = S.opt_shardings(opt, params_sh, policy)
        batch_sh = S.batch_shardings(batch, policy)
        step = build_train_step(
            cfg, policy,
            dataclasses.replace(opt_cfg))
        fn = jax.jit(step,
                     in_shardings=(params_sh, opt_sh, batch_sh),
                     out_shardings=(params_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        args = (params, opt, batch)
    else:
        serve = S.make_serve_config(cfg, quantize_acts=quantize_acts,
                                    weight_bits=weight_bits)
        sparams = S.serve_param_struct(cfg, serve.weight_bits)
        sparams_sh = policy.params_shardings(sparams)
        if shape.kind == "prefill":
            batch_sh = S.batch_shardings(batch, policy, shape.global_batch)

            def prefill_step(p, b):
                return lm.prefill(p, b, cfg, serve, policy)
            fn = jax.jit(prefill_step,
                         in_shardings=(sparams_sh, batch_sh),
                         out_shardings=None)
            args = (sparams, batch)
        else:
            cache = S.cache_struct(cfg, shape, serve)
            cache_sh = S.cache_shardings(cache, policy, shape.global_batch)
            tok_sh = S.batch_shardings(
                {"tokens": batch["tokens"]}, policy,
                shape.global_batch)["tokens"]

            def decode(p, c, tokens, pos):
                return lm.decode_step(p, c, tokens, pos, cfg, serve, policy)
            from jax.sharding import PartitionSpec as P
            fn = jax.jit(decode,
                         in_shardings=(sparams_sh, cache_sh, tok_sh,
                                       policy.named(P())),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
            args = (sparams, cache, batch["tokens"], batch["pos"])

    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return {"status": "ok", "compiled": compiled, "cfg": cfg, "shape": shape,
            "t_lower": t_lower, "t_compile": t_compile,
            "chips": mesh.devices.size}


def analyze(result: dict, save_hlo: str = "") -> dict:
    compiled = result["compiled"]
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    stats = hlo_analysis.analyze_hlo_text(text)
    roof = rl.compute_roofline(stats, result["cfg"], result["shape"],
                               result["chips"])
    record = {
        "status": "ok",
        "chips": result["chips"],
        "t_lower_s": round(result["t_lower"], 1),
        "t_compile_s": round(result["t_compile"], 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops_per_device_scan_body_once": cost.get("flops"),
            "bytes_accessed_scan_body_once": cost.get("bytes accessed"),
        },
        "hlo_stats": stats,
        "roofline": rl.summarize(roof),
        "hlo_len": len(text),
    }
    if save_hlo:
        import zstandard
        data = zstandard.ZstdCompressor(level=3).compress(text.encode())
        pathlib.Path(save_hlo).write_bytes(data)
        record["hlo_path"] = save_hlo
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-sharded", action="store_true",
                    help="sequence-parallel residual stream (perf variant)")
    ap.add_argument("--no-stamp", action="store_true",
                    help="disable STaMP activation quantization in serving")
    ap.add_argument("--weight-bits", type=int, default=4)
    ap.add_argument("--serve-replicated-weights", action="store_true")
    ap.add_argument("--bf16-params", action="store_true",
                    help="store parameters in bf16 (f32 Adam moments)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out-dir", default="")
    args = ap.parse_args()

    global OUT_DIR
    if args.out_dir:
        OUT_DIR = pathlib.Path(args.out_dir)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multipod" if args.multi_pod else "singlepod"
    stem = f"{args.arch}_{args.shape}_{mesh_tag}"
    if args.seq_sharded:
        stem += "_sp"
    if args.no_stamp:
        stem += "_nostamp"
    if args.tag:
        stem += f"_{args.tag}"

    result = lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        seq_sharded=args.seq_sharded,
        quantize_acts=not args.no_stamp,
        weight_bits=args.weight_bits or None,
        serve_replicated_weights=args.serve_replicated_weights,
        bf16_params=args.bf16_params)
    if result["status"] == "skipped":
        record = result
        print(f"SKIPPED: {result['reason']}")
    else:
        compiled = result["compiled"]
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline
        hlo_path = str(OUT_DIR / f"{stem}.hlo.zst") if args.save_hlo else ""
        record = analyze(result, save_hlo=hlo_path)
        print(json.dumps(record["roofline"], indent=2))

    out = OUT_DIR / f"{stem}.json"
    out.write_text(json.dumps(record, indent=2, default=str))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
JAX initialization.

The ``model`` axis doubles as the expert-parallel axis on MoE configs:
``repro.sharding`` places the stacked expert buffers — bf16 *and* the
fused path's prepared int8 ``{"iq","isw","izw"}`` leaves — with the expert
dim over ``model``, so the capacity dispatch/combine einsums lower to
all-to-alls over the same axis on both the reference and grouped-kernel
paths.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``jax.sharding.AxisType`` landed after 0.4.x; older releases neither
    expose it nor accept ``axis_types`` in ``jax.make_mesh`` — fall back to a
    plain mesh (Auto is the implicit behavior there anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / single-host training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"),
                         **_axis_type_kwargs(2))

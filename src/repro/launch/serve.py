"""Serving driver: PTQ a (small, trained or random-init) model and serve
batched requests through the STaMP-quantized engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --requests 16 --prompt-len 96 --max-new 16 [--no-stamp]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core.ptq import calibrate_and_quantize
from repro.data.pipeline import DataConfig, calibration_batches
from repro.models import lm
from repro.serving.engine import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-stamp", action="store_true")
    ap.add_argument("--execution", choices=("reference", "fused"),
                    default="reference",
                    help="STaMP linear path: pure-jnp reference or the "
                         "fused Pallas integer kernel (interpret on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=4,
                      seed=args.seed)
    calib = calibration_batches(dcfg, num_batches=2)
    sparams, serve, report = calibrate_and_quantize(params, calib, cfg)
    print(f"[ptq] num_hi={report.num_hi} avg_bits={report.avg_bits:.3f} "
          f"toeplitz={report.toeplitz_fraction:.3f} "
          f"head_energy={report.energy_head_fraction:.3f}")
    if args.no_stamp:
        serve = lm.ServeConfig(stamp=None, kv=serve.kv,
                               weight_bits=serve.weight_bits)
    elif serve.stamp is not None:
        import dataclasses
        serve = dataclasses.replace(
            serve, stamp=dataclasses.replace(serve.stamp,
                                             execution=args.execution))

    engine = ServingEngine(sparams, cfg, serve,
                           EngineConfig(max_batch=8, bucket=128,
                                        max_seq=128 + args.max_new))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                      max_new_tokens=args.max_new)
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}")


if __name__ == "__main__":
    main()

"""Serving driver: PTQ a (small, trained or random-init) model and serve
batched requests through a STaMP-quantized engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --requests 16 --prompt-len 96 --max-new 16 \
        [--engine paged|bucketed] [--no-stamp] [--execution fused] \
        [--no-prefix-cache] \
        [--deadline-s 2.0 --ttft-deadline-s 0.5 --max-waiting 32 \
         --shed-policy reject_newest --watermark 0.9 --numerics-guard \
         --chaos SEED]

``--engine bucketed`` is the lockstep slot-batching engine; ``--engine
paged`` (default) is the continuous-batching engine over the block-paged
mixed-precision cache — see `repro/serving/engine.py` for when to pick each.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core.ptq import calibrate_and_quantize
from repro.data.pipeline import DataConfig, calibration_batches
from repro.models import lm
from repro.serving.engine import (BucketedEngine, EngineConfig,
                                  PagedEngineConfig, PagedServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-stamp", action="store_true")
    ap.add_argument("--engine", choices=("paged", "bucketed"),
                    default="paged",
                    help="paged = continuous batching over the block-paged "
                         "cache + slot-dense SSM state pool (dense, MoE, "
                         "hybrid and pure-SSM stacks); bucketed = lockstep "
                         "slot batching (required for enc-dec stacks)")
    ap.add_argument("--execution", choices=("reference", "fused"),
                    default="reference",
                    help="STaMP linear path: pure-jnp reference or the "
                         "fused Pallas integer kernel (interpret on CPU)")
    ap.add_argument("--fused-cache-attention", action="store_true",
                    help="decode attention through the Pallas packed-cache "
                         "kernel (paged or contiguous layout)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per cache page (paged engine)")
    ap.add_argument("--prefill-chunk", type=int, default=128,
                    help="prompt tokens per prefill chunk row (paged)")
    ap.add_argument("--step-mode", choices=("unified", "two_call"),
                    default="unified",
                    help="unified = ONE ragged device program per step "
                         "(prefill chunks + decode batch); two_call = the "
                         "old prefill-then-decode jit pair (parity/A-B)")
    ap.add_argument("--max-prefills", type=int, default=2,
                    help="prefill chunk rows per unified step")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="hash-addressed prefix page reuse: requests whose "
                         "prompt shares a cached prefix start prefill at "
                         "the first uncached token (paged engine; tokens "
                         "are bit-identical either way)")
    ap.add_argument("--seed", type=int, default=0)
    # -- robustness / admission control (paged engine) ------------------
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request total latency budget in seconds; "
                         "requests past it FAIL at plan time")
    ap.add_argument("--ttft-deadline-s", type=float, default=None,
                    help="per-request first-token budget in seconds")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="bounded waiting queue: beyond this depth the "
                         "shed policy decides who is turned away")
    ap.add_argument("--shed-policy", choices=("reject_newest",
                                              "shed_oldest"),
                    default="reject_newest")
    ap.add_argument("--watermark", type=float, default=1.0,
                    help="page-pool occupancy fraction that triggers early "
                         "preemption (1.0 = only on true exhaustion)")
    ap.add_argument("--numerics-guard", action="store_true",
                    help="check step outputs for NaN/Inf and quarantine "
                         "the offending request (fused STaMP engines also "
                         "demote to reference execution)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject seeded faults (page exhaustion, swap "
                         "corruption, NaN) via a FaultPlan — a smoke of "
                         "the degradation machinery, not a benchmark")
    # -- observability ---------------------------------------------------
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the engine metrics registry snapshot "
                         "(counters/gauges/histograms) as JSON on exit")
    ap.add_argument("--metrics-prom", default=None, metavar="PATH",
                    help="write the registry in Prometheus text "
                         "exposition format on exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the engine event ring as Chrome "
                         "trace-event JSON (open in ui.perfetto.dev)")
    ap.add_argument("--quant-telemetry", action="store_true",
                    help="collect per-STaMP-site quant-health stats "
                         "(clip rate, hi-token coverage, scale range) in "
                         "the same device program as each step")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.engine == "paged" and cfg.encoder_layers:
        # fail at the CLI boundary with the fix in hand, not five frames
        # deep in cache init: enc-dec cross-attention K/V is computed once
        # from the encoder output and held dense per request — not paged.
        ap.error(f"--engine paged does not support encoder-decoder stacks "
                 f"({cfg.name}: encoder_layers={cfg.encoder_layers}); "
                 f"run with --engine bucketed")
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=4,
                      seed=args.seed)
    calib = calibration_batches(dcfg, num_batches=2)
    sparams, serve, report = calibrate_and_quantize(params, calib, cfg)
    print(f"[ptq] num_hi={report.num_hi} avg_bits={report.avg_bits:.3f} "
          f"toeplitz={report.toeplitz_fraction:.3f} "
          f"head_energy={report.energy_head_fraction:.3f}")
    if args.no_stamp:
        serve = lm.ServeConfig(stamp=None, kv=serve.kv,
                               weight_bits=serve.weight_bits)
    elif serve.stamp is not None:
        serve = dataclasses.replace(
            serve, stamp=dataclasses.replace(serve.stamp,
                                             execution=args.execution))
    if args.fused_cache_attention:
        serve = dataclasses.replace(serve, fused_cache_attention=True)
    if args.numerics_guard:
        serve = dataclasses.replace(serve, numerics_guard=True)
    if args.quant_telemetry:
        serve = dataclasses.replace(serve, quant_telemetry=True)

    max_seq = 128 + args.max_new
    if args.engine == "paged":
        num_hi = serve.kv.num_hi if serve.kv.quantized else 0
        bs = args.block_size
        if num_hi % bs:
            bs = num_hi      # pages must be single-precision (num_hi % bs == 0)
            print(f"[serve] block_size adjusted to {bs} (num_hi={num_hi})")
        fault = None
        if args.chaos is not None:
            from repro.serving.faults import FaultPlan
            fault = FaultPlan(seed=args.chaos, exhaust_rate=0.2,
                              corrupt_rate=0.3, nan_rate=0.005)
        engine = PagedServingEngine(
            sparams, cfg, serve,
            PagedEngineConfig(max_slots=8, prefill_chunk=args.prefill_chunk,
                              max_seq=max_seq, block_size=bs,
                              step_mode=args.step_mode,
                              max_prefills=args.max_prefills,
                              max_waiting=args.max_waiting,
                              shed_policy=args.shed_policy,
                              preempt_watermark=args.watermark,
                              prefix_caching=args.prefix_cache),
            fault=fault)
    else:
        engine = BucketedEngine(sparams, cfg, serve,
                                EngineConfig(max_batch=8, bucket=128,
                                             max_seq=max_seq))
    # per-site fused/reference matrix: which linears run integer kernels
    # and, for every reference site, the structured reason why
    for site, cell in engine.eligibility.items():
        why = f" ({','.join(cell['reasons'])})" if cell["reasons"] else ""
        print(f"[serve:eligibility] {site:<12} {cell['status']:<9} "
              f"kernel={cell['kernel'] or '-'} "
              f"layers={cell['layers']}{why}")
    n_ref = engine.stats["reference_fallback_sites"]
    print(f"[serve:eligibility] reference_fallback_sites={n_ref}")
    if n_ref == 0 and "moe" in engine.eligibility:
        # the MoE expert einsums were the last structurally-ineligible
        # site — call out full coverage explicitly on expert configs
        print("[serve:eligibility] full fused coverage: every STaMP site "
              "incl. grouped MoE runs the integer kernels")
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                      max_new_tokens=args.max_new,
                      deadline_s=args.deadline_s,
                      ttft_deadline_s=args.ttft_deadline_s)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    ttfts = sorted(r.ttft_s for r in done)
    print(f"[serve:{args.engine}] {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s on CPU), "
          f"ttft p50={ttfts[len(ttfts) // 2]:.2f}s")
    if args.engine == "paged":
        st = engine.stats
        print(f"[serve:paged:{args.step_mode}] steps={st['steps']} "
              f"prefill_chunks={st['prefill_chunks']} "
              f"preemptions={st['preemptions']} "
              f"dispatches/step="
              f"{st['device_dispatches'] / max(st['steps'], 1):.2f} "
              f"recompiles={st['recompiles']} "
              f"prefix_hit_rate={st['prefix_cache_hit_rate']:.2f} "
              f"prefix_tokens_reused={st['prefix_tokens_reused']}")
        print(f"[serve:lifecycle] finished={st['finished']} "
              f"failed={st['failed']} cancelled={st['cancelled']} "
              f"rejected={st['rejected']} shed={st['shed']} "
              f"deadline_misses={st['deadline_misses']} "
              f"nan_quarantines={st['nan_quarantines']} "
              f"demotions={st['demotions']} "
              f"watchdog_trips={st['watchdog_trips']}")
    for r in done[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}")

    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            f.write(engine.metrics.to_json())
        print(f"[obs] metrics snapshot -> {args.metrics_json}")
    if args.metrics_prom:
        with open(args.metrics_prom, "w") as f:
            f.write(engine.metrics.to_prometheus())
        print(f"[obs] prometheus text -> {args.metrics_prom}")
    if args.trace_out:
        import json
        from repro.obs.trace import export_chrome_trace
        trace = export_chrome_trace(engine.events, engine=args.engine)
        with open(args.trace_out, "w") as f:
            json.dump(trace, f)
        print(f"[obs] {len(trace['traceEvents'])} trace events -> "
              f"{args.trace_out} (open in ui.perfetto.dev)")
    if args.quant_telemetry:
        snap = engine.metrics.snapshot()
        rates = {k: round(v, 4) for k, v in snap["gauges"].items()
                 if k.startswith("quant_clip_rate")}
        if rates:
            print(f"[obs] quant clip rates: {rates}")


if __name__ == "__main__":
    main()

"""Run the full dry-run grid (arch × shape × mesh) in subprocesses.

One subprocess per cell keeps XLA's memory bounded and makes the sweep
resumable: cells with an existing JSON record are skipped (delete the file
to re-run).  Usage::

    PYTHONPATH=src python -m repro.launch.sweep [--only-singlepod] [--force]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[3]
OUT_DIR = ROOT / "experiments" / "dryrun"

ARCHS = [
    "minicpm-2b", "deepseek-7b", "mistral-nemo-12b", "qwen2-72b",
    "llava-next-mistral-7b", "jamba-1.5-large-398b", "seamless-m4t-large-v2",
    "kimi-k2-1t-a32b", "arctic-480b", "mamba2-1.3b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch: str, shape: str, multi_pod: bool, extra=(),
             out_dir=None, timeout: int = 3600) -> str:
    mesh_tag = "multipod" if multi_pod else "singlepod"
    out = (out_dir or OUT_DIR) / f"{arch}_{shape}_{mesh_tag}.json"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--save-hlo",
           "--out-dir", str(out_dir or OUT_DIR), *extra]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          cwd=str(ROOT), timeout=timeout,
                          env={"PYTHONPATH": str(ROOT / "src"),
                               "PATH": "/usr/bin:/bin:/usr/local/bin"})
    dt = time.time() - t0
    if proc.returncode != 0:
        err = proc.stderr.strip().splitlines()[-1] if proc.stderr else "?"
        out.write_text(json.dumps(
            {"status": "error", "error": err, "t_s": dt}, indent=2))
        return f"ERROR ({dt:.0f}s): {err[:120]}"
    try:
        rec = json.loads(out.read_text())
        if rec.get("status") == "skipped":
            return f"skipped: {rec['reason'][:60]}"
        r = rec["roofline"]
        return (f"ok ({dt:.0f}s) bottleneck={r['bottleneck']} "
                f"frac={r['roofline_fraction']:.4f}")
    except Exception as e:  # pragma: no cover
        return f"ok ({dt:.0f}s) [no record: {e}]"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-singlepod", action="store_true")
    ap.add_argument("--only-multipod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default="")
    ap.add_argument("--extra", default="",
                    help="comma-separated extra dryrun flags")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir) if args.out_dir else OUT_DIR
    extra = tuple(x for x in args.extra.split(",") if x)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True]
    if args.only_singlepod:
        meshes = [False]
    if args.only_multipod:
        meshes = [True]

    total = t0 = time.time()
    for multi_pod in meshes:
        mesh_tag = "multipod" if multi_pod else "singlepod"
        for arch in ARCHS:
            for shape in SHAPES:
                out = out_dir / f"{arch}_{shape}_{mesh_tag}.json"
                tag = f"{arch:24s} {shape:12s} {mesh_tag:10s}"
                if out.exists() and not args.force:
                    rec = json.loads(out.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"{tag} cached:{rec['status']}", flush=True)
                        continue
                msg = run_cell(arch, shape, multi_pod, extra=extra,
                               out_dir=out_dir)
                print(f"{tag} {msg}", flush=True)
    print(f"sweep done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

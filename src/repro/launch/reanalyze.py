"""Re-derive roofline records from saved .hlo.zst files (no recompilation).

Used whenever the HLO analyzer improves: the compiled artifacts are the
ground truth; the JSON records are views.  Keeps `memory`/`xla_cost` fields
from the original record (they come from the compiled object).
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

import zstandard

from repro.analysis import hlo as H
from repro.analysis import roofline as rl
from repro.configs import get_config
from repro.models.config import SHAPES

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
_NAME = re.compile(r"(?P<arch>.+?)_(?P<shape>train_4k|prefill_32k|decode_32k|"
                   r"long_500k)_(?P<mesh>singlepod|multipod)(?P<tag>.*)")


def reanalyze(json_path: pathlib.Path) -> str:
    rec = json.loads(json_path.read_text())
    if rec.get("status") != "ok":
        return "skip"
    hlo_path = json_path.with_suffix("").with_suffix("")  # strip .json
    hlo_path = json_path.parent / (json_path.stem + ".hlo.zst")
    if not hlo_path.exists():
        return "no-hlo"
    m = _NAME.match(json_path.stem)
    if not m:
        return "no-name"
    cfg = get_config(m.group("arch"))
    shape = SHAPES[m.group("shape")]
    chips = 512 if m.group("mesh") == "multipod" else 256
    text = zstandard.ZstdDecompressor().decompress(
        hlo_path.read_bytes()).decode()
    stats = H.analyze_hlo_text(text)
    roof = rl.compute_roofline(stats, cfg, shape, chips)
    rec["hlo_stats"] = stats
    rec["roofline"] = rl.summarize(roof)
    json_path.write_text(json.dumps(rec, indent=2, default=str))
    return f"ok {roof.bottleneck} frac={roof.roofline_fraction:.4f}"


def main():
    dirs = [OUT_DIR, OUT_DIR.parent / "perf"]
    for d in dirs:
        for p in sorted(d.glob("*.json")):
            print(f"{p.stem:60s} {reanalyze(p)}", flush=True)


if __name__ == "__main__":
    main()

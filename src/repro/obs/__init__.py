"""Dependency-free observability: metrics registry, structured event trace
with Chrome/Perfetto export, and STaMP quantization-health telemetry.

Three modules, layered by what they may import:

* `metrics.py` — pure stdlib.  `MetricsRegistry` with typed counters,
  gauges and fixed-bucket histograms (exponential buckets for latency-like
  quantities), labeled children, `snapshot()`/`reset()` and JSON +
  Prometheus-text exposition.  Both serving engines hang their whole
  `stats` surface off one registry.
* `trace.py` — pure stdlib.  The typed :class:`Event` record that replaced
  the engines' mixed-arity event tuples (tuple-unpacking stays compatible
  via ``__iter__``), the :class:`StepTimer` that times the engine step
  phases (plan / dispatch / post), and `export_chrome_trace` rendering
  per-request span timelines + per-step phase slices as Chrome
  trace-event JSON (load in Perfetto / ``chrome://tracing``).
* `quantstats.py` — imports jax.  Per-STaMP-site activation clip rate,
  hi-token coverage, scale dynamic range and int-saturation counts,
  computed as cheap on-device reductions *inside* the existing step
  programs (zero extra device dispatches) and aggregated into the
  registry by the engines.
"""

from repro.obs.metrics import (LATENCY_BUCKETS, MetricsRegistry,  # noqa: F401
                               exponential_buckets)
from repro.obs.trace import Event, StepTimer, export_chrome_trace  # noqa: F401

"""Structured engine events, step-phase timing, and Chrome-trace export.

:class:`Event` replaces the mixed-arity ``(step, kind, payload)`` tuples
the engines used to append to ``engine.events``: every event now carries
the same fields (step, kind, uid, timestamp, optional duration/phase,
plus a kind-specific ``fields`` dict).  Tuple-unpacking call sites keep
working — ``for step, kind, payload in engine.events`` — because
``__iter__`` reconstructs the legacy 3-tuple, including the historical
payload shapes (``(uid, start, end)`` for prefill chunks, the sorted uid
tuple for decode batches, ``(uid, error)`` for error terminals).

:class:`StepTimer` wraps the three phases of an engine step — ``plan``
(deadlines + scheduler), ``dispatch`` (host batch build + the device
program + result materialization), ``post`` (token post-loops) — into
histogram observations and per-step phase events.  It reads the
*observability* clock exactly twice per phase (enter/exit), so a
fake tick-clock test can pin exact durations; engine semantics
(deadlines, TTFT) stay on the engine's own clock, untouched.

`export_chrome_trace` renders the event ring as Chrome trace-event JSON
(the ``{"traceEvents": [...]}`` object form): one thread per request
showing its WAITING → PREFILLING → DECODING span timeline with
preempt/resume/swap/quarantine instant marks, plus one thread of
per-step phase slices.  Load the file in https://ui.perfetto.dev or
``chrome://tracing``.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional

# kinds whose legacy payload was `(uid, error)` when an error string is
# present (engine._terminate) — everything else carried a bare uid,
# except the special cases handled in Event.payload.
_TERMINAL_KINDS = ("finish", "fail", "cancel", "reject", "shed",
                   "watchdog", "swap_corrupt")

STEP_PHASES = ("plan", "dispatch", "post")


@dataclasses.dataclass
class Event:
    """One engine occurrence with a stable schema.

    ``fields`` holds kind-specific detail: ``start``/``end`` for
    ``prefill_chunk``, ``uids`` for ``decode``, ``error`` for failure
    terminals, ``to`` for ``demote``, ``site``/``clip_rate`` for
    ``quant_clip_alert``.
    """
    step: int
    kind: str
    uid: Optional[int] = None
    t: float = 0.0                 # observability-clock timestamp (s)
    dur: Optional[float] = None    # span length for phase/chunk slices (s)
    phase: Optional[str] = None    # "plan" | "dispatch" | "post" for phases
    fields: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def payload(self):
        """The legacy third tuple slot, per historical kind conventions."""
        if self.kind == "prefill_chunk":
            return (self.uid, self.fields["start"], self.fields["end"])
        if self.kind == "decode":
            return self.fields["uids"]
        if self.kind == "demote":
            return self.fields["to"]
        if self.kind == "fault_exhaust":
            return self.step
        err = self.fields.get("error")
        if err is not None:
            return (self.uid, err)
        return self.uid

    def __iter__(self):
        # legacy tuple-unpacking: `for step, kind, payload in events`
        return iter((self.step, self.kind, self.payload))


class StepTimer:
    """Times named step phases into a histogram family and emits one
    ``phase`` event per occurrence.

    ``clock`` is called exactly twice per phase (enter + exit); pass the
    engine's observability tick so event timestamps advance with phase
    boundaries.  ``on_phase(name, t0, dur)`` lets the engine append the
    phase slice to its event ring.
    """

    def __init__(self, metrics, clock: Callable[[], float],
                 on_phase: Optional[Callable[[str, float, float], None]] = None,
                 buckets=None):
        self._metrics = metrics
        self._clock = clock
        self._on_phase = on_phase
        self._buckets = buckets

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = self._clock()
        try:
            yield
        finally:
            dur = self._clock() - t0
            self._metrics.histogram(
                "step_phase_s", help="engine step phase wall time",
                buckets=self._buckets, labels={"phase": name}).observe(dur)
            if self._on_phase is not None:
                self._on_phase(name, t0, dur)


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

_INSTANT_NAMES = {
    "preempt": "preempt (pages swapped out)",
    "resume": "resume (pages swapped in)",
    "deadline_miss": "deadline miss",
    "nan_quarantine": "NaN quarantine",
    "fault_nan": "fault: injected NaN",
    "fault_corrupt": "fault: swap corruption",
    "quant_clip_alert": "quant clip alert",
}

_PID = 1
_TID_STEPS = 0


def _us(t: float, t0: float) -> int:
    return int(round((t - t0) * 1e6))


def export_chrome_trace(events: Iterable, engine: str = "engine") -> dict:
    """Render an engine event ring as a Chrome trace-event JSON object.

    One pid (the engine); tid 0 carries the per-step phase slices, one
    tid per request uid carries that request's lifecycle span timeline:
    WAITING (submit→admit, and preempt→resume while swapped out),
    PREFILLING (admit→first token, with per-chunk slices), DECODING
    (first token→terminal), instant marks for preempt/resume/faults/
    quarantines, and a terminal instant naming the outcome.
    """
    evs: List[Event] = [e for e in events if isinstance(e, Event)]
    if not evs:
        return {"traceEvents": [],
                "displayTimeUnit": "ms",
                "metadata": {"engine": engine}}
    t0 = min(e.t for e in evs)
    out: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
         "args": {"name": f"repro serving: {engine}"}},
        {"name": "thread_name", "ph": "M", "pid": _PID, "tid": _TID_STEPS,
         "args": {"name": "engine steps"}},
    ]
    named_tids = set()

    def tid_for(uid: int) -> int:
        tid = uid + 1          # tid 0 is the step-phase thread
        if tid not in named_tids:
            named_tids.add(tid)
            out.append({"name": "thread_name", "ph": "M", "pid": _PID,
                        "tid": tid, "args": {"name": f"req {uid}"}})
        return tid

    def span(uid: int, name: str, ts: float, te: float, args=None):
        out.append({"name": name, "ph": "X", "pid": _PID,
                    "tid": tid_for(uid), "ts": _us(ts, t0),
                    "dur": max(_us(te, t0) - _us(ts, t0), 0),
                    "args": args or {}})

    def instant(uid: int, name: str, t: float, args=None):
        out.append({"name": name, "ph": "i", "s": "t", "pid": _PID,
                    "tid": tid_for(uid), "ts": _us(t, t0),
                    "args": args or {}})

    # -- per-step phase slices ------------------------------------------
    for e in evs:
        if e.kind == "phase":
            out.append({"name": e.phase or "phase", "ph": "X", "pid": _PID,
                        "tid": _TID_STEPS, "ts": _us(e.t, t0),
                        "dur": max(_us(e.t + (e.dur or 0.0), t0)
                                   - _us(e.t, t0), 0),
                        "args": {"step": e.step}})

    # -- per-request lifecycle spans ------------------------------------
    # state machine per uid: (state name, state start time)
    state: Dict[int, tuple] = {}
    saw_first: Dict[int, bool] = {}
    last_t = max(e.t + (e.dur or 0.0) for e in evs)

    def close(uid: int, te: float, args=None):
        cur = state.pop(uid, None)
        if cur is not None:
            span(uid, cur[0], cur[1], te, args)

    for e in evs:
        uid, k = e.uid, e.kind
        if uid is None or k in ("phase", "decode"):
            continue
        if k == "submit":
            state[uid] = ("WAITING", e.t)
            saw_first[uid] = False
        elif k == "admit":
            close(uid, e.t)
            state[uid] = ("DECODING" if saw_first.get(uid) else "PREFILLING",
                          e.t)
        elif k == "preempt":
            close(uid, e.t)
            state[uid] = ("WAITING", e.t)
            instant(uid, _INSTANT_NAMES[k], e.t)
        elif k == "resume":
            instant(uid, _INSTANT_NAMES[k], e.t)
        elif k == "prefill_chunk":
            span(uid, f"prefill[{e.fields.get('start')}:"
                      f"{e.fields.get('end')})",
                 e.t, e.t + (e.dur or 0.0), {"step": e.step})
        elif k == "first_token":
            close(uid, e.t)
            saw_first[uid] = True
            state[uid] = ("DECODING", e.t)
            instant(uid, "first token", e.t)
        elif k in _TERMINAL_KINDS:
            close(uid, e.t)
            args = {"step": e.step}
            if e.fields.get("error"):
                args["error"] = e.fields["error"]
            instant(uid, f"terminal: {k}", e.t, args)
        elif k in _INSTANT_NAMES:
            instant(uid, _INSTANT_NAMES[k], e.t,
                    dict(e.fields) if e.fields else None)
        else:
            instant(uid, k, e.t, dict(e.fields) if e.fields else None)

    # requests still open when the ring was exported (or whose submit
    # fell off the ring): close at the last observed timestamp
    for uid in list(state):
        close(uid, last_t, {"open": True})

    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "metadata": {"engine": engine}}

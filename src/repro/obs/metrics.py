"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

Pure stdlib — importable from benches, the serve CLI and tests without
pulling in jax.  One :class:`MetricsRegistry` per engine; both serving
engines expose their legacy ``stats`` dict as a read-only view over the
registry's counters, so there is exactly one source of truth.

Design notes
------------
* Metrics are grouped into *families* (one name, one type, one help
  string, one bucket layout).  A family has labeled children — e.g.
  ``quant_clip_rate{site="qkv"}`` — addressed by a sorted label tuple.
  Calling ``registry.counter(name, labels=...)`` is get-or-create and
  always returns the same child object, so call sites don't cache.
* Histograms use fixed upper-bound buckets (Prometheus ``le``
  semantics: bucket *i* counts observations ``v <= edge[i]``, plus one
  overflow bucket).  `exponential_buckets` builds the geometric layouts
  used for latency / TTFT / queue-wait.  Percentiles are estimated by
  linear interpolation inside the covering bucket, which bounds the
  relative error by the bucket growth factor — good enough for p50/p99
  reporting and far cheaper than keeping raw sample lists.
* ``reset(exclude=...)`` zeroes values but keeps registrations, so a
  bench can drop warmup observations while preserving cumulative
  counters like ``recompiles``.
* The injectable ``clock`` only stamps snapshots (wall-clock metadata);
  engine phase timing uses its own observability clock (see trace.py).
"""

from __future__ import annotations

import bisect
import json
import re
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

LabelMap = Optional[Dict[str, str]]
LabelKey = Tuple[Tuple[str, str], ...]


def exponential_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """``count`` geometric bucket upper bounds: start, start*factor, ..."""
    if start <= 0.0 or factor <= 1.0 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    edges, v = [], start
    for _ in range(count):
        edges.append(v)
        v *= factor
    return tuple(edges)


# 100 µs .. ~210 s, factor 2 — covers interpret-mode CPU latencies end to end.
LATENCY_BUCKETS = exponential_buckets(1e-4, 2.0, 22)


def _label_key(labels: LabelMap) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_name(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing value (float internally; expose as-is)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a gauge")
        self.value += n

    def _reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Point-in-time value; set freely."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def _reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram with Prometheus ``le`` semantics."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float]):
        self.edges: Tuple[float, ...] = tuple(edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError("bucket edges must be strictly increasing")
        self.counts: List[int] = [0] * (len(self.edges) + 1)  # +overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # first edge >= v  (bucket i holds v <= edges[i])
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def percentile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) by interpolating inside
        the covering bucket.  Returns 0.0 on an empty histogram; values
        in the overflow bucket report the last finite edge."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                if i >= len(self.edges):        # overflow bucket
                    return self.edges[-1]
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i]
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.edges[-1]

    def _reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.sum = 0.0
        self.count = 0


class _Family:
    __slots__ = ("name", "type", "help", "buckets", "children")

    def __init__(self, name: str, typ: str, help: str,
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.type = typ
        self.help = help
        self.buckets = buckets
        self.children: Dict[LabelKey, object] = {}

    def child(self, key: LabelKey):
        c = self.children.get(key)
        if c is None:
            if self.type == "counter":
                c = Counter()
            elif self.type == "gauge":
                c = Gauge()
            else:
                c = Histogram(self.buckets)
            self.children[key] = c
        return c


class MetricsRegistry:
    """One namespace of metric families; the single stats surface an
    engine (or bench) publishes through."""

    def __init__(self, clock=time.time):
        self._families: Dict[str, _Family] = {}
        self._clock = clock

    # -- get-or-create accessors ----------------------------------------
    def _family(self, name: str, typ: str, help: str,
                buckets: Optional[Sequence[float]] = None) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, typ, help,
                          tuple(buckets) if buckets is not None else None)
            self._families[name] = fam
        elif fam.type != typ:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam.type}, requested {typ}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: LabelMap = None) -> Counter:
        return self._family(name, "counter", help).child(_label_key(labels))

    def gauge(self, name: str, help: str = "",
              labels: LabelMap = None) -> Gauge:
        return self._family(name, "gauge", help).child(_label_key(labels))

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None,
                  labels: LabelMap = None) -> Histogram:
        fam = self._family(name, "histogram", help,
                           buckets if buckets is not None else LATENCY_BUCKETS)
        return fam.child(_label_key(labels))

    # -- lifecycle -------------------------------------------------------
    def reset(self, exclude: Iterable[str] = ()) -> None:
        """Zero every metric value (keep registrations).  Families named
        in ``exclude`` are preserved — e.g. cumulative ``recompiles``."""
        skip = set(exclude)
        for fam in self._families.values():
            if fam.name in skip:
                continue
            for child in fam.children.values():
                child._reset()

    # -- exposition ------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-data view: {"t", "counters", "gauges", "histograms"}."""
        out = {"t": float(self._clock()),
               "counters": {}, "gauges": {}, "histograms": {}}
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            for key in sorted(fam.children):
                child = fam.children[key]
                rname = _render_name(fam.name, key)
                if fam.type == "counter":
                    out["counters"][rname] = child.value
                elif fam.type == "gauge":
                    out["gauges"][rname] = child.value
                else:
                    out["histograms"][rname] = {
                        "edges": list(child.edges),
                        "counts": list(child.counts),
                        "sum": child.sum,
                        "count": child.count,
                    }
        return out

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.type}")
            for key in sorted(fam.children):
                child = fam.children[key]
                if fam.type in ("counter", "gauge"):
                    lines.append(f"{_render_name(fam.name, key)} "
                                 f"{_fmt(child.value)}")
                else:
                    cum = 0
                    for edge, c in zip(child.edges, child.counts):
                        cum += c
                        le = key + (("le", _fmt(edge)),)
                        lines.append(f"{_render_name(fam.name + '_bucket', le)}"
                                     f" {cum}")
                    le = key + (("le", "+Inf"),)
                    lines.append(f"{_render_name(fam.name + '_bucket', le)} "
                                 f"{child.count}")
                    lines.append(f"{_render_name(fam.name + '_sum', key)} "
                                 f"{_fmt(child.sum)}")
                    lines.append(f"{_render_name(fam.name + '_count', key)} "
                                 f"{child.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))

r"""STaMP quantization-health telemetry: per-site on-device reductions.

The question this answers: *are the activation quantizers healthy at each
STaMP site* (qkv, wo, gate_up, wo_mlp, moe, in_proj, out_proj)?  Four
signals per site, all O(1) scalars reduced on device:

* **clip rate** — fraction of pre-clamp codes outside ``[0, 2^b-1]``.
  Min-max scales clip nothing by construction, so a *rising* clip rate
  means the scales no longer cover the transformed activations (stale
  calibration, saturating distribution) — the early warning that fires
  before the PR-6 NaN quarantine does.
* **saturation count** — codes ON the rails (0 or 2^b−1).  Nonzero is
  normal (min/max always saturate); a large fraction means the
  distribution is heavy-tailed in the transformed domain and the low-bit
  codes carry little information.
* **hi-token coverage** — fraction of (batch, token) rows quantized at
  ``hi_bits``; checks the mixed-precision budget the paper's accuracy
  story depends on is actually being spent.
* **scale dynamic range** — log2(max/min) of the per-token scales; a
  blow-up here predicts poor low-bit fidelity for the small-scale rows.

Collection protocol (how the stats escape ``jax.lax.scan``)
-----------------------------------------------------------
Recording happens at *trace time* into a module-level collector:

1. an engine entry point (``lm.prefill`` / ``lm.paged_unified_step``,
   gated on ``ServeConfig.quant_telemetry``) calls :func:`begin`;
2. each STaMP site calls :func:`record` with its transformed activation
   — inside ``run_stack``'s scan body these are scan tracers, so the
   body :func:`drain`\ s them and returns them as extra scan outputs
   (stacked over the period axis), while prologue-layer records stay in
   the collector;
3. ``run_stack`` re-absorbs the period-stacked stats (:func:`absorb`:
   counts sum, scale bounds min/max over the period axis);
4. the entry point calls :func:`end` and returns the site dict alongside
   its normal outputs — the scalars travel in the SAME device program,
   which is what keeps telemetry at zero extra dispatches per step
   (asserted in tests/test_obs.py).

The stats are jnp scalars until the engine host-transfers them into its
`MetricsRegistry` (:func:`summarize`).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q

# keys combined by min/max; everything else sums across layers/steps
# (key-driven so pseudo-sites — e.g. the MoE router's load counters —
# ride the same scan drain/absorb protocol with their own key sets)
_SUM_KEYS = ("clipped", "saturated", "elems", "hi_tokens", "tokens")
_MIN_KEYS = ("scale_min",)
_MAX_KEYS = ("scale_max",)

_ACTIVE = False
_SITES: Optional[Dict[str, Dict[str, jnp.ndarray]]] = None


def active() -> bool:
    return _ACTIVE


def begin() -> None:
    """Start a collection scope (entry points only, at trace time)."""
    global _ACTIVE, _SITES
    _ACTIVE = True
    _SITES = {}


def end() -> Dict[str, Dict[str, jnp.ndarray]]:
    """Close the scope and return everything collected."""
    global _ACTIVE, _SITES
    out = _SITES or {}
    _ACTIVE = False
    _SITES = None
    return out


def drain() -> Dict[str, Dict[str, jnp.ndarray]]:
    """Take the records accumulated so far, leaving the scope open.
    ``run_stack``'s scan body drains so its tracers leave the body as
    scan outputs instead of leaking."""
    global _SITES
    if not _ACTIVE or not _SITES:
        return {}
    out = _SITES
    _SITES = {}
    return out


def _merge(dst: Dict[str, Dict], site: str, stats: Dict) -> None:
    cur = dst.get(site)
    if cur is None:
        dst[site] = dict(stats)
        return
    for k, v in stats.items():
        if k in _MIN_KEYS:
            cur[k] = jnp.minimum(cur[k], v)
        elif k in _MAX_KEYS:
            cur[k] = jnp.maximum(cur[k], v)
        else:
            cur[k] = cur[k] + v


def merge_flat(records: Dict[str, Dict]) -> None:
    """Merge already-flat records (e.g. prologue-layer stats drained
    before the scan) back into the open scope."""
    if not _ACTIVE or not records:
        return
    for site, stats in records.items():
        _merge(_SITES, site, stats)


def absorb(stacked: Dict[str, Dict]) -> None:
    """Merge period-stacked scan outputs (leading axis = period layers)
    back into the open scope, reducing the stacked axis first."""
    if not _ACTIVE or not stacked:
        return
    for site, stats in stacked.items():
        flat = {}
        for k, v in stats.items():
            if k in _MIN_KEYS:
                flat[k] = jnp.min(v, axis=0)
            elif k in _MAX_KEYS:
                flat[k] = jnp.max(v, axis=0)
            else:
                flat[k] = jnp.sum(v, axis=0)
        _merge(_SITES, site, flat)


def record(site: Optional[str], tx, bits, hi_bits: int) -> None:
    """Record one site's transformed activation (called from the STaMP
    linears at trace time; no-op unless a scope is open)."""
    if not _ACTIVE or site is None:
        return
    _merge(_SITES, site, site_stats(tx, bits, hi_bits))


def record_extra(site: str, stats: Dict[str, jnp.ndarray]) -> None:
    """Record an arbitrary stats dict under a pseudo-site (e.g. the MoE
    router's ``expert_tokens``/``dropped_tokens`` load counters).  Keys
    reduce by the standard rules — sum unless named in ``_MIN_KEYS`` /
    ``_MAX_KEYS`` — and ride the identical scan drain/absorb protocol,
    so vector-valued leaves (per-expert counts) stack and re-reduce over
    the period axis like any quant counter."""
    if not _ACTIVE or site is None:
        return
    _merge(_SITES, site, {k: jnp.asarray(v) for k, v in stats.items()})


def site_stats(tx, bits, hi_bits: int, scale=None, zp=None
               ) -> Dict[str, jnp.ndarray]:
    """The on-device reductions for one transformed activation ``tx``
    of shape ``(..., s, d)`` with per-token ``bits`` (shape ``(s,)`` or
    scalar).

    Pass ``scale``/``zp`` to audit externally-chosen quantizer params;
    by default the same per-token asymmetric min-max params the
    quantizer itself derives are recomputed here (XLA CSEs the
    duplicate reductions on the reference path).  Block-granularity
    configs are audited with the same per-token proxy scales.
    """
    tx = tx.astype(jnp.float32)
    if scale is None:
        scale, zp = Q.minmax_scale_offset(tx, bits, axis=-1)
    n = Q._levels(bits)
    if isinstance(bits, jnp.ndarray) and getattr(bits, "ndim", 0):
        n = Q._align_token_axis(n, tx.ndim, -1)
    q_raw = jnp.round(tx / scale) + zp
    # half-a-code tolerance: an exact min/max hit lands on the rail to
    # within float error and must not count as clipped
    clipped = jnp.sum((q_raw < -0.5) | (q_raw > n + 0.5))
    q = jnp.clip(q_raw, 0.0, n)
    saturated = jnp.sum((q <= 0.5) | (q >= n - 0.5))
    s = tx.shape[-2]
    tokens = float(np.prod(tx.shape[:-1]))      # (batch…, token) rows
    rows_per_seq = tokens / float(s)
    if isinstance(bits, jnp.ndarray) and getattr(bits, "ndim", 0):
        hi_tokens = jnp.sum(
            (bits >= float(hi_bits)).astype(jnp.float32)) * rows_per_seq
    else:
        hi_tokens = jnp.asarray(
            tokens if float(bits) >= float(hi_bits) else 0.0, jnp.float32)
    return {
        "clipped": clipped.astype(jnp.float32),
        "saturated": saturated.astype(jnp.float32),
        "elems": jnp.asarray(float(tx.size), jnp.float32),
        "hi_tokens": hi_tokens.astype(jnp.float32),
        "tokens": jnp.asarray(tokens, jnp.float32),
        "scale_min": jnp.min(scale).astype(jnp.float32),
        "scale_max": jnp.max(scale).astype(jnp.float32),
    }


def summarize(raw: Dict[str, Dict]) -> Dict[str, Dict[str, float]]:
    """Host-side rates from the device counts: per site, ``clip_rate``,
    ``sat_rate``, ``hi_coverage``, ``scale_log2_range`` plus the raw
    counts as floats."""
    out: Dict[str, Dict[str, float]] = {}
    for site, stats in raw.items():
        if "elems" not in stats:
            # pseudo-site (router counters): pass values through — scalar
            # leaves as floats, vector leaves (per-expert) as lists
            passthru = {}
            for k, v in stats.items():
                a = np.asarray(v)
                passthru[k] = a.tolist() if a.ndim else float(a)
            out[site] = passthru
            continue
        vals = {k: float(np.asarray(v)) for k, v in stats.items()}
        elems = max(vals["elems"], 1.0)
        tokens = max(vals["tokens"], 1.0)
        smin = max(vals["scale_min"], 1e-30)
        out[site] = {
            **vals,
            "clip_rate": vals["clipped"] / elems,
            "sat_rate": vals["saturated"] / elems,
            "hi_coverage": vals["hi_tokens"] / tokens,
            "scale_log2_range": float(np.log2(max(vals["scale_max"], smin)
                                              / smin)),
        }
    return out

"""Deterministic fault injection for the paged serving engine.

A :class:`FaultPlan` is a *seeded schedule* of failures the engine, the
block allocator, and the swap layer consult at well-defined points:

* **page exhaustion** — the allocator reports "no free pages" on the steps
  the plan names (or draws, at ``exhaust_rate``, from a counter-keyed
  PRNG), driving real preemption storms through the production preemption
  path rather than a mocked one;
* **swap-in corruption** — the host copy of a preempted request's pages is
  bit-flipped before ``insert_pages`` restores it; the per-swap CRC32
  checksums recorded by ``extract_pages`` must refuse the restore
  (`paged_kvcache.SwapCorruption`), and the engine must fail exactly that
  request;
* **device-step NaN/Inf** — a chosen request's logits row is overwritten
  with NaN after the device step, exercising the ``ServeConfig.
  numerics_guard`` quarantine (and, on fused engines, the
  fused→reference demotion);
* **prefix-cache flush** — the allocator's prefix cache is dropped whole
  (``BlockAllocator.flush_cache``) on the steps the plan names, an
  eviction storm proving that requests already sharing cached pages keep
  their references, finish bit-identically, and leak nothing once the
  registrations under them disappear.

Every decision is a pure function of ``(seed, fault kind, event
ordinal)`` — never of wall-clock time or host state — so a chaos run is
exactly reproducible: the chaos tests replay a plan twice and pin the
surviving requests' tokens bit-for-bit against a fault-free run.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Optional, Tuple

import numpy as np

# kind codes folded into the per-decision PRNG seed so the fault
# streams are independent even at equal ordinals
_KIND_EXHAUST, _KIND_CORRUPT, _KIND_NAN, _KIND_FLUSH = 1, 2, 3, 4


def _draw(seed: int, kind: int, *key: int) -> float:
    """One uniform [0, 1) draw keyed by (seed, kind, event ordinal) — the
    same event always draws the same number, independent of call order."""
    return float(np.random.default_rng((seed, kind) + key).random())


@dataclasses.dataclass
class FaultPlan:
    """Seeded deterministic fault schedule (see module docstring).

    Explicit schedules (``exhaust_steps`` / ``corrupt_swap_ins`` /
    ``nan_faults``) fire regardless of the rates; the ``*_rate`` fields add
    seeded random faults on top, restricted to engine steps inside
    ``window`` (``[start, end)``; ``None`` = every step).  ``injected``
    counts what actually fired, for tests and the engine's event trace.
    """

    seed: int = 0
    # -- explicit schedules -------------------------------------------------
    exhaust_steps: FrozenSet[int] = frozenset()    # engine step numbers
    corrupt_swap_ins: FrozenSet[int] = frozenset()  # swap-in ordinals, 0-based
    nan_faults: FrozenSet[Tuple[int, int]] = frozenset()  # (uid, gen_index)
    flush_prefix_steps: FrozenSet[int] = frozenset()  # engine step numbers
    # -- seeded rates -------------------------------------------------------
    exhaust_rate: float = 0.0
    corrupt_rate: float = 0.0
    nan_rate: float = 0.0
    flush_rate: float = 0.0
    window: Optional[Tuple[int, int]] = None       # steps [start, end)

    def __post_init__(self):
        self.exhaust_steps = frozenset(int(s) for s in self.exhaust_steps)
        self.corrupt_swap_ins = frozenset(int(n)
                                          for n in self.corrupt_swap_ins)
        self.nan_faults = frozenset((int(u), int(g))
                                    for u, g in self.nan_faults)
        self.flush_prefix_steps = frozenset(int(s)
                                            for s in self.flush_prefix_steps)
        self._step = 0
        self._swap_ins = 0
        self._counted_steps: set = set()
        self._flushed_steps: set = set()
        self.injected = {"exhaustion": 0, "swap_corruption": 0, "nan": 0,
                         "prefix_flush": 0}

    # ------------------------------------------------------------------
    def begin_step(self, step: int) -> None:
        """Engine calls this once per scheduler step, before planning."""
        self._step = int(step)

    def _in_window(self) -> bool:
        return self.window is None or \
            self.window[0] <= self._step < self.window[1]

    # ------------------------------------------------------------------
    def exhausted(self) -> bool:
        """True when the allocator must report exhaustion this step.
        Stable within a step (keyed on the step number), so every
        ``can_allocate`` probe of one plan sees the same answer."""
        hit = self._step in self.exhaust_steps or (
            self._in_window() and self.exhaust_rate > 0.0 and
            _draw(self.seed, _KIND_EXHAUST, self._step) < self.exhaust_rate)
        if hit and self._step not in self._counted_steps:
            self._counted_steps.add(self._step)
            self.injected["exhaustion"] += 1
        return hit

    def flush_prefix(self) -> bool:
        """True when the prefix cache must be dropped this step.  Stable
        per step (and counted once), like `exhausted` — the engine calls it
        in its plan phase and runs ``alloc.flush_cache()`` on a hit."""
        hit = self._step in self.flush_prefix_steps or (
            self._in_window() and self.flush_rate > 0.0 and
            _draw(self.seed, _KIND_FLUSH, self._step) < self.flush_rate)
        if hit and self._step not in self._flushed_steps:
            self._flushed_steps.add(self._step)
            self.injected["prefix_flush"] += 1
        return hit

    def corrupt_swap(self, uid: int) -> bool:
        """Called once per swap-in (ordinal counter): corrupt this one?"""
        n = self._swap_ins
        self._swap_ins += 1
        hit = n in self.corrupt_swap_ins or (
            self._in_window() and self.corrupt_rate > 0.0 and
            _draw(self.seed, _KIND_CORRUPT, n) < self.corrupt_rate)
        if hit:
            self.injected["swap_corruption"] += 1
        return hit

    def nan_logits(self, uid: int, gen_index: int) -> bool:
        """Overwrite this request's logits with NaN at its
        ``gen_index``-th generated token?  Keyed on (uid, gen_index), not
        the step number, so the targeted token is schedule-independent —
        the same request NaNs at the same point under any contention."""
        hit = (uid, gen_index) in self.nan_faults or (
            self._in_window() and self.nan_rate > 0.0 and
            _draw(self.seed, _KIND_NAN, uid, gen_index) < self.nan_rate)
        if hit:
            self.injected["nan"] += 1
        return hit


def corrupt_swapped(swapped: dict, seed: int) -> dict:
    """Deep-copy a swap-out dict and flip one byte of the first non-empty
    saved array (sorted key order, so the choice is deterministic given the
    seed picks only the byte index).  Simulates host-RAM / transfer
    corruption while the request sat preempted; ``insert_pages`` must catch
    it via the recorded checksums, never restore the garbage."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    target = None
    for layer_key in sorted(swapped):
        layer = swapped[layer_key]
        if not isinstance(layer, dict):
            out[layer_key] = layer
            continue
        copied = {}
        for name in sorted(layer):
            arr = layer[name]
            arr = np.asarray(arr).copy() if isinstance(arr, np.ndarray) \
                else arr
            copied[name] = arr
            if target is None and isinstance(arr, np.ndarray) \
                    and arr.nbytes > 0 and layer_key != "__crc__":
                target = arr
        out[layer_key] = copied
    if target is None:
        raise ValueError("nothing to corrupt: swap dict holds no array data")
    flat = target.reshape(-1).view(np.uint8)
    flat[int(rng.integers(flat.size))] ^= 0xFF
    return out

"""Mixed-precision quantized KV cache — STaMP's W4A4**KV4**(+64@8b) setting.

Layout per attention stack (stacked over scan periods ``P``):

* ``k_hi / v_hi``    — ``(P, b, num_hi, kv, hd)`` **int8** — the first
  ``num_hi`` (=64) tokens, kept at 8 bits (§B.2: the attention-sink token and
  its neighbours carry massive outliers).
* ``k_lo / v_lo``    — ``(P, b, s−num_hi, kv, hd/2)`` **uint8**, two int4
  nibbles packed along ``head_dim``.
* ``*_scale, *_zp``  — ``(P, b, s, kv)`` float16 per-token/per-head dynamic
  quantization params (§B.2: per token, sequence and head; f16 is exact for
  zp ≤ 255 and halves metadata traffic — §Perf decode iter 7).  zp lands in
  [0, 255] whenever a token's values span zero (the typical K/V case); a
  one-sided token far from zero can push zp past f16's 2048 exact-integer
  range, degrading gracefully to f16 rounding of the zero point.

Effective width: (64·8 + (s−64)·4)/s ≈ 4.008 bits at s=32k — the paper's
4.125 at s=2k.  The sequence axis is sharded over the ``model`` mesh axis
(context-parallel decode); all pack/unpack ops are token-local so the layout
shards cleanly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    quantized: bool = True
    num_hi: int = 64
    hi_bits: int = 8
    lo_bits: int = 4


# ---------------------------------------------------------------------------
# token-level quant/dequant + nibble packing
# ---------------------------------------------------------------------------


def quant_tokens(x: Array, bits: int) -> tuple[Array, Array, Array]:
    """Per-(token, head) asymmetric min-max quant over head_dim.
    x: (..., kv, hd) → (q float-valued ints, scale, zp) with scale/zp
    reduced over hd."""
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=-1)
    mx = jnp.max(xf, axis=-1)
    n = float(2**bits - 1)
    scale = jnp.maximum((mx - mn) / n, _EPS)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(xf / scale[..., None]) + zp[..., None], 0.0, n)
    return q, scale, zp


def to_signed8(q: Array, zp: Array) -> tuple[Array, Array]:
    """Shift unsigned 8-bit codes (0..255) into int8 storage (−128..127);
    shifting the zero point identically keeps ``(q − zp)·s`` unchanged."""
    return (q - 128.0).astype(jnp.int8), zp - 128.0


def pack_nibbles(q: Array) -> Array:
    """(..., hd) int values in [0,15] → (..., hd/2) uint8."""
    hi = q[..., 0::2].astype(jnp.uint8)
    lo = q[..., 1::2].astype(jnp.uint8)
    return (hi << 4) | lo


def unpack_nibbles(p: Array) -> Array:
    """(..., hd/2) uint8 → (..., hd) float ints in [0,15]."""
    hi = (p >> 4).astype(jnp.float32)
    lo = (p & 0xF).astype(jnp.float32)
    out = jnp.stack([hi, lo], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def dequant_tokens(q: Array, scale: Array, zp: Array, dtype=jnp.bfloat16) -> Array:
    return ((q - zp[..., None]) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# cache init / bulk write (prefill) / single write (decode) / read
# ---------------------------------------------------------------------------


def init_layer_cache(
    periods: int, batch: int, seq: int, kv_heads: int, head_dim: int,
    cfg: KVCacheConfig,
) -> dict:
    """Zero cache for one attention position in the period pattern."""
    if not cfg.quantized:
        return {
            "k": jnp.zeros((periods, batch, seq, kv_heads, head_dim), jnp.bfloat16),
            "v": jnp.zeros((periods, batch, seq, kv_heads, head_dim), jnp.bfloat16),
        }
    hi = min(cfg.num_hi, seq)
    lo = seq - hi
    def mk(dtype, *shape):
        return jnp.zeros(shape, dtype)
    return {
        "k_hi": mk(jnp.int8, periods, batch, hi, kv_heads, head_dim),
        "v_hi": mk(jnp.int8, periods, batch, hi, kv_heads, head_dim),
        "k_lo": mk(jnp.uint8, periods, batch, lo, kv_heads, head_dim // 2),
        "v_lo": mk(jnp.uint8, periods, batch, lo, kv_heads, head_dim // 2),
        # f16 scales/zero-points: zp ≤ 255 and minmax scales are exact
        # enough in f16; halves the per-token metadata traffic (§Perf)
        "k_scale": mk(jnp.float16, periods, batch, seq, kv_heads),
        "k_zp": mk(jnp.float16, periods, batch, seq, kv_heads),
        "v_scale": mk(jnp.float16, periods, batch, seq, kv_heads),
        "v_zp": mk(jnp.float16, periods, batch, seq, kv_heads),
    }


def quantize_full(k: Array, v: Array, cfg: KVCacheConfig,
                  capacity: Optional[int] = None) -> dict:
    """Prefill path: quantize a complete (b, s, kv, hd) K/V pair into the
    cache layout (without the periods axis — caller stacks).  ``capacity``
    reserves room for subsequent decode tokens (zero-padded tail)."""
    if not cfg.quantized:
        kk = k.astype(jnp.bfloat16)
        vv = v.astype(jnp.bfloat16)
        if capacity and capacity > k.shape[1]:
            pad = [(0, 0), (0, capacity - k.shape[1]), (0, 0), (0, 0)]
            kk, vv = jnp.pad(kk, pad), jnp.pad(vv, pad)
        return {"k": kk, "v": vv}
    s = k.shape[1]
    cap = max(capacity or s, s)
    hi = min(cfg.num_hi, s)
    hi_cap = min(cfg.num_hi, cap)
    out = {}
    for name, t in (("k", k), ("v", v)):
        q_hi, sc_hi, zp_hi = quant_tokens(t[:, :hi], cfg.hi_bits)
        q_lo, sc_lo, zp_lo = quant_tokens(t[:, hi:], cfg.lo_bits)
        hi_buf, zp_hi = to_signed8(q_hi, zp_hi)
        lo_buf = pack_nibbles(q_lo)
        sc = jnp.concatenate([sc_hi, sc_lo], axis=1)
        zp = jnp.concatenate([zp_hi, zp_lo], axis=1)
        if cap > s:
            hi_buf = jnp.pad(hi_buf, [(0, 0), (0, hi_cap - hi),
                                      (0, 0), (0, 0)])
            lo_buf = jnp.pad(lo_buf, [(0, 0), (0, (cap - hi_cap) -
                                               lo_buf.shape[1]),
                                      (0, 0), (0, 0)])
            sc = jnp.pad(sc, [(0, 0), (0, cap - s), (0, 0)],
                         constant_values=1.0)
            zp = jnp.pad(zp, [(0, 0), (0, cap - s), (0, 0)])
        out[f"{name}_hi"] = hi_buf
        out[f"{name}_lo"] = lo_buf
        out[f"{name}_scale"] = sc.astype(jnp.float16)
        out[f"{name}_zp"] = zp.astype(jnp.float16)
    return out


def dequantize_full(entry: dict, cfg: KVCacheConfig,
                    dtype=jnp.bfloat16) -> tuple[Array, Array]:
    """Cache slice (no periods axis) → bf16 (b, s, kv, hd) K and V.

    NOTE: concatenates the hi/lo regions along the (sharded) sequence axis —
    under GSPMD this reshards the entire cache by a 64-token offset every
    layer.  Decode should prefer :func:`dequantize_segments` + segment
    attention (§Perf iter 3); this path remains for tests/tools.
    """
    if not cfg.quantized:
        return entry["k"].astype(dtype), entry["v"].astype(dtype)
    (k_hi, v_hi), (k_lo, v_lo) = dequantize_segments(entry, cfg, dtype)
    k = jnp.concatenate([k_hi, k_lo], axis=1)
    v = jnp.concatenate([v_hi, v_lo], axis=1)
    return k, v


def dequantize_segments(entry: dict, cfg: KVCacheConfig, dtype=jnp.bfloat16):
    """((k_hi, v_hi), (k_lo, v_lo)) — no concatenation across the sharded
    sequence axis; the hi region (64 tokens) stays replicated/tiny."""
    outs = []
    for name in ("k", "v"):
        hi_len = entry[f"{name}_hi"].shape[1]
        sc, zp = entry[f"{name}_scale"], entry[f"{name}_zp"]
        hi = dequant_tokens(entry[f"{name}_hi"].astype(jnp.float32),
                            sc[:, :hi_len], zp[:, :hi_len], dtype)
        lo_q = unpack_nibbles(entry[f"{name}_lo"])
        lo = dequant_tokens(lo_q, sc[:, hi_len:], zp[:, hi_len:], dtype)
        outs.append((hi, lo))
    (k_hi, k_lo), (v_hi, v_lo) = outs
    return (k_hi, v_hi), (k_lo, v_lo)


def write_token(entry: dict, k_new: Array, v_new: Array, pos: Array,
                cfg: KVCacheConfig) -> dict:
    """Decode path: write one (b, 1, kv, hd) K/V at position ``pos``.

    ``pos`` is a scalar (lockstep batch — every slot at the same position)
    or a (b,) vector (continuous batching — each slot at its own length).
    Both the hi (int8) and lo (packed int4) regions are updated at a clamped
    index and the correct one selected on ``pos < num_hi`` — branch-free, so
    it lowers to two dynamic-update-slices under jit.
    """
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1

    def onehot_write(buf, token, write_pos, enabled):
        """Scatter one token along the (possibly GSPMD-sharded) sequence
        axis via a broadcast compare + select.  A dynamic-update-slice at a
        traced position on a sharded axis makes GSPMD all-gather the whole
        buffer (it cannot prove which shard is written); the one-hot form
        partitions perfectly — each shard touches only its local tile
        (§Perf decode iter 5).  Vector positions broadcast per batch row."""
        s = buf.shape[1]
        iota = jax.lax.broadcasted_iota(
            jnp.int32, (1, s) + (1,) * (buf.ndim - 2), 1)
        tail = (1,) * (buf.ndim - 1)
        wp = jnp.asarray(write_pos).reshape(-1, *tail)
        en = jnp.asarray(enabled).reshape(-1, *tail)
        hit = (iota == wp) & en
        return jnp.where(hit, token.astype(buf.dtype), buf)

    if not cfg.quantized:
        out = dict(entry)
        for name, t in (("k", k_new), ("v", v_new)):
            if per_slot:
                out[name] = onehot_write(entry[name], t, pos,
                                         jnp.asarray(True))
            else:
                out[name] = jax.lax.dynamic_update_slice_in_dim(
                    entry[name], t.astype(entry[name].dtype), pos, axis=1)
        return out

    out = dict(entry)
    hi_len = entry["k_hi"].shape[1]
    in_hi = pos < hi_len
    pos_lo = pos - hi_len
    # (b|1, 1, 1) for the per-(token, head) scale/zp selects
    in_hi_b = jnp.asarray(in_hi).reshape(-1, 1, 1)

    for name, t in (("k", k_new), ("v", v_new)):
        q8, sc8, zp8 = quant_tokens(t, cfg.hi_bits)
        q8, zp8 = to_signed8(q8, zp8)
        q4, sc4, zp4 = quant_tokens(t, cfg.lo_bits)
        out[f"{name}_hi"] = onehot_write(entry[f"{name}_hi"], q8, pos, in_hi)
        out[f"{name}_lo"] = onehot_write(entry[f"{name}_lo"],
                                         pack_nibbles(q4), pos_lo, ~in_hi)
        sc = jnp.where(in_hi_b, sc8, sc4)
        zp = jnp.where(in_hi_b, zp8, zp4)
        out[f"{name}_scale"] = onehot_write(entry[f"{name}_scale"], sc, pos,
                                            jnp.asarray(True))
        out[f"{name}_zp"] = onehot_write(entry[f"{name}_zp"], zp, pos,
                                         jnp.asarray(True))
    return out


def cache_bytes(entry: dict) -> int:
    return sum(int(v.size) * v.dtype.itemsize for v in jax.tree.leaves(entry))

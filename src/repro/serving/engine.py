"""Batched serving engine with STaMP quantization.

Request lifecycle: submit → length-bucketed admission → batched prefill
(STaMP activation quantization + mixed-precision KV cache write) → lockstep
batched decode → detach on EOS/max-tokens.  The engine keeps one cache per
active bucket; admission pads prompts to the bucket length so prefill stays
a single jit'd call (no shape churn).

This is the slot-batching design (vLLM-style continuous batching without
paging): honest for a single-host deployment and exactly what the decode
dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    bucket: int = 128             # prompt bucket length (pad to this)
    max_seq: int = 256            # cache capacity
    eos_id: int = -1              # <0 disables EOS stopping


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 ecfg: EngineConfig = EngineConfig()):
        if serve.stamp is not None and serve.stamp.enabled and \
                serve.stamp.execution == "fused":
            # hoist the fused sites' weights into cached int8 buffers once;
            # prefill then runs the integer kernel per STaMP linear and
            # decode dequantizes the same buffers (no bf16 weight copies
            # re-materialized per call).
            params = lm.prepare_fused_weights(params, serve.stamp)
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.ecfg = ecfg
        self.queue: List[Request] = []
        self._uid = 0
        serve = dataclasses.replace(serve, cache_capacity=ecfg.max_seq)
        self.serve = serve
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, serve))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg, serve))

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return self._uid

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests."""
        done: List[Request] = []
        while self.queue:
            batch = self.queue[: self.ecfg.max_batch]
            self.queue = self.queue[self.ecfg.max_batch:]
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        t0 = time.time()
        b = len(reqs)
        bucket = self.ecfg.bucket
        prompts = np.zeros((b, bucket), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-bucket:]
            prompts[i, bucket - len(p):] = p     # left-pad
        # NOTE: left-padding keeps the *last* position meaningful for the
        # next-token logits; the first-64-token high-precision region then
        # covers padding for short prompts — harmless (zero energy tokens).
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new_tokens for r in reqs)
        max_new = min(max_new, self.ecfg.max_seq - bucket)
        outs = np.zeros((b, max_new), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        alive = np.ones(b, bool)
        for step in range(max_new):
            outs[:, step] = np.where(alive, np.asarray(tok), 0)
            if self.ecfg.eos_id >= 0:
                alive &= outs[:, step] != self.ecfg.eos_id
                if not alive.any():
                    outs = outs[:, : step + 1]
                    break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(bucket + step))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        dt = time.time() - t0
        for i, r in enumerate(reqs):
            r.out_tokens = outs[i][: r.max_new_tokens]
            r.latency_s = dt
        return reqs

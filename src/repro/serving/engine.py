"""Serving engines with STaMP quantization: lockstep bucketed batching and
continuous batching over the block-paged mixed-precision cache.

Two engines share one request API (`submit` → `run` → completed
`Request`s with tokens + latency/TTFT):

* :class:`BucketedEngine` (alias ``ServingEngine``) — the slot-batching
  design: requests are grouped into fixed-size batches, prompts right-padded
  to the bucket length, prefill is one jit'd call and decode runs lockstep
  with **per-slot positions** (each request decodes at its own length, so
  padding never leaks into the math and the whole batch waits only on the
  longest *generation*, not on padded prompt positions).
* :class:`PagedServingEngine` — continuous batching: a
  `serving/scheduler.py` state machine admits/evicts requests every step
  against the block-paged cache (`serving/paged_kvcache.py`).  Prompts
  prefill in fixed-size chunks interleaved with the running decode batch
  (no bucket padding), requests join/leave the decode slot array at step
  granularity, and block exhaustion preempts the latest arrival by swapping
  its pages to host memory — resume is bit-identical, no recompute.

Both engines share the model entry points in `models/lm.py`; with
``stamp=None`` (or a prompt that fits one prefill chunk) they produce
token-identical greedy output, which the parity tests pin.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs import quantstats as QS
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Event, StepTimer
from repro.serving import paged_kvcache as PKV
from repro.serving.faults import FaultPlan, corrupt_swapped
from repro.serving.scheduler import (CANCELLED, PREFILLING, REJECTED, RUNNING,
                                     PrefillWork, SchedRequest, Scheduler,
                                     SchedulerConfig)


def _transform_window(stamp, chunk: int) -> int:
    """Transform-aware chunk-boundary window: a Haar DWT / WHT at L levels
    mixes tokens in blocks of 2^L, so non-final chunk ends align to that
    multiple (scheduler satellite).  Window > chunk cannot be aligned — the
    per-chunk transform spans the whole chunk, so there is no intra-chunk
    window to preserve (the documented fallback: no alignment)."""
    if stamp is None or not stamp.enabled or stamp.seq_transform == "none":
        return 1
    w = 2 ** stamp.resolved_levels(chunk)
    return w if w <= chunk else 1


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0
    ttft_s: float = 0.0           # submit → first token
    preemptions: int = 0
    submit_t: float = 0.0
    obs_submit_t: float = 0.0     # observability-clock submit stamp (the
    # engine clock owns deadlines/TTFT; histograms/events use this one)
    # lifecycle: "queued" until the request reaches exactly one terminal
    # state — "finished" | "failed" | "cancelled" | "rejected".  `error`
    # says why for the failed/rejected ones.  `out_tokens` carries the
    # partial generation for failed/cancelled requests (possibly empty).
    status: str = "queued"
    error: Optional[str] = None
    deadline_s: Optional[float] = None       # total submit→finish budget
    ttft_deadline_s: Optional[float] = None  # submit→first-token budget


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    bucket: int = 128             # prompt bucket length (pad to this)
    max_seq: int = 256            # cache capacity
    eos_id: int = -1              # <0 disables EOS stopping
    max_events: int = 4096        # event-trace ring buffer (0 = unbounded)
    # quant-telemetry clip rate above which a quant_clip_alert event is
    # emitted for the offending STaMP site (ServeConfig.quant_telemetry)
    clip_alert_threshold: float = 0.05


@dataclasses.dataclass
class PagedEngineConfig:
    max_slots: int = 8            # decode batch width (static jit shape)
    prefill_chunk: int = 128      # tokens per prefill chunk row
    max_seq: int = 256            # per-request length cap (table width)
    block_size: int = 16          # tokens per cache page
    num_hi_blocks: Optional[int] = None   # pool sizes; None = enough for
    num_lo_blocks: Optional[int] = None   # max_slots full-length requests
    eos_id: int = -1
    max_prefills: int = 2         # chunk spans per unified step (≥ 1)
    step_mode: str = "unified"    # "unified" (one program per step) |
    # "two_call" (the PR-3 prefill-then-decode pair, kept for parity tests
    # and A/B benchmarking — schedules exactly like the old engine)
    max_events: int = 4096        # event-trace ring buffer (0 = unbounded)
    # -- robustness / admission control --------------------------------
    max_waiting: Optional[int] = None  # bounded waiting queue (None = ∞)
    shed_policy: str = "reject_newest"  # "reject_newest" | "shed_oldest"
    # consecutive zero-span steps before the watchdog fails the request at
    # the head of the line (livelock backstop — 0 disables)
    watchdog_steps: int = 8
    # on a NaN/Inf quarantine under a fused STaMP config, demote the whole
    # engine to reference execution (original bf16 weights, no integer
    # kernels) — the slow-but-safe escape hatch for saturating activations
    demote_on_nan: bool = True
    # forwarded to SchedulerConfig.preempt_watermark (< 1.0 enables)
    preempt_watermark: float = 1.0
    # hash-addressed prefix reuse across requests (ref-counted page
    # sharing + copy-on-write; see BlockAllocator).  Cache-on output is
    # bit-identical to cache-off — matches restart prefill on the same
    # chunk boundaries the cache-off engine would use — so it defaults
    # on.  Auto-disabled on stacks with Mamba layers (recurrent state
    # cannot skip past cached tokens) and pure-SSM stacks (no pages).
    prefix_caching: bool = True
    # quant-telemetry clip rate above which a quant_clip_alert event is
    # emitted for the offending STaMP site (ServeConfig.quant_telemetry)
    clip_alert_threshold: float = 0.05


class _EngineBase:
    """Shared request plumbing: fused-weight preparation + submit queue +
    the observability surface both engines expose identically
    (``metrics`` registry, ``stats`` view, ``events`` ring of typed
    :class:`Event` records, step-phase timer).

    ``clock`` is the engine's *semantic* time source (default
    ``time.perf_counter``): deadlines, `Request.ttft_s`/`latency_s`.
    Injectable so deadline tests and the degraded-mode bench advance time
    deterministically instead of sleeping.  ``obs_clock`` is a SEPARATE
    source for event timestamps and phase/latency histograms — adding
    observability must never change how often the semantic clock is read
    (an injected tick-clock test would otherwise measure different
    deadlines with telemetry on).  Event appends read no clock at all:
    they reuse ``_obs_now``, cached at tick points (submit, step-phase
    boundaries)."""

    # every legacy ``stats`` key, now a registry counter; the dict-shaped
    # ``stats`` property renders exactly these
    STAT_KEYS = ("steps", "decode_tokens", "prefill_chunks", "preemptions",
                 "device_dispatches", "recompiles", "swap_bytes",
                 "finished", "failed", "cancelled", "rejected", "shed",
                 "deadline_misses", "nan_quarantines", "demotions",
                 "watchdog_trips", "stalled_steps", "swap_corruptions",
                 "prefix_cache_queries", "prefix_cache_hits",
                 "prefix_tokens_reused", "cow_copies")

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 clock: Optional[Callable[[], float]] = None,
                 obs_clock: Optional[Callable[[], float]] = None):
        self._clock = clock if clock is not None else time.perf_counter
        self._obs_clock = obs_clock if obs_clock is not None \
            else time.perf_counter
        self._obs_now = 0.0
        self._step_i = 0
        self.metrics = MetricsRegistry()
        for k in self.STAT_KEYS:
            self.metrics.counter(k, help=f"engine {k.replace('_', ' ')}")
        self._timer = StepTimer(self.metrics, self._tick,
                                on_phase=self._on_phase)
        self.events: collections.deque = collections.deque()
        # the pre-`prepare_fused_weights` weights: fused preparation merges
        # wq/wk/wv into one int8 wqkv (destructively, per site), so demoting
        # a misbehaving engine back to reference execution needs this copy
        self._raw_params = params
        if serve.stamp is not None and serve.stamp.enabled and \
                serve.stamp.execution == "fused":
            # hoist every fused site's weights into cached int8 buffers once
            # (lm.FUSED_SITES: merged QKV+bias, attention out-proj, gate/up
            # pairs, MLP down, mamba in/out); prefill then runs the integer
            # kernels per STaMP linear — the gate/up pair through ONE
            # dual-output call — and decode consumes the same buffers
            # through the single-token integer kernel
            # (kernels/decode_matmul.py) instead of re-dequantizing them to
            # bf16 every step.
            params = lm.prepare_fused_weights(params, serve.stamp)
            serve = dataclasses.replace(serve, fused_decode_matmul=True)
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self._uid = 0
        self._refresh_eligibility()

    def _refresh_eligibility(self) -> None:
        """Recompute the per-site fused/reference matrix for the *current*
        serve config (at construction, and again after a fused → reference
        demotion) and publish the ``reference_fallback_sites`` gauge so a
        silent fall-off-the-fused-path shows up on the metrics surface, not
        just in step latency."""
        self.eligibility = lm.fused_site_matrix(self.cfg, self.serve.stamp)
        n_ref = sum(1 for c in self.eligibility.values()
                    if c["status"] == "reference")
        self.metrics.gauge(
            "reference_fallback_sites",
            help="linear sites running the reference (non-fused) path"
        ).set(n_ref)

    # -- observability core ---------------------------------------------
    def _init_events(self, max_events: int) -> None:
        """Size the event ring: unbounded growth over a long serving run
        is a memory leak, so the trace keeps the newest ``max_events``."""
        self.events = collections.deque(
            maxlen=max_events if max_events > 0 else None)

    def _tick(self) -> float:
        """Advance + cache the observability clock.  Everything between
        two ticks (event appends above all) shares the cached stamp, so
        instrumenting a new event never costs a clock read."""
        self._obs_now = self._obs_clock()
        return self._obs_now

    def _event(self, kind: str, uid: Optional[int] = None,
               dur: Optional[float] = None, phase: Optional[str] = None,
               **fields) -> None:
        self.events.append(Event(step=self._step_i, kind=kind, uid=uid,
                                 t=self._obs_now, dur=dur, phase=phase,
                                 fields=fields))

    def _on_phase(self, name: str, t0: float, dur: float) -> None:
        self.events.append(Event(step=self._step_i, kind="phase",
                                 t=t0, dur=dur, phase=name))

    def _inc(self, stat: str, n: int = 1) -> None:
        self.metrics.counter(stat).inc(n)

    @property
    def stats(self) -> Dict[str, int]:
        """The legacy dict view over the registry counters (read-only
        snapshot — mutate through the registry / ``reset_stats``), plus
        the ``reference_fallback_sites`` eligibility gauge."""
        out = {k: int(self.metrics.counter(k).value)
               for k in self.STAT_KEYS}
        out["reference_fallback_sites"] = int(
            self.metrics.gauge("reference_fallback_sites").value)
        return out

    def reset_stats(self, keep: tuple = ("recompiles",),
                    clear_events: bool = False) -> None:
        """Zero every metric except ``keep`` (default: the cumulative
        compile counter, which warmup legitimately owns), optionally
        clearing the event ring — the benchmark warmup/measure boundary
        for BOTH engines."""
        self.metrics.reset(exclude=keep)
        self._refresh_eligibility()   # reset() zeroes gauges; re-publish
        self._refresh_derived_gauges()
        if clear_events:
            self.events.clear()

    def _refresh_derived_gauges(self) -> None:
        """Hook for gauges derived from live engine state (same recompute
        rule as ``reference_fallback_sites``): re-published after any
        ``metrics.reset`` so a warmup/measure boundary never zeroes what
        the state still says.  The paged engine recomputes its
        prefix-cache gauges here; the base has none."""

    def _observe_latency(self, name: str, seconds: float) -> None:
        self.metrics.histogram(name, help=f"request {name}").observe(
            max(seconds, 0.0))

    def _absorb_telemetry(self, raw) -> None:
        """Fold one step's quant-telemetry site dict into the registry:
        monotonic counters for the raw counts, gauges for the per-step
        rates, and a ``quant_clip_alert`` event for any site whose clip
        rate crosses the config threshold."""
        if not raw:
            return
        raw = dict(raw)
        router = raw.pop("moe_router", None)
        if router is not None:
            self._absorb_router_stats(router)
        summ = QS.summarize(raw)
        thresh = getattr(self.ecfg, "clip_alert_threshold", 0.05)
        for site, s in summ.items():
            lbl = {"site": site}
            for key in ("clipped", "saturated", "elems", "hi_tokens",
                        "tokens"):
                self.metrics.counter(
                    f"quant_{key}_total", labels=lbl,
                    help=f"quant telemetry: cumulative {key}").inc(s[key])
            for key in ("clip_rate", "sat_rate", "hi_coverage",
                        "scale_log2_range"):
                self.metrics.gauge(
                    f"quant_{key}", labels=lbl,
                    help=f"quant telemetry: last-step {key}").set(s[key])
            if s["clip_rate"] > thresh:
                self.metrics.counter(
                    "quant_clip_alerts", labels=lbl,
                    help="clip-rate threshold crossings").inc()
                self._event("quant_clip_alert", site=site,
                            clip_rate=s["clip_rate"], threshold=thresh)

    def _absorb_router_stats(self, router: dict) -> None:
        """Publish the MoE router's load counters (recorded by `moe_route`
        under the ``moe_router`` pseudo-site): per-expert load-balance
        gauges, the cumulative dropped-token counter, and the step's
        capacity occupancy / drop rate."""
        expert_tokens = np.asarray(router.get("expert_tokens", []),
                                   np.float64).reshape(-1)
        dropped = float(np.asarray(router.get("dropped_tokens", 0.0)))
        slots = float(np.asarray(router.get("capacity_slots", 0.0)))
        for i, n in enumerate(expert_tokens):
            self.metrics.gauge(
                "moe_expert_tokens", labels={"expert": str(i)},
                help="MoE router: tokens dispatched to this expert "
                     "(last step, summed over layers)").set(float(n))
        self.metrics.counter(
            "moe_dropped_tokens",
            help="MoE router: cumulative capacity-dropped tokens").inc(
            dropped)
        routed = float(expert_tokens.sum())
        self.metrics.gauge(
            "moe_capacity_occupancy",
            help="MoE router: kept tokens / capacity slots (last step)"
        ).set(routed / slots if slots > 0 else 0.0)
        total = routed + dropped
        self.metrics.gauge(
            "moe_drop_rate",
            help="MoE router: dropped / (kept + dropped) (last step)"
        ).set(dropped / total if total > 0 else 0.0)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None) -> int:
        """Queue one request; returns its uid.

        Malformed inputs fail fast HERE with an actionable ValueError —
        an empty prompt, a non-positive token budget, or a prompt the
        engine's tables cannot hold would otherwise surface as an opaque
        kernel shape error (or silent truncation) steps later.  Deadlines
        are budgets in clock seconds from this call; the paged engine
        fails the request at the first planning step past the budget.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token")
        if max_new_tokens <= 0:
            raise ValueError(f"max_new_tokens must be positive, got "
                             f"{max_new_tokens}")
        limit = self._max_prompt_len()
        if prompt.size > limit:
            raise ValueError(
                f"prompt length {prompt.size} exceeds the engine's limit of "
                f"{limit} tokens (raise max_seq, or chunk the prompt)")
        self._uid += 1
        # perf_counter, not time.time: TTFT / latency are *intervals*, and
        # wall-clock steps (NTP slew) would skew the bench percentiles
        req = Request(self._uid, prompt, max_new_tokens,
                      submit_t=self._clock(), obs_submit_t=self._tick(),
                      deadline_s=deadline_s,
                      ttft_deadline_s=ttft_deadline_s)
        self._event("submit", uid=req.uid, prompt_len=int(prompt.size))
        self._enqueue(req)
        return self._uid

    def _max_prompt_len(self) -> int:
        raise NotImplementedError

    def _enqueue(self, req: Request) -> None:
        raise NotImplementedError


class BucketedEngine(_EngineBase):
    """Lockstep slot-batching (the pre-paging design, kept as the simple
    baseline, the numerics oracle, and the only engine covering enc-dec
    cross-attention caches)."""

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 ecfg: Optional[EngineConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 obs_clock: Optional[Callable[[], float]] = None):
        super().__init__(params, cfg, serve, clock=clock,
                         obs_clock=obs_clock)
        # NOTE: default constructed per instance — a dataclass default
        # instance in the signature would be shared across engines (mutable
        # default), letting one engine's config edits leak into another.
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        self._init_events(self.ecfg.max_events)
        self.queue: List[Request] = []
        serve = dataclasses.replace(self.serve,
                                    cache_capacity=self.ecfg.max_seq)
        self.serve = serve
        self._collect = lm._collect_telemetry(serve)
        cfgm = self.cfg
        self._prefill = jax.jit(
            lambda p, b, lp: lm.prefill(p, b, cfgm, serve, last_pos=lp))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfgm, serve))

    def _max_prompt_len(self) -> int:
        # the bucket is the prompt capacity; one position must stay free
        # for the first generated token's K/V write
        return min(self.ecfg.bucket, self.ecfg.max_seq - 1)

    def _enqueue(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests."""
        done: List[Request] = []
        while self.queue:
            batch = self.queue[: self.ecfg.max_batch]
            self.queue = self.queue[self.ecfg.max_batch:]
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        t0 = self._clock()
        b = len(reqs)
        bucket = self.ecfg.bucket
        self._step_i += 1
        self._inc("steps")
        with self._timer.phase("plan"):
            prompts = np.zeros((b, bucket), np.int32)
            lens = np.zeros((b,), np.int32)
            for i, r in enumerate(reqs):
                p = r.prompt[-bucket:]
                prompts[i, : len(p)] = p          # right-pad
                lens[i] = len(p)
            for r in reqs:
                self._event("admit", uid=r.uid)
                self._observe_latency("queue_wait_s",
                                      self._obs_now - r.obs_submit_t)
        # Right-padding + per-slot decode positions: pad tokens sit AFTER
        # every prompt position, so causal attention never sees them, the
        # next-token logits are read at each row's true last token, and the
        # first generated token overwrites the pad K/V at position len —
        # the output is identical to serving the request unpadded (and to
        # the paged engine's chunked prefill of the same prompt).
        with self._timer.phase("dispatch"):
            out = self._prefill(self.params,
                                {"tokens": jnp.asarray(prompts)},
                                jnp.asarray(lens - 1))
            if self._collect:
                logits, cache, telem = out
            else:
                logits, cache = out
                telem = None
            self._inc("device_dispatches")
            self._inc("prefill_chunks", b)
            max_new = max(r.max_new_tokens for r in reqs)
            max_new = min(max_new, self.ecfg.max_seq - int(lens.max()))
            outs = np.zeros((b, max_new), np.int32)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # force the async-dispatched prefill before timestamping, so
            # TTFT measures execution (as the paged engine's np.argmax
            # does)
            jax.block_until_ready(tok)
        if telem is not None:
            self._absorb_telemetry(telem)
        t_first = self._clock()
        for r in reqs:
            r.ttft_s = t_first - r.submit_t
            self._event("first_token", uid=r.uid)
            self._observe_latency("ttft_s", self._obs_now - r.obs_submit_t)
        alive = np.ones(b, bool)
        for step in range(max_new):
            outs[:, step] = np.where(alive, np.asarray(tok), 0)
            if self.ecfg.eos_id >= 0:
                alive &= outs[:, step] != self.ecfg.eos_id
                if not alive.any():
                    outs = outs[:, : step + 1]
                    break
            with self._timer.phase("dispatch"):
                self._step_i += 1
                self._inc("steps")
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.asarray(lens + step))
                self._inc("device_dispatches")
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self._inc("decode_tokens", int(alive.sum()))
        dt = self._clock() - t0
        self._tick()
        for i, r in enumerate(reqs):
            r.out_tokens = outs[i][: r.max_new_tokens]
            r.latency_s = dt
            r.status = "finished"
            self._inc("finished")
            self._event("finish", uid=r.uid)
            self._observe_latency("latency_s",
                                  self._obs_now - r.obs_submit_t)
        return reqs


# backward-compatible name: the bucketed engine is the original design
ServingEngine = BucketedEngine


class PagedServingEngine(_EngineBase):
    """Continuous batching over the block-paged mixed-precision cache.

    Each engine step the scheduler admits waiting requests into free slots
    and reserves pages (preempting later arrivals on exhaustion), then the
    whole step's work — up to ``max_prefills`` prefill chunks AND the
    decode slot array — runs as **one ragged batched forward**
    (`lm.paged_unified_step`): every step dispatches exactly one device
    program and streams the weights once, where the two-call design paid
    two dispatches and two cold weight passes on every mixed step while
    decode slots idled during prefill.  Shapes are bucketed on the number
    of chunk rows (0, 1, 2, 4, … up to ``max_prefills``), so the compile
    count per engine lifetime is fixed (``stats["recompiles"]`` /
    :meth:`compile_count`; the first/continuation-chunk distinction is a
    traced mask, not a shape).  ``step_mode="two_call"`` keeps the PR-3
    prefill-then-decode pair — scheduling-identical (one chunk per step,
    no boundary alignment) — as the parity oracle and A/B baseline.
    ``events`` records the admission / join / leave / preemption trace in
    a ring buffer capped at ``max_events``.

    **Hybrid and pure-SSM stacks are first-class**: Mamba layers keep
    their recurrent state in a slot-dense pool next to the paged K/V
    (fixed bytes per slot — `stats` surface it via the scheduler's
    ``state_bytes_per_slot``), prefill chunks carry conv/SSM state across
    chunk boundaries through the request's slot row, decode advances the
    recurrence with inactive slots masked, and preemption swaps the slot
    state to host together with the victim's pages (bit-identical
    resume).  A stack with no attention layers skips page reservation
    entirely — slots are then the only capacity dimension.  Enc-dec
    stacks still need :class:`BucketedEngine`.
    """

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 ecfg: Optional[PagedEngineConfig] = None,
                 fault: Optional[FaultPlan] = None,
                 clock: Optional[Callable[[], float]] = None,
                 obs_clock: Optional[Callable[[], float]] = None):
        super().__init__(params, cfg, serve, clock=clock,
                         obs_clock=obs_clock)
        self.ecfg = ecfg if ecfg is not None else PagedEngineConfig()
        e = self.ecfg
        if e.shed_policy not in ("reject_newest", "shed_oldest"):
            raise ValueError(f"unknown shed_policy {e.shed_policy!r}")
        self.fault = fault
        quant = self.serve.kv
        num_hi = quant.num_hi if quant.quantized else 0
        if quant.quantized and num_hi % e.block_size:
            raise ValueError("num_hi must be a multiple of block_size")
        hi_per_seq = num_hi // e.block_size if quant.quantized else 0
        lo_per_seq = -(-(e.max_seq - num_hi) // e.block_size)
        n_hi = e.num_hi_blocks if e.num_hi_blocks is not None \
            else e.max_slots * hi_per_seq + 1
        n_lo = e.num_lo_blocks if e.num_lo_blocks is not None \
            else e.max_slots * lo_per_seq + 1
        self.pcfg = PKV.PagedCacheConfig(
            block_size=e.block_size, num_lo_blocks=n_lo,
            num_hi_blocks=max(n_hi, 1), max_blocks_per_seq=lo_per_seq,
            quant=quant)
        self.serve = dataclasses.replace(self.serve, paged=self.pcfg,
                                         cache_capacity=None)
        # stack composition decides the state families: attention layers
        # read/write the page pools, mamba layers the slot-dense SSM pool
        # (fixed-size per slot, no paging — its null slot is row max_slots).
        # Enc-dec stacks are the one remaining gap (init_paged_cache raises
        # the actionable NotImplementedError before any device allocation).
        pro, period, _ = cfg.layer_plan()
        specs = list(period) + list(pro)
        self._has_attn = any(s.mixer == "attn" for s in specs)
        self._has_mamba = any(s.mixer == "mamba" for s in specs)
        self.pools = lm.init_paged_cache(cfg, self.pcfg,
                                         num_slots=e.max_slots)
        if e.step_mode not in ("unified", "two_call"):
            raise ValueError(f"unknown step_mode {e.step_mode!r}")
        unified = e.step_mode == "unified"
        # prefix reuse skips prefill compute for cached tokens, which a
        # Mamba layer cannot (its recurrent state lives outside the page
        # pools and must advance through every token); pure-SSM stacks
        # have no pages to share at all
        self._prefix_on = bool(e.prefix_caching and self._has_attn
                               and not self._has_mamba)
        self.sched = Scheduler(
            SchedulerConfig(
                max_slots=e.max_slots, prefill_chunk=e.prefill_chunk,
                max_prefills=max(e.max_prefills, 1) if unified else 1,
                transform_window=_transform_window(
                    self.serve.stamp, e.prefill_chunk) if unified else 1,
                state_bytes_per_slot=PKV.ssm_state_bytes_per_slot(
                    self.pools),
                needs_kv_pages=self._has_attn,
                preempt_watermark=e.preempt_watermark,
                prefix_caching=self._prefix_on),
            self.pcfg, swap_out=self._swap_out, swap_in=self._swap_in,
            cow=self._cow_copy, on_prefix=self._on_prefix_lookup)
        if fault is not None:
            # the allocator consults the plan on every probe: injected
            # exhaustion flows through the REAL preemption/degradation
            # paths, not a mock
            self.sched.alloc.fault = fault.exhausted
        self._requests: Dict[int, Request] = {}
        self._init_events(e.max_events)
        self._stall = 0              # consecutive zero-span steps
        self._swap_failed: List[tuple] = []   # (sreq, error) from _swap_in
        self._terminal_done: List[Request] = []  # rejected/cancelled/failed
        self._demoted = False
        # shape buckets for the chunk-row count: 0 (all-decode), powers of
        # two, and max_prefills — the full set of compiled variants
        mp = max(e.max_prefills, 1) if unified else 1
        buckets = {0, mp}
        b = 1
        while b < mp:
            buckets.add(b)
            b *= 2
        self._npf_buckets = sorted(buckets)
        self._compiled_keys: set = set()
        self._build_step_fns()
        self._refresh_prefix_gauges()

    # -- prefix caching -------------------------------------------------
    def _on_prefix_lookup(self, sreq: SchedRequest, match) -> None:
        """Scheduler callback on every fresh-admission cache lookup."""
        self._inc("prefix_cache_queries")
        if match is None:
            return
        self._inc("prefix_cache_hits")
        self._inc("prefix_tokens_reused", match.matched)
        self._event("prefix_hit", uid=sreq.uid, matched=match.matched,
                    pages=len(match.hi_pages) + len(match.lo_pages))

    def _cow_copy(self, sreq: SchedRequest, pool: str, src: int,
                  dst: int) -> None:
        """Scheduler callback: device-copy one page before the request's
        first divergent write lands in it (partial-page prefix match)."""
        self.pools = PKV.copy_page(self.pools, pool, src, dst)
        self._inc("cow_copies")
        self._event("cow", uid=sreq.uid, pool=pool, src=src, dst=dst)

    def _refresh_prefix_gauges(self) -> None:
        """Publish the prefix-cache gauges from LIVE allocator state (and
        the hit-rate from the counters).  Like ``reference_fallback_sites``
        these are recomputed — never carried — so ``reset_stats`` and a
        fused → reference demotion cannot zero what the allocator still
        holds."""
        cs = self.sched.alloc.cache_stats()
        q = self.metrics.counter("prefix_cache_queries").value
        h = self.metrics.counter("prefix_cache_hits").value
        self.metrics.gauge(
            "prefix_cache_hit_rate",
            help="prefix cache: hits / lookups").set(h / q if q else 0.0)
        self.metrics.gauge(
            "kv_pages_shared",
            help="pages currently referenced by 2+ requests").set(
            cs["kv_pages_shared"])
        self.metrics.gauge(
            "sink_pages_pinned",
            help="hi-precision (int8 sink) pages cached AND referenced — "
                 "the mixed-precision cost a shared prefix pins for every "
                 "child").set(cs["sink_pages_pinned"])
        self.metrics.gauge(
            "prefix_cached_pages",
            help="pages registered in the prefix cache").set(
            cs["cached_pages"])

    def _refresh_derived_gauges(self) -> None:
        self._refresh_prefix_gauges()

    @property
    def stats(self) -> Dict[str, int]:
        out = _EngineBase.stats.fget(self)
        g = self.metrics.gauge
        out["prefix_cache_hit_rate"] = float(
            g("prefix_cache_hit_rate").value)
        out["kv_pages_shared"] = int(g("kv_pages_shared").value)
        out["sink_pages_pinned"] = int(g("sink_pages_pinned").value)
        out["prefix_cached_pages"] = int(g("prefix_cached_pages").value)
        return out

    def _build_step_fns(self) -> None:
        """(Re)build the jit'd step entry points from the CURRENT
        ``self.serve``.  Called at construction and again on fused →
        reference demotion, which swaps the params/serve config underneath
        (old compiled variants are dropped; the recompile counter starts
        over for the new config)."""
        self._compiled_keys = set()
        unified = self.ecfg.step_mode == "unified"
        cfgm, serve_p = self.cfg, self.serve
        # static: whether the step fns return an extra quant-telemetry
        # element (recomputed here so demotion keeps arity consistent
        # with the rebuilt serve config)
        self._collect = lm._collect_telemetry(serve_p)
        if unified:
            self._unified = jax.jit(
                lambda p, pools, pt, ps, pln, pf, pli, psl, dt, dp, da, ht,
                lt, pg, off, ih:
                lm.paged_unified_step(p, pools, pt, ps, pln, pf, pli, psl,
                                      dt, dp, da, ht, lt, pg, off, ih,
                                      cfgm, serve_p))
        else:
            self._prefill_first = jax.jit(
                lambda p, pools, t, s, ht, lt, pg, off, ih, li, sl:
                lm.paged_prefill_chunk(p, pools, t, s, ht, lt, pg, off, ih,
                                       li, cfgm, serve_p, first=True,
                                       slot=sl))
            self._prefill_cont = jax.jit(
                lambda p, pools, t, s, ht, lt, pg, off, ih, li, sl:
                lm.paged_prefill_chunk(p, pools, t, s, ht, lt, pg, off, ih,
                                       li, cfgm, serve_p, first=False,
                                       slot=sl))
            self._decode = jax.jit(
                lambda p, pools, t, pos, ht, lt, pg, off, ih, act:
                lm.paged_decode_step(p, pools, t, pos, ht, lt, pg, off, ih,
                                     cfgm, serve_p, active=act))

    def compile_count(self) -> int:
        """Compiled variants of the unified step this engine has built
        (shape-bucketed chunk-row counts).  Prefers jit's own lowering
        cache; falls back to the host-side bucket set."""
        fn = getattr(self, "_unified", None)
        if fn is not None and hasattr(fn, "_cache_size"):
            return fn._cache_size()
        return len(self._compiled_keys)

    # ------------------------------------------------------------------
    def _max_prompt_len(self) -> int:
        # one position stays free for the first generated token's K/V write
        return self.ecfg.max_seq - 1

    def _capacity_reason(self, req: Request) -> Optional[str]:
        """None if the request can EVER run to completion alone on this
        engine; otherwise why not.  The check mirrors the scheduler's
        reservation arithmetic: the deepest position it will reserve is
        ``prompt_len + gen - 1`` (the page for the last generated token's
        K/V write), so a request whose page demand at that position
        exceeds the whole pool would previously livelock or crash the
        step loop — now it never enters the queue."""
        if not self._has_attn:
            return None              # pure-SSM: slots are the only capacity
        plen = int(req.prompt.shape[0])
        gen = min(req.max_new_tokens, self.ecfg.max_seq - plen)
        nh, nl = PKV.pages_needed(plen + gen - 1, self.pcfg)
        cap_hi, cap_lo = self.sched.alloc.capacity()
        if nh > cap_hi or nl > cap_lo:
            # Credit the cached prefix before rejecting: the worst case
            # assumes the full max_new_tokens budget is spent, but warm
            # shared-prefix traffic routinely stops at EOS long before
            # that depth — rejecting it on the cold worst case alone
            # throws away exactly the requests the cache makes cheap.
            # Only FULLY shared pages count (a mid-page CoW divergence
            # nets zero: the copy costs the page the share saved).  A
            # credited request that does run to worst-case depth degrades
            # through the normal exhaustion path (preempt-self, watchdog)
            # instead of being refused up front.
            matched = self.sched.probe_prefix(req.prompt)
            bs = self.pcfg.block_size
            ch, cl = PKV.pages_needed(matched // bs * bs, self.pcfg)
            if nh - ch > cap_hi or nl - cl > cap_lo:
                return (f"capacity-infeasible: needs {nh} hi + {nl} lo "
                        f"pages at peak but the pools hold only {cap_hi} "
                        f"hi + {cap_lo} lo — the request could never run "
                        f"even alone")
        return None

    def _enqueue(self, req: Request) -> None:
        self._requests[req.uid] = req
        reason = self._capacity_reason(req)
        if reason is not None:
            self._terminate(req, REJECTED, reason, stat="rejected",
                            kind="reject")
            return
        e = self.ecfg
        if e.max_waiting is not None and \
                len(self.sched.waiting) >= e.max_waiting:
            if e.shed_policy == "shed_oldest":
                # prefer shedding a queued request that has not run at all
                # (a preempted one holds real generation progress)
                fresh = [r for r in self.sched.waiting if r.swapped is None
                         and r.pos == 0 and not r.generated]
                if fresh:
                    victim = fresh[0]
                    self.sched.cancel(victim.uid, state=REJECTED,
                                      error="shed: waiting queue full")
                    vreq = self._requests[victim.uid]
                    self._terminate(vreq, REJECTED,
                                    "shed: waiting queue full",
                                    stat="shed", kind="shed",
                                    sreq=victim)
                else:
                    self._terminate(req, REJECTED,
                                    "shed: waiting queue full",
                                    stat="shed", kind="shed")
                    return
            else:                    # reject_newest
                self._terminate(req, REJECTED,
                                f"waiting queue full "
                                f"({e.max_waiting} requests)",
                                stat="shed", kind="shed")
                return
        self.sched.submit(SchedRequest(
            uid=req.uid, prompt=req.prompt,
            max_new_tokens=req.max_new_tokens, arrival=req.uid))

    def _terminate(self, req: Request, status: str, error: Optional[str],
                   stat: str, kind: str,
                   sreq: Optional[SchedRequest] = None) -> None:
        """Move one Request to a terminal state outside the normal finish
        path (reject/shed/cancel/fail) and queue it for the caller's done
        list."""
        req.status = status
        req.error = error
        if req.out_tokens is None:
            gen = sreq.generated[: sreq.max_new_tokens] if sreq else []
            req.out_tokens = np.asarray(gen, np.int32)
        if sreq is not None:
            req.preemptions = sreq.preemptions
        req.latency_s = self._clock() - req.submit_t
        self._inc(stat)
        if error:
            self._event(kind, uid=req.uid, error=error)
        else:
            self._event(kind, uid=req.uid)
        self._observe_latency("latency_s", self._obs_now - req.obs_submit_t)
        self._terminal_done.append(req)

    def _swap_out(self, sreq: SchedRequest) -> None:
        # slot still assigned here (the scheduler swaps before it frees),
        # so the per-slot SSM state rides along with the pages
        sreq.swapped = PKV.extract_pages(self.pools, sreq.hi_pages,
                                         sreq.lo_pages, slot=sreq.slot)
        self._event("preempt", uid=sreq.uid)
        self._inc("preemptions")
        self._inc("swap_bytes", PKV.swapped_bytes(sreq.swapped))

    def _swap_in(self, sreq: SchedRequest) -> None:
        # sreq.slot is the NEW placement — SSM state restores there, pages
        # at whatever ids the allocator handed back (tables indirect)
        swapped = sreq.swapped
        if self.fault is not None and self.fault.corrupt_swap(sreq.uid):
            swapped = corrupt_swapped(swapped, self.fault.seed)
            self._event("fault_corrupt", uid=sreq.uid)
        try:
            self.pools = PKV.insert_pages(self.pools, swapped,
                                          sreq.hi_pages, sreq.lo_pages,
                                          slot=sreq.slot)
        except PKV.SwapCorruption as exc:
            # insert_pages verifies checksums BEFORE touching the pools, so
            # nothing was restored.  The scheduler is mid-_admit and will
            # finish placing this request; _step fails it (releasing the
            # just-granted slot/pages) right after plan_step returns —
            # everyone else keeps running.
            self._swap_failed.append((sreq, str(exc)))
            return
        self._event("resume", uid=sreq.uid)

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Drain the engine.  Every submitted request comes back in exactly
        one terminal state (`Request.status`); per-request problems —
        rejection, deadline miss, swap corruption, NaN quarantine, livelock
        — fail THAT request and never raise out of run()."""
        t0 = self._clock()
        done: List[Request] = []
        self._drain_terminal(done)   # submit-time rejects / early cancels
        while self.sched.has_work():
            self._step(done)
            self._drain_terminal(done)
        dt = self._clock() - t0
        for r in done:
            r.latency_s = r.latency_s or dt
        return done

    def _drain_terminal(self, done: List[Request]) -> None:
        if self._terminal_done:
            done.extend(self._terminal_done)
            self._terminal_done = []

    def cancel(self, uid: int) -> bool:
        """Terminate one request wherever it is — queued, mid-prefill,
        mid-decode, or preempted — releasing exactly the slot/pages it
        holds.  Partial tokens are kept on the Request.  Returns False for
        an unknown or already-terminal uid."""
        sreq = self.sched.cancel(uid)
        if sreq is None:
            return False
        self._terminate(self._requests[uid], CANCELLED, None,
                        stat="cancelled", kind="cancel", sreq=sreq)
        return True

    def request(self, uid: int) -> Optional[Request]:
        """The Request record for a uid (terminal or not)."""
        return self._requests.get(uid)

    def _fail(self, sreq: SchedRequest, error: str,
              kind: str = "fail") -> None:
        """Quarantine one scheduler request: release its resources, mark
        the Request failed, keep everyone else running."""
        self.sched.fail(sreq, error)
        self._terminate(self._requests[sreq.uid], "failed", error,
                        stat="failed", kind=kind, sreq=sreq)

    def _check_deadlines(self) -> None:
        """Plan-time deadline enforcement: a request past its total or
        TTFT budget fails BEFORE this step plans, so its pages/slot go to
        requests that can still meet theirs."""
        now = self._clock()
        for sreq in list(self.sched.active) + list(self.sched.waiting):
            req = self._requests[sreq.uid]
            waited = now - req.submit_t
            miss = None
            if req.deadline_s is not None and waited > req.deadline_s:
                miss = (f"deadline miss: {waited:.3f}s elapsed > "
                        f"{req.deadline_s:.3f}s total budget")
            elif req.ttft_deadline_s is not None and not sreq.generated \
                    and waited > req.ttft_deadline_s:
                miss = (f"deadline miss: no first token after "
                        f"{waited:.3f}s > {req.ttft_deadline_s:.3f}s "
                        f"TTFT budget")
            if miss is not None:
                self._inc("deadline_misses")
                self._event("deadline_miss", uid=sreq.uid)
                self._fail(sreq, miss)

    def _watchdog(self, progress: bool) -> None:
        """Livelock backstop: ``has_work()`` plus N consecutive zero-span
        steps means nothing can be placed or advanced (injected
        exhaustion, a resume that can never re-allocate, admission
        thrash).  Fail the request at the head of the line — the one FCFS
        is stuck behind — not the engine."""
        if progress:
            self._stall = 0
            return
        if not self.sched.has_work():
            return
        self._stall += 1
        self._inc("stalled_steps")
        n = self.ecfg.watchdog_steps
        if n <= 0 or self._stall < n:
            return
        self._stall = 0
        self._inc("watchdog_trips")
        blockers = sorted(self.sched.waiting + self.sched.active,
                          key=lambda r: (r.arrival, r.uid))
        if blockers:
            self._fail(blockers[0],
                       f"watchdog: no scheduling progress for {n} "
                       f"consecutive steps", kind="watchdog")

    # -- numerics guard -------------------------------------------------
    def _next_token(self, sreq: SchedRequest, row: np.ndarray) -> bool:
        """Greedy-sample one span's logits row, behind the NaN/Inf guard.
        Returns False when the request was quarantined instead."""
        if self.fault is not None and \
                self.fault.nan_logits(sreq.uid, len(sreq.generated)):
            row = np.full_like(row, np.nan)
            self._event("fault_nan", uid=sreq.uid)
        if self.serve.numerics_guard and not np.isfinite(row).all():
            self._quarantine(sreq, f"non-finite logits at generated index "
                                   f"{len(sreq.generated)}")
            return False
        sreq.generated.append(int(np.argmax(row)))
        return True

    def _quarantine(self, sreq: SchedRequest, error: str) -> None:
        self._inc("nan_quarantines")
        self._event("nan_quarantine", uid=sreq.uid)
        self._fail(sreq, error)
        self._maybe_demote()

    def _maybe_demote(self) -> None:
        """Fused → reference graceful degradation: after a NaN quarantine
        under a fused STaMP config, rebuild the engine on the retained
        original weights with reference-path execution (no integer
        kernels).  Slower, but an activation distribution that saturates
        the int4/int8 path cannot take the whole fleet slice with it.
        One-shot per engine; in-flight caches are kept (page layout does
        not depend on the execution path)."""
        st = self.serve.stamp
        if (not self.ecfg.demote_on_nan or self._demoted or st is None
                or not st.enabled or st.execution != "fused"):
            return
        self._demoted = True
        self.params = self._raw_params
        self.serve = dataclasses.replace(
            self.serve,
            stamp=dataclasses.replace(st, execution="reference"),
            fused_decode_matmul=False)
        self._build_step_fns()
        self._refresh_eligibility()
        self._refresh_prefix_gauges()
        self._inc("demotions")
        self._event("demote", to="reference")

    # ------------------------------------------------------------------
    def _tables_np(self, sreqs: List[SchedRequest]) -> tuple:
        """Host-built block tables over the full slot array (unmapped → 0)."""
        e, pc = self.ecfg, self.pcfg
        ht = np.zeros((e.max_slots, max(pc.hi_blocks_per_seq, 1)), np.int32)
        lt = np.zeros((e.max_slots, pc.max_blocks_per_seq), np.int32)
        for sreq in sreqs:
            if sreq.slot < 0:
                continue
            ht[sreq.slot, : len(sreq.hi_pages)] = sreq.hi_pages
            lt[sreq.slot, : len(sreq.lo_pages)] = sreq.lo_pages
        if pc.hi_blocks_per_seq == 0:
            ht = ht[:, :0]
        return ht, lt

    def _tables(self, sreqs: List[SchedRequest]) -> tuple:
        ht, lt = self._tables_np(sreqs)
        return jnp.asarray(ht), jnp.asarray(lt)

    def _write_target(self, sreq: SchedRequest, pos: int) -> tuple:
        is_hi, pidx, off = PKV.token_page_index(pos, self.pcfg)
        page = (sreq.hi_pages if is_hi else sreq.lo_pages)[pidx]
        return page, off, is_hi

    def _bucket_npf(self, n: int) -> int:
        for b in self._npf_buckets:
            if b >= n:
                return b
        return self._npf_buckets[-1]

    def _step(self, done: List[Request]) -> None:
        self._step_i += 1
        self._inc("steps")
        with self._timer.phase("plan"):
            if self.fault is not None:
                self.fault.begin_step(self._step_i)
                if self.fault.exhausted():
                    self._event("fault_exhaust")
                if self.fault.flush_prefix():
                    dropped = self.sched.alloc.flush_cache()
                    self._event("fault_prefix_flush", dropped=dropped)
            self._check_deadlines()
            plan = self.sched.plan_step()
            for sreq in plan.admitted:
                self._event("admit", uid=sreq.uid)
                req = self._requests.get(sreq.uid)
                if req is not None:
                    self._observe_latency("queue_wait_s",
                                          self._obs_now - req.obs_submit_t)
            if self._swap_failed:
                # a swap-in refused its checksum during _admit: the request
                # got a slot/pages but its cache was never restored — fail
                # it and drop it from this step's spans before anything runs
                for sreq, msg in self._swap_failed:
                    self._inc("swap_corruptions")
                    self._fail(sreq, msg, kind="swap_corrupt")
                self._swap_failed = []
                plan.prefills = [w for w in plan.prefills
                                 if w.sreq.state == PREFILLING]
                plan.decode = [r for r in plan.decode if r.state == RUNNING]

        progress = bool(plan.prefills or plan.decode)
        if self.ecfg.step_mode == "two_call":
            if plan.prefills:
                self._run_prefill_chunk(plan.prefills[0], done)
            if plan.decode:
                self._run_decode(plan.decode, done)
        elif progress:
            self._run_unified(plan, done)
        self._watchdog(progress)
        self._publish_load()

    def _publish_load(self) -> None:
        """Per-step occupancy gauges from the scheduler/allocator."""
        for name, v in self.sched.load().items():
            self.metrics.gauge(f"sched_{name}",
                               help=f"scheduler {name}").set(v)
        self._refresh_prefix_gauges()

    def _run_unified(self, plan, done: List[Request]) -> None:
        """Build the flattened ragged batch the scheduler planned and run
        it as ONE device program: ``n_pf`` chunk rows (bucketed; unused
        rows are null-page dummies) + the decode slot array."""
        e = self.ecfg
        c_len, s = e.prefill_chunk, e.max_slots
        works = plan.prefills
        n_pf = self._bucket_npf(len(works))
        telem = None
        with self._timer.phase("dispatch"):
            pf_tokens = np.zeros((n_pf, c_len), np.int32)
            pf_start = np.zeros((n_pf,), np.int32)
            pf_length = np.zeros((n_pf,), np.int32)
            pf_first = np.zeros((n_pf,), bool)
            pf_last = np.zeros((n_pf,), np.int32)
            # dummy chunk rows park on the null slot (index max_slots):
            # their SSM-state scatter lands there the way masked K/V
            # writes land on the null page
            pf_slots = np.full((n_pf,), s, np.int32)
            pages = np.zeros((n_pf * c_len + s,), np.int32)
            offs = np.zeros((n_pf * c_len + s,), np.int32)
            ishi = np.zeros((n_pf * c_len + s,), bool)
            for i, w in enumerate(works):
                sreq, start, end = w.sreq, w.start, w.end
                valid = end - start
                pf_tokens[i, :valid] = sreq.prompt[start:end]
                pf_start[i] = start
                pf_length[i] = end
                pf_first[i] = start == 0
                pf_slots[i] = sreq.slot
                # the chunk's last valid row — on a final chunk that is
                # the prompt's last token, whose logits are the
                # first-token distribution (pf_logits of non-final chunks
                # are discarded)
                pf_last[i] = valid - 1
                base = i * c_len
                if self._has_attn:
                    for t in range(valid):
                        pages[base + t], offs[base + t], ishi[base + t] = \
                            self._write_target(sreq, start + t)
            dec_tokens = np.zeros((s,), np.int32)
            dec_pos = np.zeros((s,), np.int32)
            dec_active = np.zeros((s,), bool)
            base = n_pf * c_len
            for sreq in plan.decode:
                dec_tokens[sreq.slot] = sreq.generated[-1]
                dec_pos[sreq.slot] = sreq.pos
                dec_active[sreq.slot] = True
                if self._has_attn:
                    pages[base + sreq.slot], offs[base + sreq.slot], \
                        ishi[base + sreq.slot] = \
                        self._write_target(sreq, sreq.pos)
            # span-ordered tables: one row per chunk span (that request's
            # own table), then the whole slot array for the decode spans
            ht_np, lt_np = self._tables_np([w.sreq for w in works]
                                           + plan.decode)
            pf_ht = np.zeros((n_pf, ht_np.shape[1]), np.int32)
            pf_lt = np.zeros((n_pf, lt_np.shape[1]), np.int32)
            for i, w in enumerate(works):
                pf_ht[i] = ht_np[w.sreq.slot]
                pf_lt[i] = lt_np[w.sreq.slot]
            span_ht = np.concatenate([pf_ht, ht_np], axis=0)
            span_lt = np.concatenate([pf_lt, lt_np], axis=0)

            if n_pf not in self._compiled_keys:
                self._compiled_keys.add(n_pf)
                self._inc("recompiles")
            out = self._unified(
                self.params, self.pools, jnp.asarray(pf_tokens),
                jnp.asarray(pf_start), jnp.asarray(pf_length),
                jnp.asarray(pf_first), jnp.asarray(pf_last),
                jnp.asarray(pf_slots), jnp.asarray(dec_tokens),
                jnp.asarray(dec_pos), jnp.asarray(dec_active),
                jnp.asarray(span_ht), jnp.asarray(span_lt),
                jnp.asarray(pages), jnp.asarray(offs), jnp.asarray(ishi))
            if self._collect:
                pf_logits, dec_logits, self.pools, telem = out
            else:
                pf_logits, dec_logits, self.pools = out
            self._inc("device_dispatches")
            pf_logits = np.asarray(pf_logits)
            dec_logits = np.asarray(dec_logits)
        if telem is not None:
            self._absorb_telemetry(telem)

        with self._timer.phase("post"):
            for i, w in enumerate(works):
                sreq = w.sreq
                try:
                    sreq.pos = w.end
                    # completed prompt pages become addressable for later
                    # arrivals (before _maybe_finish can release them)
                    self.sched.register_prefix(sreq)
                    self._inc("prefill_chunks")
                    self._event("prefill_chunk", uid=sreq.uid,
                                start=w.start, end=w.end)
                    if w.end == sreq.prompt_len:
                        if not self._next_token(sreq, pf_logits[i]):
                            continue  # quarantined — resources released
                        sreq.state = RUNNING
                        req = self._requests[sreq.uid]
                        req.ttft_s = self._clock() - req.submit_t
                        self._event("first_token", uid=sreq.uid)
                        self._observe_latency(
                            "ttft_s", self._obs_now - req.obs_submit_t)
                        self._maybe_finish(sreq, done)
                except Exception as exc:  # noqa: BLE001 — isolation boundary
                    self._fail(sreq,
                               f"prefill postprocessing error: {exc!r}")
            if plan.decode:
                self._event("decode",
                            uids=tuple(sorted(r.uid for r in plan.decode)))
                for sreq in plan.decode:
                    try:
                        sreq.pos += 1      # last token is now cached
                        if not self._next_token(sreq,
                                                dec_logits[sreq.slot]):
                            continue
                        self._inc("decode_tokens")
                        self._maybe_finish(sreq, done)
                    except Exception as exc:   # noqa: BLE001
                        self._fail(sreq,
                                   f"decode postprocessing error: {exc!r}")

    # -- two_call mode (the PR-3 step pair, kept for parity/AB) ---------
    def _run_prefill_chunk(self, work: PrefillWork,
                           done: List[Request]) -> None:
        e = self.ecfg
        sreq, start, end = work.sreq, work.start, work.end
        valid = end - start
        chunk = np.zeros((1, e.prefill_chunk), np.int32)
        chunk[0, :valid] = sreq.prompt[start:end]
        pages = np.zeros((e.prefill_chunk,), np.int32)
        offs = np.zeros((e.prefill_chunk,), np.int32)
        ishi = np.zeros((e.prefill_chunk,), bool)
        if self._has_attn:
            for i in range(valid):
                pages[i], offs[i], ishi[i] = \
                    self._write_target(sreq, start + i)
        ht_all, lt_all = self._tables([sreq])
        slot_sel = np.asarray([sreq.slot], np.int32)
        ht, lt = ht_all[slot_sel], lt_all[slot_sel]
        last_index = (sreq.prompt_len - 1) - start if end == sreq.prompt_len \
            else valid - 1
        fn = self._prefill_first if start == 0 else self._prefill_cont
        telem = None
        with self._timer.phase("dispatch"):
            out = fn(
                self.params, self.pools, jnp.asarray(chunk),
                jnp.int32(start), ht, lt, jnp.asarray(pages),
                jnp.asarray(offs), jnp.asarray(ishi),
                jnp.int32(last_index), jnp.int32(sreq.slot))
            if self._collect:
                logits, self.pools, telem = out
            else:
                logits, self.pools = out
            self._inc("device_dispatches")
            logits = np.asarray(logits)
        if telem is not None:
            self._absorb_telemetry(telem)
        with self._timer.phase("post"):
            sreq.pos = end
            self.sched.register_prefix(sreq)
            self._inc("prefill_chunks")
            self._event("prefill_chunk", uid=sreq.uid, start=start, end=end)
            if end == sreq.prompt_len:
                if not self._next_token(sreq, logits[0]):
                    return           # quarantined
                sreq.state = RUNNING
                req = self._requests[sreq.uid]
                req.ttft_s = self._clock() - req.submit_t
                self._event("first_token", uid=sreq.uid)
                self._observe_latency("ttft_s",
                                      self._obs_now - req.obs_submit_t)
                self._maybe_finish(sreq, done)

    def _run_decode(self, running: List[SchedRequest],
                    done: List[Request]) -> None:
        e = self.ecfg
        s = e.max_slots
        tokens = np.zeros((s,), np.int32)
        positions = np.zeros((s,), np.int32)
        active = np.zeros((s,), bool)
        pages = np.zeros((s,), np.int32)
        offs = np.zeros((s,), np.int32)
        ishi = np.zeros((s,), bool)
        for sreq in running:
            tokens[sreq.slot] = sreq.generated[-1]
            positions[sreq.slot] = sreq.pos
            active[sreq.slot] = True
            if self._has_attn:
                pages[sreq.slot], offs[sreq.slot], ishi[sreq.slot] = \
                    self._write_target(sreq, sreq.pos)
        ht, lt = self._tables(running)
        with self._timer.phase("dispatch"):
            logits, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(tokens),
                jnp.asarray(positions), ht, lt, jnp.asarray(pages),
                jnp.asarray(offs), jnp.asarray(ishi), jnp.asarray(active))
            self._inc("device_dispatches")
            logits = np.asarray(logits)
        with self._timer.phase("post"):
            self._event("decode",
                        uids=tuple(sorted(r.uid for r in running)))
            for sreq in running:
                sreq.pos += 1                  # last token is now cached
                if not self._next_token(sreq, logits[sreq.slot]):
                    continue
                self._inc("decode_tokens")
                self._maybe_finish(sreq, done)

    def _maybe_finish(self, sreq: SchedRequest, done: List[Request]) -> None:
        eos = self.ecfg.eos_id
        hit_eos = eos >= 0 and sreq.generated and sreq.generated[-1] == eos
        cap = min(sreq.max_new_tokens,
                  self.ecfg.max_seq - sreq.prompt_len)
        if hit_eos or len(sreq.generated) >= cap:
            out = sreq.generated[: sreq.max_new_tokens]
            req = self._requests[sreq.uid]
            req.out_tokens = np.asarray(out, np.int32)
            req.latency_s = self._clock() - req.submit_t
            req.preemptions = sreq.preemptions
            req.status = "finished"
            self.sched.finish(sreq)
            self._inc("finished")
            self._event("finish", uid=sreq.uid)
            self._observe_latency("latency_s",
                                  self._obs_now - req.obs_submit_t)
            done.append(req)

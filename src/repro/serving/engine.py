"""Serving engines with STaMP quantization: lockstep bucketed batching and
continuous batching over the block-paged mixed-precision cache.

Two engines share one request API (`submit` → `run` → completed
`Request`s with tokens + latency/TTFT):

* :class:`BucketedEngine` (alias ``ServingEngine``) — the slot-batching
  design: requests are grouped into fixed-size batches, prompts right-padded
  to the bucket length, prefill is one jit'd call and decode runs lockstep
  with **per-slot positions** (each request decodes at its own length, so
  padding never leaks into the math and the whole batch waits only on the
  longest *generation*, not on padded prompt positions).
* :class:`PagedServingEngine` — continuous batching: a
  `serving/scheduler.py` state machine admits/evicts requests every step
  against the block-paged cache (`serving/paged_kvcache.py`).  Prompts
  prefill in fixed-size chunks interleaved with the running decode batch
  (no bucket padding), requests join/leave the decode slot array at step
  granularity, and block exhaustion preempts the latest arrival by swapping
  its pages to host memory — resume is bit-identical, no recompute.

Both engines share the model entry points in `models/lm.py`; with
``stamp=None`` (or a prompt that fits one prefill chunk) they produce
token-identical greedy output, which the parity tests pin.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import paged_kvcache as PKV
from repro.serving.scheduler import (RUNNING, PrefillWork, SchedRequest,
                                     Scheduler, SchedulerConfig)


def _transform_window(stamp, chunk: int) -> int:
    """Transform-aware chunk-boundary window: a Haar DWT / WHT at L levels
    mixes tokens in blocks of 2^L, so non-final chunk ends align to that
    multiple (scheduler satellite).  Window > chunk cannot be aligned — the
    per-chunk transform spans the whole chunk, so there is no intra-chunk
    window to preserve (the documented fallback: no alignment)."""
    if stamp is None or not stamp.enabled or stamp.seq_transform == "none":
        return 1
    w = 2 ** stamp.resolved_levels(chunk)
    return w if w <= chunk else 1


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0
    ttft_s: float = 0.0           # submit → first token
    preemptions: int = 0
    submit_t: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    bucket: int = 128             # prompt bucket length (pad to this)
    max_seq: int = 256            # cache capacity
    eos_id: int = -1              # <0 disables EOS stopping


@dataclasses.dataclass
class PagedEngineConfig:
    max_slots: int = 8            # decode batch width (static jit shape)
    prefill_chunk: int = 128      # tokens per prefill chunk row
    max_seq: int = 256            # per-request length cap (table width)
    block_size: int = 16          # tokens per cache page
    num_hi_blocks: Optional[int] = None   # pool sizes; None = enough for
    num_lo_blocks: Optional[int] = None   # max_slots full-length requests
    eos_id: int = -1
    max_prefills: int = 2         # chunk spans per unified step (≥ 1)
    step_mode: str = "unified"    # "unified" (one program per step) |
    # "two_call" (the PR-3 prefill-then-decode pair, kept for parity tests
    # and A/B benchmarking — schedules exactly like the old engine)
    max_events: int = 4096        # event-trace ring buffer (0 = unbounded)


class _EngineBase:
    """Shared request plumbing: fused-weight preparation + submit queue."""

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig):
        if serve.stamp is not None and serve.stamp.enabled and \
                serve.stamp.execution == "fused":
            # hoist every fused site's weights into cached int8 buffers once
            # (lm.FUSED_SITES: merged QKV+bias, attention out-proj, gate/up
            # pairs, MLP down, mamba in/out); prefill then runs the integer
            # kernels per STaMP linear — the gate/up pair through ONE
            # dual-output call — and decode consumes the same buffers
            # through the single-token integer kernel
            # (kernels/decode_matmul.py) instead of re-dequantizing them to
            # bf16 every step.
            params = lm.prepare_fused_weights(params, serve.stamp)
            serve = dataclasses.replace(serve, fused_decode_matmul=True)
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self._uid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        self._uid += 1
        # perf_counter, not time.time: TTFT / latency are *intervals*, and
        # wall-clock steps (NTP slew) would skew the bench percentiles
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens, submit_t=time.perf_counter())
        self._enqueue(req)
        return self._uid

    def _enqueue(self, req: Request) -> None:
        raise NotImplementedError


class BucketedEngine(_EngineBase):
    """Lockstep slot-batching (the pre-paging design, kept as the simple
    baseline, the numerics oracle, and the only engine covering enc-dec
    cross-attention caches)."""

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 ecfg: Optional[EngineConfig] = None):
        super().__init__(params, cfg, serve)
        # NOTE: default constructed per instance — a dataclass default
        # instance in the signature would be shared across engines (mutable
        # default), letting one engine's config edits leak into another.
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        self.queue: List[Request] = []
        serve = dataclasses.replace(self.serve,
                                    cache_capacity=self.ecfg.max_seq)
        self.serve = serve
        cfgm = self.cfg
        self._prefill = jax.jit(
            lambda p, b, lp: lm.prefill(p, b, cfgm, serve, last_pos=lp))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfgm, serve))

    def _enqueue(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests."""
        done: List[Request] = []
        while self.queue:
            batch = self.queue[: self.ecfg.max_batch]
            self.queue = self.queue[self.ecfg.max_batch:]
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, reqs: List[Request]) -> List[Request]:
        t0 = time.perf_counter()
        b = len(reqs)
        bucket = self.ecfg.bucket
        prompts = np.zeros((b, bucket), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-bucket:]
            prompts[i, : len(p)] = p              # right-pad
            lens[i] = len(p)
        # Right-padding + per-slot decode positions: pad tokens sit AFTER
        # every prompt position, so causal attention never sees them, the
        # next-token logits are read at each row's true last token, and the
        # first generated token overwrites the pad K/V at position len —
        # the output is identical to serving the request unpadded (and to
        # the paged engine's chunked prefill of the same prompt).
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)},
                                      jnp.asarray(lens - 1))
        max_new = max(r.max_new_tokens for r in reqs)
        max_new = min(max_new, self.ecfg.max_seq - int(lens.max()))
        outs = np.zeros((b, max_new), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # force the async-dispatched prefill before timestamping, so TTFT
        # measures execution (as the paged engine's np.argmax does)
        jax.block_until_ready(tok)
        t_first = time.perf_counter()
        for r in reqs:
            r.ttft_s = t_first - r.submit_t
        alive = np.ones(b, bool)
        for step in range(max_new):
            outs[:, step] = np.where(alive, np.asarray(tok), 0)
            if self.ecfg.eos_id >= 0:
                alive &= outs[:, step] != self.ecfg.eos_id
                if not alive.any():
                    outs = outs[:, : step + 1]
                    break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(lens + step))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.out_tokens = outs[i][: r.max_new_tokens]
            r.latency_s = dt
        return reqs


# backward-compatible name: the bucketed engine is the original design
ServingEngine = BucketedEngine


class PagedServingEngine(_EngineBase):
    """Continuous batching over the block-paged mixed-precision cache.

    Each engine step the scheduler admits waiting requests into free slots
    and reserves pages (preempting later arrivals on exhaustion), then the
    whole step's work — up to ``max_prefills`` prefill chunks AND the
    decode slot array — runs as **one ragged batched forward**
    (`lm.paged_unified_step`): every step dispatches exactly one device
    program and streams the weights once, where the two-call design paid
    two dispatches and two cold weight passes on every mixed step while
    decode slots idled during prefill.  Shapes are bucketed on the number
    of chunk rows (0, 1, 2, 4, … up to ``max_prefills``), so the compile
    count per engine lifetime is fixed (``stats["recompiles"]`` /
    :meth:`compile_count`; the first/continuation-chunk distinction is a
    traced mask, not a shape).  ``step_mode="two_call"`` keeps the PR-3
    prefill-then-decode pair — scheduling-identical (one chunk per step,
    no boundary alignment) — as the parity oracle and A/B baseline.
    ``events`` records the admission / join / leave / preemption trace in
    a ring buffer capped at ``max_events``.

    **Hybrid and pure-SSM stacks are first-class**: Mamba layers keep
    their recurrent state in a slot-dense pool next to the paged K/V
    (fixed bytes per slot — `stats` surface it via the scheduler's
    ``state_bytes_per_slot``), prefill chunks carry conv/SSM state across
    chunk boundaries through the request's slot row, decode advances the
    recurrence with inactive slots masked, and preemption swaps the slot
    state to host together with the victim's pages (bit-identical
    resume).  A stack with no attention layers skips page reservation
    entirely — slots are then the only capacity dimension.  Enc-dec
    stacks still need :class:`BucketedEngine`.
    """

    def __init__(self, params, cfg: ModelConfig, serve: lm.ServeConfig,
                 ecfg: Optional[PagedEngineConfig] = None):
        super().__init__(params, cfg, serve)
        self.ecfg = ecfg if ecfg is not None else PagedEngineConfig()
        e = self.ecfg
        quant = self.serve.kv
        num_hi = quant.num_hi if quant.quantized else 0
        if quant.quantized and num_hi % e.block_size:
            raise ValueError("num_hi must be a multiple of block_size")
        hi_per_seq = num_hi // e.block_size if quant.quantized else 0
        lo_per_seq = -(-(e.max_seq - num_hi) // e.block_size)
        n_hi = e.num_hi_blocks if e.num_hi_blocks is not None \
            else e.max_slots * hi_per_seq + 1
        n_lo = e.num_lo_blocks if e.num_lo_blocks is not None \
            else e.max_slots * lo_per_seq + 1
        self.pcfg = PKV.PagedCacheConfig(
            block_size=e.block_size, num_lo_blocks=n_lo,
            num_hi_blocks=max(n_hi, 1), max_blocks_per_seq=lo_per_seq,
            quant=quant)
        self.serve = dataclasses.replace(self.serve, paged=self.pcfg,
                                         cache_capacity=None)
        # stack composition decides the state families: attention layers
        # read/write the page pools, mamba layers the slot-dense SSM pool
        # (fixed-size per slot, no paging — its null slot is row max_slots).
        # Enc-dec stacks are the one remaining gap (init_paged_cache raises
        # the actionable NotImplementedError before any device allocation).
        pro, period, _ = cfg.layer_plan()
        specs = list(period) + list(pro)
        self._has_attn = any(s.mixer == "attn" for s in specs)
        self._has_mamba = any(s.mixer == "mamba" for s in specs)
        self.pools = lm.init_paged_cache(cfg, self.pcfg,
                                         num_slots=e.max_slots)
        if e.step_mode not in ("unified", "two_call"):
            raise ValueError(f"unknown step_mode {e.step_mode!r}")
        unified = e.step_mode == "unified"
        self.sched = Scheduler(
            SchedulerConfig(
                max_slots=e.max_slots, prefill_chunk=e.prefill_chunk,
                max_prefills=max(e.max_prefills, 1) if unified else 1,
                transform_window=_transform_window(
                    self.serve.stamp, e.prefill_chunk) if unified else 1,
                state_bytes_per_slot=PKV.ssm_state_bytes_per_slot(
                    self.pools),
                needs_kv_pages=self._has_attn),
            self.pcfg, swap_out=self._swap_out, swap_in=self._swap_in)
        self._requests: Dict[int, Request] = {}
        # (step, kind, payload) ring buffer — unbounded growth over a long
        # serving run is a memory leak, so the trace keeps the newest
        # max_events entries
        self.events: collections.deque = collections.deque(
            maxlen=e.max_events if e.max_events > 0 else None)
        self.stats = {"steps": 0, "decode_tokens": 0, "prefill_chunks": 0,
                      "preemptions": 0, "device_dispatches": 0,
                      "recompiles": 0, "swap_bytes": 0}
        self._step_i = 0
        # shape buckets for the chunk-row count: 0 (all-decode), powers of
        # two, and max_prefills — the full set of compiled variants
        mp = max(e.max_prefills, 1) if unified else 1
        buckets = {0, mp}
        b = 1
        while b < mp:
            buckets.add(b)
            b *= 2
        self._npf_buckets = sorted(buckets)
        self._compiled_keys: set = set()

        cfgm, serve_p = self.cfg, self.serve
        if unified:
            self._unified = jax.jit(
                lambda p, pools, pt, ps, pln, pf, pli, psl, dt, dp, da, ht,
                lt, pg, off, ih:
                lm.paged_unified_step(p, pools, pt, ps, pln, pf, pli, psl,
                                      dt, dp, da, ht, lt, pg, off, ih,
                                      cfgm, serve_p))
        else:
            self._prefill_first = jax.jit(
                lambda p, pools, t, s, ht, lt, pg, off, ih, li, sl:
                lm.paged_prefill_chunk(p, pools, t, s, ht, lt, pg, off, ih,
                                       li, cfgm, serve_p, first=True,
                                       slot=sl))
            self._prefill_cont = jax.jit(
                lambda p, pools, t, s, ht, lt, pg, off, ih, li, sl:
                lm.paged_prefill_chunk(p, pools, t, s, ht, lt, pg, off, ih,
                                       li, cfgm, serve_p, first=False,
                                       slot=sl))
            self._decode = jax.jit(
                lambda p, pools, t, pos, ht, lt, pg, off, ih, act:
                lm.paged_decode_step(p, pools, t, pos, ht, lt, pg, off, ih,
                                     cfgm, serve_p, active=act))

    def compile_count(self) -> int:
        """Compiled variants of the unified step this engine has built
        (shape-bucketed chunk-row counts).  Prefers jit's own lowering
        cache; falls back to the host-side bucket set."""
        fn = getattr(self, "_unified", None)
        if fn is not None and hasattr(fn, "_cache_size"):
            return fn._cache_size()
        return len(self._compiled_keys)

    # ------------------------------------------------------------------
    def _enqueue(self, req: Request) -> None:
        self._requests[req.uid] = req
        self.sched.submit(SchedRequest(
            uid=req.uid, prompt=req.prompt[-self.ecfg.max_seq + 1:],
            max_new_tokens=req.max_new_tokens, arrival=req.uid))

    def _swap_out(self, sreq: SchedRequest) -> None:
        # slot still assigned here (the scheduler swaps before it frees),
        # so the per-slot SSM state rides along with the pages
        sreq.swapped = PKV.extract_pages(self.pools, sreq.hi_pages,
                                         sreq.lo_pages, slot=sreq.slot)
        self.events.append((self._step_i, "preempt", sreq.uid))
        self.stats["preemptions"] += 1
        self.stats["swap_bytes"] += PKV.swapped_bytes(sreq.swapped)

    def _swap_in(self, sreq: SchedRequest) -> None:
        # sreq.slot is the NEW placement — SSM state restores there, pages
        # at whatever ids the allocator handed back (tables indirect)
        self.pools = PKV.insert_pages(self.pools, sreq.swapped,
                                      sreq.hi_pages, sreq.lo_pages,
                                      slot=sreq.slot)
        self.events.append((self._step_i, "resume", sreq.uid))

    # ------------------------------------------------------------------
    def run(self) -> List[Request]:
        t0 = time.perf_counter()
        done: List[Request] = []
        while self.sched.has_work():
            self._step(done)
        dt = time.perf_counter() - t0
        for r in done:
            r.latency_s = r.latency_s or dt
        return done

    # ------------------------------------------------------------------
    def _tables_np(self, sreqs: List[SchedRequest]) -> tuple:
        """Host-built block tables over the full slot array (unmapped → 0)."""
        e, pc = self.ecfg, self.pcfg
        ht = np.zeros((e.max_slots, max(pc.hi_blocks_per_seq, 1)), np.int32)
        lt = np.zeros((e.max_slots, pc.max_blocks_per_seq), np.int32)
        for sreq in sreqs:
            if sreq.slot < 0:
                continue
            ht[sreq.slot, : len(sreq.hi_pages)] = sreq.hi_pages
            lt[sreq.slot, : len(sreq.lo_pages)] = sreq.lo_pages
        if pc.hi_blocks_per_seq == 0:
            ht = ht[:, :0]
        return ht, lt

    def _tables(self, sreqs: List[SchedRequest]) -> tuple:
        ht, lt = self._tables_np(sreqs)
        return jnp.asarray(ht), jnp.asarray(lt)

    def _write_target(self, sreq: SchedRequest, pos: int) -> tuple:
        is_hi, pidx, off = PKV.token_page_index(pos, self.pcfg)
        page = (sreq.hi_pages if is_hi else sreq.lo_pages)[pidx]
        return page, off, is_hi

    def _bucket_npf(self, n: int) -> int:
        for b in self._npf_buckets:
            if b >= n:
                return b
        return self._npf_buckets[-1]

    def _step(self, done: List[Request]) -> None:
        self._step_i += 1
        self.stats["steps"] += 1
        plan = self.sched.plan_step()
        for sreq in plan.admitted:
            self.events.append((self._step_i, "admit", sreq.uid))

        if self.ecfg.step_mode == "two_call":
            if plan.prefills:
                self._run_prefill_chunk(plan.prefills[0], done)
            if plan.decode:
                self._run_decode(plan.decode, done)
            return
        if plan.prefills or plan.decode:
            self._run_unified(plan, done)

    def _run_unified(self, plan, done: List[Request]) -> None:
        """Build the flattened ragged batch the scheduler planned and run
        it as ONE device program: ``n_pf`` chunk rows (bucketed; unused
        rows are null-page dummies) + the decode slot array."""
        e = self.ecfg
        c_len, s = e.prefill_chunk, e.max_slots
        works = plan.prefills
        n_pf = self._bucket_npf(len(works))
        pf_tokens = np.zeros((n_pf, c_len), np.int32)
        pf_start = np.zeros((n_pf,), np.int32)
        pf_length = np.zeros((n_pf,), np.int32)
        pf_first = np.zeros((n_pf,), bool)
        pf_last = np.zeros((n_pf,), np.int32)
        # dummy chunk rows park on the null slot (index max_slots): their
        # SSM-state scatter lands there the way masked K/V writes land on
        # the null page
        pf_slots = np.full((n_pf,), s, np.int32)
        pages = np.zeros((n_pf * c_len + s,), np.int32)
        offs = np.zeros((n_pf * c_len + s,), np.int32)
        ishi = np.zeros((n_pf * c_len + s,), bool)
        for i, w in enumerate(works):
            sreq, start, end = w.sreq, w.start, w.end
            valid = end - start
            pf_tokens[i, :valid] = sreq.prompt[start:end]
            pf_start[i] = start
            pf_length[i] = end
            pf_first[i] = start == 0
            pf_slots[i] = sreq.slot
            # the chunk's last valid row — on a final chunk that is the
            # prompt's last token, whose logits are the first-token
            # distribution (pf_logits of non-final chunks are discarded)
            pf_last[i] = valid - 1
            base = i * c_len
            if self._has_attn:
                for t in range(valid):
                    pages[base + t], offs[base + t], ishi[base + t] = \
                        self._write_target(sreq, start + t)
        dec_tokens = np.zeros((s,), np.int32)
        dec_pos = np.zeros((s,), np.int32)
        dec_active = np.zeros((s,), bool)
        base = n_pf * c_len
        for sreq in plan.decode:
            dec_tokens[sreq.slot] = sreq.generated[-1]
            dec_pos[sreq.slot] = sreq.pos
            dec_active[sreq.slot] = True
            if self._has_attn:
                pages[base + sreq.slot], offs[base + sreq.slot], \
                    ishi[base + sreq.slot] = \
                    self._write_target(sreq, sreq.pos)
        # span-ordered tables: one row per chunk span (that request's own
        # table), then the whole slot array for the decode spans
        ht_np, lt_np = self._tables_np([w.sreq for w in works] + plan.decode)
        pf_ht = np.zeros((n_pf, ht_np.shape[1]), np.int32)
        pf_lt = np.zeros((n_pf, lt_np.shape[1]), np.int32)
        for i, w in enumerate(works):
            pf_ht[i] = ht_np[w.sreq.slot]
            pf_lt[i] = lt_np[w.sreq.slot]
        span_ht = np.concatenate([pf_ht, ht_np], axis=0)
        span_lt = np.concatenate([pf_lt, lt_np], axis=0)

        if n_pf not in self._compiled_keys:
            self._compiled_keys.add(n_pf)
            self.stats["recompiles"] += 1
        pf_logits, dec_logits, self.pools = self._unified(
            self.params, self.pools, jnp.asarray(pf_tokens),
            jnp.asarray(pf_start), jnp.asarray(pf_length),
            jnp.asarray(pf_first), jnp.asarray(pf_last),
            jnp.asarray(pf_slots), jnp.asarray(dec_tokens),
            jnp.asarray(dec_pos), jnp.asarray(dec_active),
            jnp.asarray(span_ht), jnp.asarray(span_lt),
            jnp.asarray(pages), jnp.asarray(offs), jnp.asarray(ishi))
        self.stats["device_dispatches"] += 1
        pf_logits = np.asarray(pf_logits)
        dec_logits = np.asarray(dec_logits)

        for i, w in enumerate(works):
            sreq = w.sreq
            sreq.pos = w.end
            self.stats["prefill_chunks"] += 1
            self.events.append((self._step_i, "prefill_chunk",
                                (sreq.uid, w.start, w.end)))
            if w.end == sreq.prompt_len:
                tok = int(np.argmax(pf_logits[i]))
                sreq.generated.append(tok)
                sreq.state = RUNNING
                req = self._requests[sreq.uid]
                req.ttft_s = time.perf_counter() - req.submit_t
                self.events.append((self._step_i, "first_token", sreq.uid))
                self._maybe_finish(sreq, done)
        if plan.decode:
            self.events.append((self._step_i, "decode",
                                tuple(sorted(r.uid for r in plan.decode))))
            for sreq in plan.decode:
                sreq.pos += 1              # last token is now cached
                tok = int(np.argmax(dec_logits[sreq.slot]))
                sreq.generated.append(tok)
                self.stats["decode_tokens"] += 1
                self._maybe_finish(sreq, done)

    # -- two_call mode (the PR-3 step pair, kept for parity/AB) ---------
    def _run_prefill_chunk(self, work: PrefillWork,
                           done: List[Request]) -> None:
        e = self.ecfg
        sreq, start, end = work.sreq, work.start, work.end
        valid = end - start
        chunk = np.zeros((1, e.prefill_chunk), np.int32)
        chunk[0, :valid] = sreq.prompt[start:end]
        pages = np.zeros((e.prefill_chunk,), np.int32)
        offs = np.zeros((e.prefill_chunk,), np.int32)
        ishi = np.zeros((e.prefill_chunk,), bool)
        if self._has_attn:
            for i in range(valid):
                pages[i], offs[i], ishi[i] = \
                    self._write_target(sreq, start + i)
        ht_all, lt_all = self._tables([sreq])
        slot_sel = np.asarray([sreq.slot], np.int32)
        ht, lt = ht_all[slot_sel], lt_all[slot_sel]
        last_index = (sreq.prompt_len - 1) - start if end == sreq.prompt_len \
            else valid - 1
        fn = self._prefill_first if start == 0 else self._prefill_cont
        logits, self.pools = fn(
            self.params, self.pools, jnp.asarray(chunk),
            jnp.int32(start), ht, lt, jnp.asarray(pages), jnp.asarray(offs),
            jnp.asarray(ishi), jnp.int32(last_index), jnp.int32(sreq.slot))
        self.stats["device_dispatches"] += 1
        sreq.pos = end
        self.stats["prefill_chunks"] += 1
        self.events.append((self._step_i, "prefill_chunk",
                            (sreq.uid, start, end)))
        if end == sreq.prompt_len:
            tok = int(np.argmax(np.asarray(logits[0])))
            sreq.generated.append(tok)
            sreq.state = RUNNING
            req = self._requests[sreq.uid]
            req.ttft_s = time.perf_counter() - req.submit_t
            self.events.append((self._step_i, "first_token", sreq.uid))
            self._maybe_finish(sreq, done)

    def _run_decode(self, running: List[SchedRequest],
                    done: List[Request]) -> None:
        e = self.ecfg
        s = e.max_slots
        tokens = np.zeros((s,), np.int32)
        positions = np.zeros((s,), np.int32)
        active = np.zeros((s,), bool)
        pages = np.zeros((s,), np.int32)
        offs = np.zeros((s,), np.int32)
        ishi = np.zeros((s,), bool)
        for sreq in running:
            tokens[sreq.slot] = sreq.generated[-1]
            positions[sreq.slot] = sreq.pos
            active[sreq.slot] = True
            if self._has_attn:
                pages[sreq.slot], offs[sreq.slot], ishi[sreq.slot] = \
                    self._write_target(sreq, sreq.pos)
        ht, lt = self._tables(running)
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(positions), ht, lt, jnp.asarray(pages),
            jnp.asarray(offs), jnp.asarray(ishi), jnp.asarray(active))
        self.stats["device_dispatches"] += 1
        logits = np.asarray(logits)
        self.events.append((self._step_i, "decode",
                            tuple(sorted(r.uid for r in running))))
        for sreq in running:
            sreq.pos += 1                      # last token is now cached
            tok = int(np.argmax(logits[sreq.slot]))
            sreq.generated.append(tok)
            self.stats["decode_tokens"] += 1
            self._maybe_finish(sreq, done)

    def _maybe_finish(self, sreq: SchedRequest, done: List[Request]) -> None:
        eos = self.ecfg.eos_id
        hit_eos = eos >= 0 and sreq.generated and sreq.generated[-1] == eos
        cap = min(sreq.max_new_tokens,
                  self.ecfg.max_seq - sreq.prompt_len)
        if hit_eos or len(sreq.generated) >= cap:
            out = sreq.generated[: sreq.max_new_tokens]
            req = self._requests[sreq.uid]
            req.out_tokens = np.asarray(out, np.int32)
            req.latency_s = time.perf_counter() - req.submit_t
            req.preemptions = sreq.preemptions
            self.sched.finish(sreq)
            self.events.append((self._step_i, "finish", sreq.uid))
            done.append(req)

"""Block-paged mixed-precision KV cache — the serving-time layout behind
continuous batching.

The contiguous cache (`serving/kvcache.py`) reserves ``max_seq`` tokens per
batch slot whether or not a request uses them; every decode step then streams
that full reservation through the attention reduction.  Here the cache is a
**pool of fixed-size pages** shared by all slots, indexed per request through
a block table, so

* HBM held per request is proportional to its *actual* length (rounded up to
  one page), and
* int4 nibble packing quadruples the tokens per HBM page vs bf16 — the
  "4.008-bit effective cache" (§B.2) becomes 4.008 bits of *allocated* HBM,
  not just of traffic.

Layout per attention stack (stacked over scan periods ``P``; quantization
reuses `kvcache.py`'s per-token quant + nibble packing bit-for-bit):

* **hi pool** — ``k_hi / v_hi``: ``(P, NH, bs, kv, hd)`` int8.  The first
  ``num_hi`` (=64) logical tokens of every sequence live here at 8 bits (the
  attention-sink region, §B.2); ``num_hi % bs == 0`` so a page is entirely
  hi or entirely lo.
* **lo pool** — ``k_lo / v_lo``: ``(P, NL, bs, kv, hd/2)`` uint8, two int4
  nibbles packed along head_dim.
* ``*_scale / *_zp`` — ``(P, N?, bs, kv)`` float16 per-token params,
  paged alongside their codes (a page is self-describing, so eviction /
  swap moves one contiguous unit).

Page 0 of each pool is the **null page**: never handed out by the
allocator, and never *read unmasked*.  Block tables hold 0 for unmapped
logical blocks, and masked / pad / inactive-slot writes are routed there,
so neither reads nor scatters need a validity branch — but those routed
writes mean the null page accumulates stale quantized values; correctness
rests on every reader masking unmapped blocks by the slot length (which
all readers do), **not** on the page staying zero.

Block ids are shared across layers and periods (one allocation covers the
whole stack, vLLM-style), which keeps the allocator — a host-side numpy free
list — out of the jit'd step entirely: the engine turns (slot, position) into
(page, offset) arrays on the host and the device code only ever sees dense
int32 indices.

**Hybrid stacks (Mamba + attention)** add a second, *slot-dense* state
family next to the page pools: a Mamba layer's recurrent state is
fixed-size per request — one ``(heads, head_dim, ssm_state)`` f32 state
matrix plus a ``(conv_width - 1, conv_dim)`` bf16 conv tail — so it needs
no paging at all.  :func:`init_ssm_slots` allocates it per *slot*
(``num_slots + 1`` rows; the extra row is the **null slot**, the scatter
target for unused prefill chunk rows — the slot-indexed twin of the null
page).  Preemption swaps the per-slot state with the victim's pages
(`extract_pages` / `insert_pages` take the slot), so a hybrid resume is
bit-identical end to end: pages AND recurrence state restored exactly.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import heapq
import zlib
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kvcache as KV

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry.  ``quant`` carries the precision split (num_hi/bits)."""

    block_size: int = 16          # tokens per page
    num_lo_blocks: int = 64       # lo-pool pages (page 0 = null)
    num_hi_blocks: int = 16       # hi-pool pages (page 0 = null)
    max_blocks_per_seq: int = 16  # lo-table width (static decode grid)
    quant: KV.KVCacheConfig = KV.KVCacheConfig()

    def __post_init__(self):
        if self.quant.quantized and self.quant.num_hi % self.block_size:
            raise ValueError(
                f"num_hi={self.quant.num_hi} must be a multiple of "
                f"block_size={self.block_size} (pages are single-precision)")

    @property
    def hi_blocks_per_seq(self) -> int:
        if not self.quant.quantized:
            return 0
        return self.quant.num_hi // self.block_size

    @property
    def num_hi(self) -> int:
        return self.quant.num_hi if self.quant.quantized else 0


# ---------------------------------------------------------------------------
# pools
# ---------------------------------------------------------------------------


def init_pools(periods: int, kv_heads: int, head_dim: int,
               cfg: PagedCacheConfig) -> dict:
    """Zero page pools for one attention position in the period pattern."""
    bs = cfg.block_size
    if not cfg.quant.quantized:
        shape = (periods, cfg.num_lo_blocks, bs, kv_heads, head_dim)
        return {"k": jnp.zeros(shape, jnp.bfloat16),
                "v": jnp.zeros(shape, jnp.bfloat16)}
    nh, nl = cfg.num_hi_blocks, cfg.num_lo_blocks
    return {
        "k_hi": jnp.zeros((periods, nh, bs, kv_heads, head_dim), jnp.int8),
        "v_hi": jnp.zeros((periods, nh, bs, kv_heads, head_dim), jnp.int8),
        "k_lo": jnp.zeros((periods, nl, bs, kv_heads, head_dim // 2),
                          jnp.uint8),
        "v_lo": jnp.zeros((periods, nl, bs, kv_heads, head_dim // 2),
                          jnp.uint8),
        # f16 for the same exactness/traffic argument as the contiguous cache
        "k_hi_scale": jnp.zeros((periods, nh, bs, kv_heads), jnp.float16),
        "k_hi_zp": jnp.zeros((periods, nh, bs, kv_heads), jnp.float16),
        "v_hi_scale": jnp.zeros((periods, nh, bs, kv_heads), jnp.float16),
        "v_hi_zp": jnp.zeros((periods, nh, bs, kv_heads), jnp.float16),
        "k_lo_scale": jnp.zeros((periods, nl, bs, kv_heads), jnp.float16),
        "k_lo_zp": jnp.zeros((periods, nl, bs, kv_heads), jnp.float16),
        "v_lo_scale": jnp.zeros((periods, nl, bs, kv_heads), jnp.float16),
        "v_lo_zp": jnp.zeros((periods, nl, bs, kv_heads), jnp.float16),
    }


def pool_bytes(entry: dict) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in entry.values())


# ---------------------------------------------------------------------------
# slot-dense SSM state pool (hybrid / pure-SSM stacks)
# ---------------------------------------------------------------------------


def init_ssm_slots(periods: int, num_slots: int, conv_width: int,
                   conv_dim: int, heads: int, head_dim: int,
                   state: int) -> dict:
    """Per-slot recurrent state for one Mamba position in the period
    pattern.  Unlike K/V, SSM state is **fixed-size per request** — one
    ``(heads, head_dim, state)`` matrix and a ``(conv_width - 1,
    conv_dim)`` conv tail — so it lives slot-dense, not paged.  Row
    ``num_slots`` (the last one) is the **null slot**: never assigned to a
    request, it absorbs the scatter from unused prefill chunk rows the way
    the null page absorbs masked K/V writes, so the unified step needs no
    validity branch on its state write either."""
    return {
        "state": jnp.zeros((periods, num_slots + 1, heads, head_dim, state),
                           jnp.float32),
        "conv": jnp.zeros((periods, num_slots + 1, conv_width - 1, conv_dim),
                          jnp.bfloat16),
    }


def is_ssm_entry(entry: dict) -> bool:
    return "state" in entry


def ssm_state_bytes_per_slot(pools: dict) -> int:
    """Fixed HBM bytes ONE slot pins across every Mamba layer (the
    admission-time cost of a hybrid request, independent of its length —
    the scheduler's slot gate is the capacity check for this family)."""
    total = 0
    for entry in pools.values():
        if not is_ssm_entry(entry):
            continue
        slots_axis = 1 if _ssm_has_periods(entry) else 0
        for arr in entry.values():
            total += (int(arr.size) // arr.shape[slots_axis]) * \
                arr.dtype.itemsize
    return total


def _ssm_has_periods(entry: dict) -> bool:
    """Scanned-period SSM entries are state ``(P, S+1, h, p, n)`` / conv
    ``(P, S+1, w-1, cd)``; prologue entries come period-stripped (one axis
    fewer) — mirror of :func:`_has_periods_axis` for the page pools."""
    return entry["state"].ndim == 5


# ---------------------------------------------------------------------------
# host-side page allocator
# ---------------------------------------------------------------------------


class OutOfBlocks(Exception):
    """Raised by the allocator; the scheduler turns it into preemption."""


class SwapCorruption(Exception):
    """A swapped-out page set failed its checksum at swap-in: the host copy
    was corrupted while the request sat preempted.  The restore is refused
    (pools untouched) — the engine fails that one request and keeps
    serving."""


#: root of the prefix-hash chain (the digest "before" page 0)
_PREFIX_ROOT = b""


def _prefix_digest(parent: bytes, tokens: np.ndarray) -> bytes:
    """Chain hash for one token-chunk-aligned page of prompt tokens:
    ``H(parent_digest || page_tokens)``.  The digest addresses the page's
    *entire prefix content*, not just its own tokens, so two pages holding
    equal tokens after different prefixes never collide — and an
    incremental walk over a prompt costs O(block_size) per page."""
    h = hashlib.sha256(parent)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class PrefixMatch:
    """One prefix-cache hit: ``matched`` logical tokens [0, matched) are
    covered by the cached ``hi_pages`` / ``lo_pages`` (refs already
    acquired).  ``cow`` names the one *partially* covered page — ``(pool,
    index into that pool's list)`` — when ``matched`` is not a page
    multiple: the caller must copy that page before any write scatters
    into it (copy-on-write on the first divergent write)."""

    matched: int
    hi_pages: List[int]
    lo_pages: List[int]
    cow: Optional[tuple] = None      # ("hi"|"lo", list index) or None


@dataclasses.dataclass
class _CacheEntry:
    pool: str                        # "hi" | "lo"
    page: int
    tokens: np.ndarray               # the block_size prompt tokens it holds
    parent: bytes                    # parent digest in the chain


class BlockAllocator:
    """Ref-counted, hash-addressed page store over the hi and lo pools
    (host, deterministic).

    Page ids are handed out lowest-first (min-heap pop) so identical request
    streams produce identical placements (the engine-parity tests rely on
    this).  Page 0 of either pool is never allocated — it is the null page.
    Releasing page 0, an out-of-range id, or a page nobody holds raises
    ``ValueError`` (a real exception, not an ``assert`` stripped under
    ``python -O``); membership is tracked in set/dict mirrors so the check
    is O(1) per page.

    **Ref-counting + prefix cache** (vLLM-style prefix reuse): every
    allocated page carries a reference count (``alloc_* = 1``; ``acquire``
    adds a holder, ``release`` drops one).  Pages *registered* in the
    prefix cache (`register_prefix`) are addressed by the chain hash of
    the prompt tokens they hold; a later request with the same prompt
    prefix shares them (`lookup_prefix`) instead of re-allocating and
    re-prefilling.  A cached page whose ref count reaches zero is not
    freed — it parks in a per-pool LRU of **evictable** pages, still
    holding its quantized content for future hits, and is reclaimed
    lazily: ``alloc_*`` evicts the least-recently-used zero-ref cached
    page only once the true free list is empty.  ``can_allocate`` /
    ``all_free`` therefore count evictable pages as free-equivalent
    capacity (`flush_cache` evicts everything for tests that want exact
    free-list equality).

    ``fault`` is the deterministic fault-injection hook
    (`serving/faults.py`): a zero-arg callable that returns True while
    injected page exhaustion is active — ``can_allocate`` then reports no
    capacity and ``alloc_*`` raises :class:`OutOfBlocks`, driving the
    scheduler's real preemption/degradation paths without consuming any
    actual pages.
    """

    def __init__(self, cfg: PagedCacheConfig,
                 fault: Optional[Callable[[], bool]] = None):
        self.cfg = cfg
        self.fault = fault
        # ascending ranges are already valid min-heaps
        self._free_hi = list(range(1, cfg.num_hi_blocks)) \
            if cfg.quant.quantized else []
        self._free_lo = list(range(1, cfg.num_lo_blocks))
        self._free_hi_set = set(self._free_hi)
        self._free_lo_set = set(self._free_lo)
        self._num_blocks = {"hi": cfg.num_hi_blocks if cfg.quant.quantized
                            else 0, "lo": cfg.num_lo_blocks}
        # page id -> holders; an entry exists while the page is allocated
        # OR parked evictable (ref 0, cached)
        self._ref = {"hi": {}, "lo": {}}
        # prefix cache: chain digest -> entry, plus the reverse and
        # parent->children maps the lookup/eviction paths need
        self._cache: dict = {}                       # digest -> _CacheEntry
        self._by_page: dict = {}                     # (pool, page) -> digest
        self._children: dict = {}                    # digest -> set(digest)
        # zero-ref cached pages in LRU order (oldest first) per pool
        self._evict = {"hi": collections.OrderedDict(),
                       "lo": collections.OrderedDict()}
        self.cache_evictions = 0
        # peak pages simultaneously *referenced* (ref >= 1) — the bench's
        # pages-held-per-workload signal (evictable cache copies excluded:
        # they are reclaimable capacity, not demand)
        self.peak_referenced = 0

    def free_counts(self) -> tuple[int, int]:
        return len(self._free_hi), len(self._free_lo)

    def evictable_counts(self) -> tuple[int, int]:
        """(hi, lo) zero-ref cached pages — reclaimable on demand."""
        return len(self._evict["hi"]), len(self._evict["lo"])

    def available_counts(self) -> tuple[int, int]:
        """(hi, lo) pages an allocation could obtain: free + evictable."""
        return (len(self._free_hi) + len(self._evict["hi"]),
                len(self._free_lo) + len(self._evict["lo"]))

    def capacity(self) -> tuple[int, int]:
        """(hi, lo) *allocatable* pages — pool sizes minus the null page.
        The scheduler's submit-time feasibility check compares a request's
        worst-case page demand against this, so a prompt that could never
        be placed is rejected up front instead of livelocking the step
        loop."""
        return (max(self._num_blocks["hi"] - 1, 0),
                max(self._num_blocks["lo"] - 1, 0))

    def all_free(self) -> bool:
        """True when every allocatable page is reclaimable — on the free
        list or parked as a zero-ref cached page (the prefix cache
        legitimately outlives the requests that populated it).  The leak
        invariant the chaos/soak tests assert once all requests reach a
        terminal state; `flush_cache` collapses it to exact free-list
        equality."""
        return self.available_counts() == self.capacity()

    def _fault_active(self) -> bool:
        return self.fault is not None and self.fault()

    def can_allocate(self, n_hi: int, n_lo: int) -> bool:
        if (n_hi > 0 or n_lo > 0) and self._fault_active():
            return False
        avail_hi, avail_lo = self.available_counts()
        return n_hi <= avail_hi and n_lo <= avail_lo

    def _note_usage(self) -> None:
        cap_hi, cap_lo = self.capacity()
        avail_hi, avail_lo = self.available_counts()
        used = (cap_hi - avail_hi) + (cap_lo - avail_lo)
        if used > self.peak_referenced:
            self.peak_referenced = used

    def _heap(self, pool: str) -> tuple[list, set]:
        return ((self._free_hi, self._free_hi_set) if pool == "hi"
                else (self._free_lo, self._free_lo_set))

    def _evict_lru(self, pool: str) -> None:
        """Reclaim the least-recently-used zero-ref cached page: drop its
        cache registration and return it to the free list."""
        page, _ = self._evict[pool].popitem(last=False)
        self._drop_cache_entry(pool, page)
        del self._ref[pool][page]
        heap, members = self._heap(pool)
        heapq.heappush(heap, page)
        members.add(page)
        self.cache_evictions += 1

    def _drop_cache_entry(self, pool: str, page: int) -> None:
        digest = self._by_page.pop((pool, page))
        entry = self._cache.pop(digest)
        kids = self._children.get(entry.parent)
        if kids is not None:
            kids.discard(digest)
            if not kids:
                del self._children[entry.parent]

    def _alloc(self, pool: str) -> int:
        heap, members = self._heap(pool)
        if self._fault_active():
            raise OutOfBlocks(f"{pool} pool exhausted")
        if not heap and self._evict[pool]:
            self._evict_lru(pool)
        if not heap:
            raise OutOfBlocks(f"{pool} pool exhausted")
        i = heapq.heappop(heap)
        members.remove(i)
        self._ref[pool][i] = 1
        self._note_usage()
        return i

    def alloc_hi(self) -> int:
        return self._alloc("hi")

    def alloc_lo(self) -> int:
        return self._alloc("lo")

    def ref_count(self, pool: str, page: int) -> int:
        return self._ref[pool].get(int(page), 0)

    def acquire(self, hi_ids, lo_ids) -> None:
        """Add one holder to each page (a prefix-cache hit sharing them).
        A zero-ref evictable page leaves the LRU — it is referenced
        again."""
        for pool, ids in (("hi", hi_ids), ("lo", lo_ids)):
            for i in ids:
                i = int(i)
                refs = self._ref[pool]
                if refs.get(i) is None:
                    raise ValueError(
                        f"cannot acquire {pool} page {i}: not allocated")
                if refs[i] == 0:
                    self._evict[pool].pop(i, None)
                refs[i] += 1
        self._note_usage()

    def release(self, hi_ids, lo_ids) -> None:
        """Drop one holder from each page.  A page reaching zero holders
        returns to the free list — unless it is registered in the prefix
        cache, in which case it parks in the evictable LRU with its
        content intact (newest-released = most recently used)."""
        for pool, ids in (("hi", hi_ids), ("lo", lo_ids)):
            heap, members = self._heap(pool)
            for i in ids:
                i = int(i)
                if not 0 < i < self._num_blocks[pool]:
                    raise ValueError(
                        f"cannot free {pool} page {i}: outside the "
                        f"allocatable range [1, {self._num_blocks[pool]}) "
                        f"(page 0 is the null page)")
                refs = self._ref[pool]
                if i in members or refs.get(i, 0) <= 0:
                    raise ValueError(f"double free of {pool} page {i}")
                refs[i] -= 1
                if refs[i] > 0:
                    continue
                if (pool, i) in self._by_page:
                    # cached: keep content, park LRU-evictable
                    self._evict[pool][i] = None
                    self._evict[pool].move_to_end(i)
                else:
                    del refs[i]
                    heapq.heappush(heap, i)
                    members.add(i)

    # back-compat name: scheduler/tests predate ref-counting — with every
    # page at ref 1 (no sharing) this is exactly the old free()
    def free(self, hi_ids, lo_ids) -> None:
        self.release(hi_ids, lo_ids)

    # -- prefix cache ---------------------------------------------------
    def _hi_per_seq(self) -> int:
        return self.cfg.hi_blocks_per_seq

    def _page_for_index(self, g: int, hi_pages, lo_pages) -> tuple[str, int]:
        hps = self._hi_per_seq()
        if g < hps:
            return "hi", int(hi_pages[g])
        return "lo", int(lo_pages[g - hps])

    def register_prefix(self, prompt: np.ndarray, upto: int,
                        hi_pages, lo_pages) -> int:
        """Register every *fully materialized* prompt page in [0, upto) —
        upto is the request's materialized position, so only pages whose
        block_size tokens are all written (and all prompt tokens, never
        generated ones) become addressable.  A digest collision keeps the
        existing entry: the newcomer's page simply stays private.  Returns
        the number of new registrations."""
        bs = self.cfg.block_size
        n_full = min(int(upto), int(len(prompt))) // bs
        parent, new = _PREFIX_ROOT, 0
        for g in range(n_full):
            toks = np.asarray(prompt[g * bs:(g + 1) * bs], np.int32)
            digest = _prefix_digest(parent, toks)
            if digest not in self._cache:
                pool, page = self._page_for_index(g, hi_pages, lo_pages)
                if (pool, page) not in self._by_page:
                    self._cache[digest] = _CacheEntry(pool, page,
                                                      toks.copy(), parent)
                    self._by_page[(pool, page)] = digest
                    self._children.setdefault(parent, set()).add(digest)
                    new += 1
            parent = digest
        return new

    def _walk_prefix(self, prompt: np.ndarray,
                     limit: int) -> tuple[int, list]:
        """Longest cached coverage of ``prompt[:limit]``: full pages along
        the digest chain, then at most one partially-matching child page
        (the divergence point CoW exists for).  Returns ``(raw_tokens,
        [(pool, page), ...])`` covering them — no refs taken."""
        bs = self.cfg.block_size
        limit = min(int(limit), int(len(prompt)))
        parent, pages = _PREFIX_ROOT, []
        full = 0
        while (full + 1) * bs <= limit:
            toks = np.asarray(prompt[full * bs:(full + 1) * bs], np.int32)
            digest = _prefix_digest(parent, toks)
            entry = self._cache.get(digest)
            if entry is None:
                break
            pages.append((entry.pool, entry.page))
            parent = digest
            full += 1
        matched = full * bs
        # partial tail: a cached child page whose stored tokens share a
        # proper prefix with the remaining prompt (divergence mid-page)
        rest = np.asarray(prompt[matched:limit], np.int32)
        best_extra, best = 0, None
        for digest in sorted(self._children.get(parent, ()),
                             key=lambda d: (self._cache[d].pool,
                                            self._cache[d].page)):
            entry = self._cache[digest]
            n = min(len(rest), len(entry.tokens))
            eq = entry.tokens[:n] == rest[:n]
            extra = int(n if eq.all() else np.argmin(eq))
            if extra > best_extra:
                best_extra, best = extra, (entry.pool, entry.page)
        if best is not None:
            pages.append(best)
            matched += best_extra
        return matched, pages

    def peek_prefix(self, prompt: np.ndarray, limit: int,
                    quantum: int) -> int:
        """Side-effect-free probe: the aligned token count `lookup_prefix`
        would return right now (the submit-time capacity check's prefix
        credit)."""
        raw, _ = self._walk_prefix(prompt, limit)
        return min(raw, int(limit)) // quantum * quantum

    def lookup_prefix(self, prompt: np.ndarray, limit: int,
                      quantum: int) -> Optional[PrefixMatch]:
        """Longest cached prefix of ``prompt``, aligned DOWN to a multiple
        of ``quantum`` (the engine's aligned-chunk length, so a cache-hit
        prefill restarts exactly on a cache-off chunk boundary — the
        bit-identical-token guarantee) and capped at ``limit``.  Acquires
        one reference on every returned page.  When the aligned match ends
        mid-page, the final page is returned for *reading* only and
        flagged in ``cow``: the caller must replace it with a copy before
        writing (see `copy_page`) — if the CoW copy could not be allocated
        the match is shortened until it ends on a page boundary."""
        bs = self.cfg.block_size
        raw, pages = self._walk_prefix(prompt, limit)
        matched = min(raw, int(limit)) // quantum * quantum
        while matched > 0 and matched % bs and not (
                self.can_allocate(1, 0)
                if pages[(matched - 1) // bs][0] == "hi"
                else self.can_allocate(0, 1)):
            # no page for the copy-on-write copy: retreat to the previous
            # quantum until the match ends on a page boundary (or dies)
            matched = (matched - 1) // quantum * quantum
        if matched <= 0:
            return None
        n_pages = -(-matched // bs)
        hi_pages = [p for pool, p in pages[:n_pages] if pool == "hi"]
        lo_pages = [p for pool, p in pages[:n_pages] if pool == "lo"]
        cow = None
        if matched % bs:
            pool, _ = pages[n_pages - 1]
            cow = (pool, (len(hi_pages) if pool == "hi" else len(lo_pages))
                   - 1)
        self.acquire(hi_pages, lo_pages)
        return PrefixMatch(matched=matched, hi_pages=hi_pages,
                           lo_pages=lo_pages, cow=cow)

    def flush_cache(self) -> int:
        """Drop every prefix-cache registration: zero-ref (evictable) pages
        return to the free list; pages still referenced by live requests
        merely lose their registration (they free normally on release).
        Returns the number of registrations dropped — the fault-injection
        hook for cache-eviction storms, and the test hook for exact
        free-list equality."""
        dropped = len(self._cache)
        for pool in ("hi", "lo"):
            while self._evict[pool]:
                self._evict_lru(pool)
        # remaining registrations belong to ref>0 pages: unregister only
        for (pool, page) in list(self._by_page):
            self._drop_cache_entry(pool, page)
        return dropped

    def cache_stats(self) -> dict:
        """Live prefix-cache occupancy for the engine's gauges."""
        shared = sum(1 for refs in self._ref.values()
                     for r in refs.values() if r >= 2)
        pinned_sink = sum(1 for (pool, page) in self._by_page
                          if pool == "hi"
                          and self._ref["hi"].get(page, 0) >= 1)
        ev_hi, ev_lo = self.evictable_counts()
        return {"cached_pages": len(self._by_page),
                "evictable_pages": ev_hi + ev_lo,
                "kv_pages_shared": shared,
                "sink_pages_pinned": pinned_sink,
                "cache_evictions": self.cache_evictions,
                "peak_referenced_pages": self.peak_referenced}


# ---------------------------------------------------------------------------
# host-side index math (slot position -> page/offset)
# ---------------------------------------------------------------------------


def token_page_index(pos: int, cfg: PagedCacheConfig) -> tuple[bool, int, int]:
    """Logical position -> (is_hi, page_index_within_table, offset)."""
    bs = cfg.block_size
    if pos < cfg.num_hi:
        return True, pos // bs, pos % bs
    rel = pos - cfg.num_hi
    return False, rel // bs, rel % bs


def pages_needed(pos: int, cfg: PagedCacheConfig) -> tuple[int, int]:
    """(hi, lo) page counts required to hold logical positions [0, pos) —
    the shared demand arithmetic behind the scheduler's reservations and
    the engine's submit-time capacity-feasibility check."""
    bs = cfg.block_size
    hi_tokens = min(pos, cfg.num_hi)
    lo_tokens = pos - hi_tokens
    return -(-hi_tokens // bs), -(-lo_tokens // bs)


# ---------------------------------------------------------------------------
# device-side write / read
# ---------------------------------------------------------------------------


def _quant_token(t: Array, bits: int) -> tuple[Array, Array, Array]:
    """Per-token quant matching `kvcache.quant_tokens` + signed shift for
    8-bit codes (identical math, so paged and contiguous caches hold
    bit-identical codes for the same K/V)."""
    q, sc, zp = KV.quant_tokens(t, bits)
    if bits == 8:
        q, zp = KV.to_signed8(q, zp)
        return q.astype(jnp.int8), sc, zp
    return KV.pack_nibbles(q), sc, zp


def _scatter_tokens(entry: dict, kc: Array, vc: Array,
                    pages: Array, offsets: Array, is_hi: Array,
                    cfg: PagedCacheConfig) -> dict:
    """Scatter N token rows into the pools.  ``kc / vc``: (N, kv, hd);
    ``pages / offsets``: (N,) int32 physical page + in-page offset
    (host-computed); ``is_hi``: (N,) bool.  A write lands in exactly one
    pool — the other pool's scatter (and any masked/pad token) is routed to
    its null page, which is never read unmasked, so no validity branch is
    needed on device."""
    out = dict(entry)
    if not cfg.quant.quantized:
        pg_lo = jnp.where(is_hi, 0, pages)
        for name, t in (("k", kc), ("v", vc)):
            out[name] = entry[name].at[pg_lo, offsets].set(
                t.astype(entry[name].dtype))
        return out
    pg_hi = jnp.where(is_hi, pages, 0)
    pg_lo = jnp.where(is_hi, 0, pages)
    for name, t in (("k", kc), ("v", vc)):
        q8, sc8, zp8 = _quant_token(t, 8)
        q4, sc4, zp4 = _quant_token(t, cfg.quant.lo_bits)
        out[f"{name}_hi"] = entry[f"{name}_hi"].at[pg_hi, offsets].set(q8)
        out[f"{name}_lo"] = entry[f"{name}_lo"].at[pg_lo, offsets].set(q4)
        for suffix, hi_val, lo_val in (("scale", sc8, sc4), ("zp", zp8, zp4)):
            out[f"{name}_hi_{suffix}"] = \
                entry[f"{name}_hi_{suffix}"].at[pg_hi, offsets].set(
                    hi_val.astype(jnp.float16))
            out[f"{name}_lo_{suffix}"] = \
                entry[f"{name}_lo_{suffix}"].at[pg_lo, offsets].set(
                    lo_val.astype(jnp.float16))
    return out


def write_tokens(entry: dict, k_new: Array, v_new: Array,
                 pages: Array, offsets: Array, is_hi: Array,
                 cfg: PagedCacheConfig) -> dict:
    """Decode path: scatter one new token per slot into the pools.
    ``k_new / v_new``: (S, 1, kv, hd); inactive slots arrive with
    ``pages == 0`` (the null page)."""
    return _scatter_tokens(entry, k_new[:, 0], v_new[:, 0], pages, offsets,
                           is_hi, cfg)


def write_chunk(entry: dict, k: Array, v: Array,
                pages: Array, offsets: Array, is_hi: Array,
                cfg: PagedCacheConfig) -> dict:
    """Prefill path: scatter a (1, C, kv, hd) K/V chunk of one slot into
    the pools; pad tokens beyond the chunk's valid length arrive with
    ``pages == 0``."""
    return _scatter_tokens(entry, k[0], v[0], pages, offsets, is_hi, cfg)


def write_ragged(entry: dict, k: Array, v: Array,
                 pages: Array, offsets: Array, is_hi: Array,
                 cfg: PagedCacheConfig) -> dict:
    """Unified-step path: scatter the whole flattened token stream — every
    prefill chunk's tokens followed by one token per decode slot — in ONE
    device scatter.  ``k / v``: (T, kv, hd); pad / inactive entries arrive
    with ``pages == 0`` (the null page).  Real writes always target
    disjoint (page, offset) pairs (requests own disjoint pages), so the
    combined scatter is order-independent except on the never-read null
    page."""
    return _scatter_tokens(entry, k, v, pages, offsets, is_hi, cfg)


def gather_segments(entry: dict, hi_table: Array, lo_table: Array,
                    cfg: PagedCacheConfig, dtype=jnp.bfloat16):
    """Block tables -> dense dequantized segments for the XLA attention path.

    ``hi_table``: (S, nh) int32; ``lo_table``: (S, nl) int32 — unmapped
    logical blocks hold 0 (the null page, all-zero) and are masked by length
    downstream.  Returns ``[(k_hi, v_hi, 0), (k_lo, v_lo, num_hi)]`` shaped
    (S, nh*bs, kv, hd) / (S, nl*bs, kv, hd) — the same segment structure
    `decode_attention_segments` consumes for the contiguous cache, so the
    two layouts share one attention implementation (and its exact numerics).
    """
    s = hi_table.shape[0] if cfg.quant.quantized else lo_table.shape[0]
    bs = cfg.block_size

    def dense(codes, lo: bool):
        g = codes[lo_table if lo else hi_table]       # (S, n, bs, kv, ...)
        n = g.shape[1]
        return g.reshape(s, n * bs, *g.shape[3:])

    if not cfg.quant.quantized:
        k = dense(entry["k"], True).astype(dtype)
        v = dense(entry["v"], True).astype(dtype)
        return [(k, v, 0)]

    segs = []
    regions = (("hi", False, 0), ("lo", True, cfg.num_hi))
    if hi_table.shape[1] == 0:           # no sink region configured
        regions = regions[1:]
    for region, lo, offset in regions:
        kv_pair = []
        for name in ("k", "v"):
            codes = dense(entry[f"{name}_{region}"], lo)
            sc = dense(entry[f"{name}_{region}_scale"], lo)
            zp = dense(entry[f"{name}_{region}_zp"], lo)
            if region == "hi":
                vals = codes.astype(jnp.float32)
            else:
                vals = KV.unpack_nibbles(codes)
            kv_pair.append(KV.dequant_tokens(vals, sc, zp, dtype))
        segs.append((kv_pair[0], kv_pair[1], offset))
    return segs


# ---------------------------------------------------------------------------
# page swap (host <-> device) — preemption support
# ---------------------------------------------------------------------------


def _has_periods_axis(entry: dict) -> bool:
    """Scanned-period pools are (P, N, bs, kv, hd); prologue entries come
    period-stripped as (N, bs, kv, hd) (see `lm.init_paged_cache`) — the
    page axis moves accordingly."""
    probe = entry["k_hi"] if "k_hi" in entry else entry["k"]
    return probe.ndim == 5


# reserved top-level key in the swap dict: per-array CRC32 of the saved
# bytes, recorded at swap-out and verified before swap-in touches the pools
CRC_KEY = "__crc__"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def verify_swapped(swapped: dict) -> None:
    """Check every saved array against the checksums `extract_pages`
    recorded; raise :class:`SwapCorruption` on the first mismatch.  A swap
    dict without checksums (older callers, hand-built test fixtures)
    passes unverified."""
    crcs = swapped.get(CRC_KEY)
    if crcs is None:
        return
    for layer_key, layer in swapped.items():
        if layer_key == CRC_KEY:
            continue
        for name, arr in layer.items():
            if _crc(np.asarray(arr)) != crcs[layer_key][name]:
                raise SwapCorruption(
                    f"swap-in checksum mismatch at {layer_key}/{name}: the "
                    f"host copy was corrupted while the request was "
                    f"preempted — refusing to restore it")


def extract_pages(pools: dict, hi_ids: list[int], lo_ids: list[int],
                  slot: int | None = None) -> dict:
    """Copy a request's pages — and, for hybrid stacks, its per-slot SSM
    state — to host memory (vLLM-style swap-out).  The result maps each
    layer key to {array_name: np.ndarray of the selected pages / slot row}
    and restores bit-identically via :func:`insert_pages`, so a preempted
    request resumes from the exact cache state it was evicted with — no
    recompute, no numeric drift.  ``slot`` selects the SSM row for
    slot-dense entries; it is required when the pools contain any.  The
    result also carries a CRC32 per saved array under :data:`CRC_KEY`;
    :func:`insert_pages` verifies them before touching the pools, so
    corruption of the host copy fails loudly (`SwapCorruption`) instead of
    silently resuming garbage."""
    hi = np.asarray(hi_ids, np.int32)
    lo = np.asarray(lo_ids, np.int32)
    swapped = {}
    for layer_key, entry in pools.items():
        if is_ssm_entry(entry):
            if slot is None:
                raise ValueError(
                    "pools hold slot-dense SSM state; extract_pages needs "
                    "the request's slot to swap it out")
            periods = _ssm_has_periods(entry)
            swapped[layer_key] = {
                name: np.asarray(arr[:, slot] if periods else arr[slot])
                for name, arr in entry.items()}
            continue
        periods = _has_periods_axis(entry)
        layer = {}
        for name, arr in entry.items():
            ids = lo if (name in ("k", "v") or "_lo" in name) else hi
            layer[name] = np.asarray(arr[:, ids] if periods else arr[ids])
        swapped[layer_key] = layer
    swapped[CRC_KEY] = {
        layer_key: {name: _crc(arr) for name, arr in layer.items()}
        for layer_key, layer in swapped.items() if layer_key != CRC_KEY}
    return swapped


def insert_pages(pools: dict, swapped: dict, hi_ids: list[int],
                 lo_ids: list[int], slot: int | None = None) -> dict:
    """Swap-in: place saved pages at (possibly different) page ids — and
    saved SSM state at the (possibly different) ``slot`` the scheduler
    re-admitted the request into.  Checksums recorded at swap-out are
    verified *first*: on mismatch the restore raises
    :class:`SwapCorruption` with the pools untouched, so the engine can
    fail just the corrupted request and keep the batch running."""
    verify_swapped(swapped)
    hi = jnp.asarray(np.asarray(hi_ids, np.int32))
    lo = jnp.asarray(np.asarray(lo_ids, np.int32))
    out = {}
    for layer_key, entry in pools.items():
        if is_ssm_entry(entry):
            if slot is None:
                raise ValueError(
                    "pools hold slot-dense SSM state; insert_pages needs "
                    "the resumed request's slot to swap it back in")
            periods = _ssm_has_periods(entry)
            layer = dict(entry)
            for name, arr in entry.items():
                saved = jnp.asarray(swapped[layer_key][name])
                layer[name] = arr.at[:, slot].set(saved) if periods \
                    else arr.at[slot].set(saved)
            out[layer_key] = layer
            continue
        periods = _has_periods_axis(entry)
        layer = dict(entry)
        for name, arr in entry.items():
            ids = lo if (name in ("k", "v") or "_lo" in name) else hi
            if ids.size:
                saved = jnp.asarray(swapped[layer_key][name])
                layer[name] = arr.at[:, ids].set(saved) if periods \
                    else arr.at[ids].set(saved)
        out[layer_key] = layer
    return out


def copy_page(pools: dict, pool: str, src: int, dst: int) -> dict:
    """Copy-on-write device copy: duplicate one physical page (codes +
    scale/zp) from ``src`` to ``dst`` within the named pool, across every
    attention layer.  Used when a prefix-cache match ends mid-page: the
    child reads positions below the divergence point from the copy and
    its first `write_ragged` scatters the divergent tokens into the copy,
    leaving the shared original untouched.  Bytes beyond the divergence
    offset carry the parent's stale values — masked by slot length exactly
    like the null page's residue, never read.  SSM slot entries (hybrid
    stacks) are skipped: recurrent state is per-request, never shared."""
    out = {}
    for layer_key, entry in pools.items():
        if is_ssm_entry(entry):
            out[layer_key] = entry
            continue
        periods = _has_periods_axis(entry)
        layer = dict(entry)
        for name, arr in entry.items():
            in_lo = name in ("k", "v") or "_lo" in name
            if in_lo != (pool == "lo"):
                continue
            layer[name] = arr.at[:, dst].set(arr[:, src]) if periods \
                else arr.at[dst].set(arr[src])
        out[layer_key] = layer
    return out


def swapped_bytes(swapped: dict) -> int:
    """Host bytes one swap-out moved (pages + SSM state) — the
    ``swap_bytes`` stat the serving bench reports per preemption."""
    return sum(int(arr.nbytes)
               for layer_key, layer in swapped.items()
               if layer_key != CRC_KEY
               for arr in layer.values())

"""Continuous-batching scheduler: request queue, slot state machine,
per-step admission/eviction, and block-exhaustion preemption.

The scheduler is pure host-side bookkeeping (deterministic Python over the
numpy prompt arrays) — it never touches device memory.  Each engine step it
produces a :class:`StepPlan`:

* **admissions** — FCFS by arrival.  A request is admitted when a slot is
  free and (for a preempted request resuming) every page it held can be
  re-allocated; the engine then swaps its saved pages back in.
* **one prefill chunk** — the earliest admitted request that still has
  prompt tokens uncached gets its next ``prefill_chunk`` tokens.  Prefill is
  chunked *between* decode steps rather than bucket-padded up front, so a
  long prompt never stalls the running batch for more than one chunk.
* **the decode batch** — every RUNNING slot decodes one token.  Requests
  join and leave this batch at step granularity; there is no lockstep
  bucket.

Preemption: when a decode step needs a fresh page and the pools are
exhausted, the victim is the **latest-admitted** active request (vLLM's
priority rule — earlier arrivals are never starved by later ones).  Pages
reserved ahead of the victim's materialized prefix (a prefill chunk's
reservation not yet executed) are released empty; the rest are swapped to
host memory via the engine callback *before* they are freed, and the
request re-enters the waiting queue at its original arrival rank.  On
resume the saved pages are swapped back in at whatever page ids are then
free — block tables indirect through the pools, so placement is
irrelevant — and generation continues from the exact cache state it was
evicted with (bit-identical, no recompute).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.serving.paged_kvcache import (BlockAllocator, OutOfBlocks,
                                         PagedCacheConfig)

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class SchedRequest:
    """Scheduler-side state for one engine request."""

    uid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int
    arrival: int                     # FCFS rank (never changes)
    state: str = WAITING
    slot: int = -1
    pos: int = 0                     # tokens materialized in the cache
    generated: List[int] = dataclasses.field(default_factory=list)
    hi_pages: List[int] = dataclasses.field(default_factory=list)
    lo_pages: List[int] = dataclasses.field(default_factory=list)
    swapped: Optional[dict] = None   # host-side pages while preempted
    admit_seq: int = -1              # preemption priority (latest = victim)
    preemptions: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def pages_for(self, pos: int, cfg: PagedCacheConfig) -> tuple[int, int]:
        """(hi, lo) page counts needed to hold positions [0, pos)."""
        bs = cfg.block_size
        hi_tokens = min(pos, cfg.num_hi)
        lo_tokens = pos - hi_tokens
        return -(-hi_tokens // bs), -(-lo_tokens // bs)


@dataclasses.dataclass
class StepPlan:
    admitted: List[SchedRequest]
    resumed: List[SchedRequest]      # subset of admitted that swapped back in
    prefill: Optional[SchedRequest]  # next chunk is prompt[pos : pos+chunk]
    decode: List[SchedRequest]       # RUNNING slots, slot-index order
    preempted: List[SchedRequest]    # evicted (already swapped out + freed)


@dataclasses.dataclass
class SchedulerConfig:
    max_slots: int = 8
    prefill_chunk: int = 64


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, cache_cfg: PagedCacheConfig,
                 swap_out: Callable[[SchedRequest], None],
                 swap_in: Callable[[SchedRequest], None]):
        self.cfg = cfg
        self.cache_cfg = cache_cfg
        self.alloc = BlockAllocator(cache_cfg)
        self._swap_out = swap_out
        self._swap_in = swap_in
        self.waiting: List[SchedRequest] = []    # sorted by arrival
        self.active: List[SchedRequest] = []     # PREFILLING | RUNNING
        self._free_slots = list(range(cfg.max_slots))
        self._admit_counter = 0
        self.num_preemptions = 0
        self._step_preempted: List[SchedRequest] = []

    # ------------------------------------------------------------------
    def submit(self, sreq: SchedRequest) -> None:
        self.waiting.append(sreq)
        self.waiting.sort(key=lambda r: r.arrival)

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # ------------------------------------------------------------------
    def plan_step(self) -> StepPlan:
        self._step_preempted: List[SchedRequest] = []
        admitted, resumed = self._admit()
        prefill = self._pick_prefill()
        self._ensure_decode_capacity()
        decode = sorted((r for r in self.active if r.state == RUNNING),
                        key=lambda r: r.slot)
        if prefill is not None and prefill.state != PREFILLING:
            prefill = None           # lost its pages to a decode preemption
        return StepPlan(admitted=admitted, resumed=resumed, prefill=prefill,
                        decode=decode, preempted=self._step_preempted)

    def finish(self, sreq: SchedRequest) -> None:
        sreq.state = FINISHED
        self.active.remove(sreq)
        self._free_slots.append(sreq.slot)
        self._free_slots.sort()
        self.alloc.free(sreq.hi_pages, sreq.lo_pages)
        sreq.hi_pages, sreq.lo_pages = [], []
        sreq.slot = -1

    # ------------------------------------------------------------------
    def _admit(self) -> tuple[List[SchedRequest], List[SchedRequest]]:
        admitted, resumed = [], []
        while self.waiting and self._free_slots:
            sreq = self.waiting[0]
            if sreq.swapped is not None:
                nh, nl = sreq.pages_for(sreq.pos, self.cache_cfg)
                if not self.alloc.can_allocate(nh, nl):
                    break            # resume needs every page back at once
                self.waiting.pop(0)
                sreq.hi_pages = [self.alloc.alloc_hi() for _ in range(nh)]
                sreq.lo_pages = [self.alloc.alloc_lo() for _ in range(nl)]
                self._place(sreq)
                self._swap_in(sreq)
                sreq.swapped = None
                sreq.state = RUNNING if sreq.pos >= sreq.prompt_len \
                    else PREFILLING
                resumed.append(sreq)
            else:
                self.waiting.pop(0)
                self._place(sreq)
                sreq.state = PREFILLING
            admitted.append(sreq)
        return admitted, resumed

    def _place(self, sreq: SchedRequest) -> None:
        sreq.slot = self._free_slots.pop(0)
        sreq.admit_seq = self._admit_counter
        self._admit_counter += 1
        self.active.append(sreq)

    def _pick_prefill(self) -> Optional[SchedRequest]:
        """Strict FCFS: only the earliest-arrival request with prompt tokens
        left may prefill; reserve pages for its next chunk (preempting only
        requests that arrived after it)."""
        cands = sorted((r for r in self.active if r.state == PREFILLING),
                       key=lambda r: r.arrival)
        if not cands:
            return None
        sreq = cands[0]
        end = min(sreq.pos + self.cfg.prefill_chunk, sreq.prompt_len)
        return sreq if self._reserve(sreq, end) else None

    def _ensure_decode_capacity(self) -> None:
        """Every RUNNING slot writes one token this step; make sure the page
        holding that position exists.  On exhaustion the latest arrival is
        evicted — possibly the requester itself, if nothing younger holds
        pages (earlier arrivals are never sacrificed for later ones)."""
        for sreq in sorted((r for r in self.active if r.state == RUNNING),
                           key=lambda r: r.arrival):
            if sreq.state != RUNNING:
                continue             # preempted earlier in this very loop
            if not self._reserve(sreq, sreq.pos + 1):
                # no younger page-holder exists, so sreq is the youngest:
                # swap itself out rather than rob an earlier arrival
                self._preempt(sreq)

    def _reserve(self, sreq: SchedRequest, upto: int) -> bool:
        """Grow the request's page lists to cover positions [0, upto),
        preempting later arrivals as needed."""
        nh, nl = sreq.pages_for(upto, self.cache_cfg)
        need_hi = nh - len(sreq.hi_pages)
        need_lo = nl - len(sreq.lo_pages)
        if need_hi <= 0 and need_lo <= 0:
            return True
        while not self.alloc.can_allocate(max(need_hi, 0), max(need_lo, 0)):
            victim = self._pick_victim(exclude=sreq, after=sreq.arrival)
            if victim is None:
                if not self.active or self.active == [sreq]:
                    raise OutOfBlocks(
                        f"pools too small for a single request "
                        f"(uid={sreq.uid}, upto={upto})")
                return False
            self._preempt(victim)
        sreq.hi_pages += [self.alloc.alloc_hi() for _ in range(need_hi)]
        sreq.lo_pages += [self.alloc.alloc_lo() for _ in range(need_lo)]
        return True

    def _pick_victim(self, exclude: Optional[SchedRequest],
                     after: Optional[int] = None) -> Optional[SchedRequest]:
        cands = [r for r in self.active
                 if r is not exclude and (r.hi_pages or r.lo_pages)]
        if after is not None:
            cands = [r for r in cands if r.arrival > after]
        if not cands:
            return None
        return max(cands, key=lambda r: r.arrival)

    def _preempt(self, victim: SchedRequest) -> None:
        # A prefill reservation runs ahead of execution (`_pick_prefill`
        # covers [0, end) while only [0, pos) is materialized), so a victim
        # caught mid-plan can hold more pages than its materialized prefix.
        # Those extra pages carry no data: release them before the swap so
        # the saved page set always equals the pages_for(pos) re-allocation
        # at resume (extract/insert page counts must agree).
        nh, nl = victim.pages_for(victim.pos, self.cache_cfg)
        extra_hi, extra_lo = victim.hi_pages[nh:], victim.lo_pages[nl:]
        if extra_hi or extra_lo:
            victim.hi_pages = victim.hi_pages[:nh]
            victim.lo_pages = victim.lo_pages[:nl]
            self.alloc.free(extra_hi, extra_lo)
        self._swap_out(victim)       # copies pages to host BEFORE freeing
        self.alloc.free(victim.hi_pages, victim.lo_pages)
        victim.hi_pages, victim.lo_pages = [], []
        self.active.remove(victim)
        self._free_slots.append(victim.slot)
        self._free_slots.sort()
        victim.slot = -1
        victim.state = WAITING
        victim.preemptions += 1
        self.num_preemptions += 1
        self._step_preempted.append(victim)
        self.submit(victim)          # re-enters at its original arrival rank

"""Continuous-batching scheduler: request queue, slot state machine,
per-step admission/eviction, and block-exhaustion preemption.

The scheduler is pure host-side bookkeeping (deterministic Python over the
numpy prompt arrays) — it never touches device memory.  Each engine step it
produces a :class:`StepPlan`:

* **admissions** — FCFS by arrival.  A request is admitted when a slot is
  free and (for a preempted request resuming) every page it held can be
  re-allocated; the engine then swaps its saved pages back in.  With
  ``prefix_caching`` on, a fresh admission first adopts the longest cached
  prefix of its prompt (ref-counted page sharing + copy-on-write at a
  mid-page divergence) and chunked prefill starts at the first uncached
  token — see `_attach_prefix` / `BlockAllocator.lookup_prefix`.
* **prefill chunks** — up to ``max_prefills`` requests that still have
  prompt tokens uncached each get their next ``prefill_chunk`` tokens, in
  strict ``(arrival, uid)`` order (the one-prefill-per-step FCFS limit of
  the two-call engine is lifted; the first candidate that cannot reserve
  pages stops the scan so later arrivals never prefill past it).  Prefill
  is chunked *between* decode steps rather than bucket-padded up front, so
  a long prompt never stalls the running batch for more than one chunk.
  Non-final chunk ends are aligned down to multiples of
  ``transform_window`` so a chunk never splits a STaMP transform block
  mid-window (window ≤ chunk; a window larger than the chunk cannot be
  aligned — the per-chunk sequence transform spans the whole chunk anyway,
  so there is no intra-chunk window to preserve and the chunk is scheduled
  unaligned).
* **the decode batch** — every RUNNING slot decodes one token.  Requests
  join and leave this batch at step granularity; there is no lockstep
  bucket.

Together these form one **ragged step**: each planned prefill chunk is a
query span of ``end - start`` tokens and each RUNNING slot a span of one
token; :meth:`Scheduler.plan_step` returns the per-span ``(query_start,
query_len)`` metadata (`StepPlan.spans`) over the flattened token batch
that `serving/engine.py` hands to `models/lm.paged_unified_step` as a
single device program.

Hybrid stacks (Mamba + attention) add a second state family: per-slot
conv/SSM state, fixed-size per request (``SchedulerConfig.
state_bytes_per_slot``).  Admission already gates on a free slot, which is
exactly the capacity unit of that family — so admission needs no extra
arithmetic, and a preemption victim's SSM state swaps to host *together
with* its pages (the engine's swap callbacks read ``sreq.slot``, which is
still assigned at swap-out time and re-assigned before swap-in).  A stack
with no attention layers (``needs_kv_pages=False``) skips page reservation
entirely — decode can then never be preempted, because a running request's
footprint stops growing once its slot is held.

Preemption: when a decode step needs a fresh page and the pools are
exhausted, the victim is the **latest-admitted** active request (vLLM's
priority rule — earlier arrivals are never starved by later ones).  Pages
reserved ahead of the victim's materialized prefix (a prefill chunk's
reservation not yet executed) are released empty; the rest are swapped to
host memory via the engine callback *before* they are freed, and the
request re-enters the waiting queue at its original arrival rank.  On
resume the saved pages are swapped back in at whatever page ids are then
free — block tables indirect through the pools, so placement is
irrelevant — and generation continues from the exact cache state it was
evicted with (bit-identical, no recompute).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional

import numpy as np

from repro.serving.paged_kvcache import (BlockAllocator, OutOfBlocks,
                                         PagedCacheConfig)

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"
FAILED = "failed"
CANCELLED = "cancelled"
REJECTED = "rejected"

#: States a request never leaves.  Every submitted request ends in exactly
#: one of these; the engine's run() loop terminates when all have.
TERMINAL = (FINISHED, FAILED, CANCELLED, REJECTED)


@dataclasses.dataclass
class SchedRequest:
    """Scheduler-side state for one engine request."""

    uid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int
    arrival: int                     # FCFS rank (never changes)
    state: str = WAITING
    slot: int = -1
    pos: int = 0                     # tokens materialized in the cache
    generated: List[int] = dataclasses.field(default_factory=list)
    hi_pages: List[int] = dataclasses.field(default_factory=list)
    lo_pages: List[int] = dataclasses.field(default_factory=list)
    swapped: Optional[dict] = None   # host-side pages while preempted
    admit_seq: int = -1              # preemption priority (latest = victim)
    preemptions: int = 0
    prefix_matched: int = 0          # tokens served from the prefix cache
    error: Optional[str] = None      # set when state is FAILED / REJECTED

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def pages_for(self, pos: int, cfg: PagedCacheConfig) -> tuple[int, int]:
        """(hi, lo) page counts needed to hold positions [0, pos)."""
        bs = cfg.block_size
        hi_tokens = min(pos, cfg.num_hi)
        lo_tokens = pos - hi_tokens
        return -(-hi_tokens // bs), -(-lo_tokens // bs)


@dataclasses.dataclass
class PrefillWork:
    """One planned prefill chunk: ``sreq.prompt[start:end]`` runs this step
    (pages for [0, end) are already reserved)."""

    sreq: SchedRequest
    start: int
    end: int


@dataclasses.dataclass
class StepPlan:
    admitted: List[SchedRequest]
    resumed: List[SchedRequest]      # subset of admitted that swapped back in
    prefills: List[PrefillWork]      # FCFS-ordered chunks, ≤ max_prefills
    decode: List[SchedRequest]       # RUNNING slots, slot-index order
    preempted: List[SchedRequest]    # evicted (already swapped out + freed)

    @property
    def prefill(self) -> Optional[SchedRequest]:
        """Two-call compatibility view: the single FCFS prefill candidate."""
        return self.prefills[0].sreq if self.prefills else None

    def spans(self) -> List[tuple]:
        """Ragged metadata for the flattened unified batch:
        ``(uid, query_start, query_len)`` per span — prefill chunks first
        (in plan order), then one 1-token span per decode slot.  Offsets are
        cumulative over the flattened token stream."""
        out, off = [], 0
        for w in self.prefills:
            out.append((w.sreq.uid, off, w.end - w.start))
            off += w.end - w.start
        for sreq in self.decode:
            out.append((sreq.uid, off, 1))
            off += 1
        return out


@dataclasses.dataclass
class SchedulerConfig:
    max_slots: int = 8
    prefill_chunk: int = 64
    max_prefills: int = 1            # prefill chunks per (unified) step
    transform_window: int = 1        # align non-final chunk ends to this
    # Hybrid / SSM accounting: a slot pins `state_bytes_per_slot` of HBM the
    # moment a request is admitted (per-slot conv + SSM state across every
    # Mamba layer) — a *fixed* cost, independent of request length, so the
    # free-slot gate in `_admit` IS the capacity check for this state
    # family and no admission arithmetic consumes the number: it is
    # recorded here (set by the engine from the allocated pools) purely
    # for observability — stats and the serving bench report it.  Pages
    # only ever cover the attention layers; a stack with none at all
    # (pure SSM) sets `needs_kv_pages=False`: reservation and
    # preemption-by-page-exhaustion are then no-ops — the only capacity
    # dimension is the slot count.
    state_bytes_per_slot: int = 0
    needs_kv_pages: bool = True
    # High-watermark early preemption: when page-pool occupancy exceeds this
    # fraction of total capacity, the latest arrival is evicted *before*
    # anything actually runs out — exhaustion becomes a planned degradation
    # (one clean swap-out between steps) instead of a mid-reservation
    # scramble.  1.0 disables the watermark (preempt only on true
    # exhaustion, the pre-robustness behavior).
    preempt_watermark: float = 1.0
    # Prefix caching: on fresh admission, look up the longest cached prefix
    # of the prompt (BlockAllocator's hash-addressed page store) and start
    # chunked prefill at the first uncached token, sharing the covered
    # pages by ref count.  Off by default so direct-Scheduler callers are
    # unaffected; `PagedServingEngine` turns it on (and registers completed
    # prompt pages after every chunk).
    prefix_caching: bool = False


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, cache_cfg: PagedCacheConfig,
                 swap_out: Callable[[SchedRequest], None],
                 swap_in: Callable[[SchedRequest], None],
                 cow: Optional[Callable[[SchedRequest, str, int, int],
                                        None]] = None,
                 on_prefix: Optional[Callable] = None):
        self.cfg = cfg
        self.cache_cfg = cache_cfg
        self.alloc = BlockAllocator(cache_cfg)
        self._swap_out = swap_out
        self._swap_in = swap_in
        # copy-on-write device copy: cow(sreq, pool, src_page, dst_page)
        # duplicates one physical page before the request's first divergent
        # write; on_prefix(sreq, match_or_None) observes every lookup
        self._cow = cow
        self._on_prefix = on_prefix
        self.waiting: List[SchedRequest] = []    # sorted by (arrival, uid)
        self.active: List[SchedRequest] = []     # PREFILLING | RUNNING
        # min-heap: O(log n) admission instead of pop(0) + sort(), and the
        # lowest-free-slot-first placement stays deterministic at high slot
        # counts (an ascending range is already a valid heap)
        self._free_slots = list(range(cfg.max_slots))
        self._admit_counter = 0
        self.num_preemptions = 0
        self._step_preempted: List[SchedRequest] = []

    # ------------------------------------------------------------------
    def submit(self, sreq: SchedRequest) -> None:
        self.waiting.append(sreq)
        # (arrival, uid): equal-arrival submissions keep a reproducible
        # order instead of whatever the sort happens to preserve
        self.waiting.sort(key=lambda r: (r.arrival, r.uid))

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # ------------------------------------------------------------------
    def plan_step(self) -> StepPlan:
        self._step_preempted: List[SchedRequest] = []
        admitted, resumed = self._admit()
        self._apply_watermark(skip=admitted)
        prefills = self._pick_prefills()
        self._ensure_decode_capacity()
        decode = sorted((r for r in self.active if r.state == RUNNING),
                        key=lambda r: r.slot)
        # a decode-capacity preemption can evict a planned prefill candidate
        prefills = [w for w in prefills if w.sreq.state == PREFILLING]
        return StepPlan(admitted=admitted, resumed=resumed,
                        prefills=prefills, decode=decode,
                        preempted=self._step_preempted)

    def finish(self, sreq: SchedRequest) -> None:
        sreq.state = FINISHED
        self._release(sreq)

    def fail(self, sreq: SchedRequest, error: str) -> None:
        """Move one request to FAILED and return every resource it holds —
        the batch keeps running; nothing else is touched."""
        sreq.state = FAILED
        sreq.error = error
        self._release(sreq)

    def cancel(self, uid: int, state: str = CANCELLED,
               error: Optional[str] = None) -> Optional[SchedRequest]:
        """Terminate a request by uid wherever it currently is — waiting,
        mid-prefill, running, or preempted-with-swapped-pages — releasing
        exactly the slot/pages it holds.  Returns the request, or None if
        the uid is unknown or already terminal."""
        for sreq in self.active + self.waiting:
            if sreq.uid == uid:
                sreq.state = state
                sreq.error = error
                self._release(sreq)
                return sreq
        return None

    def quiescent(self) -> bool:
        """True when nothing is queued or active and every resource is back
        in its pool: all slots free, all pages free.  The chaos suite's
        no-leak invariant."""
        return (not self.waiting and not self.active
                and len(self._free_slots) == self.cfg.max_slots
                and self.alloc.all_free())

    def load(self) -> dict:
        """Occupancy snapshot for the engine's per-step gauges: queue
        depths, free decode slots, and free pages per pool family."""
        free_hi, free_lo = self.alloc.free_counts()
        return {"waiting": len(self.waiting),
                "active": len(self.active),
                "free_slots": len(self._free_slots),
                "free_hi_pages": free_hi,
                "free_lo_pages": free_lo}

    def _release(self, sreq: SchedRequest) -> None:
        """Return everything a request holds: its slot (if placed), its
        device pages (if any — including pages reserved ahead of the
        materialized prefix, which is why this must free the *lists*, not
        a pages_for() recomputation), and its host-side swap copy."""
        if sreq in self.active:
            self.active.remove(sreq)
            heapq.heappush(self._free_slots, sreq.slot)
            sreq.slot = -1
        elif sreq in self.waiting:
            self.waiting.remove(sreq)
        self.alloc.free(sreq.hi_pages, sreq.lo_pages)
        sreq.hi_pages, sreq.lo_pages = [], []
        sreq.swapped = None

    # ------------------------------------------------------------------
    def _admit(self) -> tuple[List[SchedRequest], List[SchedRequest]]:
        admitted, resumed = [], []
        while self.waiting and self._free_slots:
            sreq = self.waiting[0]
            if sreq.swapped is not None:
                nh, nl = self._pages_for(sreq, sreq.pos)
                if not self.alloc.can_allocate(nh, nl):
                    break            # resume needs every page back at once
                self.waiting.pop(0)
                sreq.hi_pages = [self.alloc.alloc_hi() for _ in range(nh)]
                sreq.lo_pages = [self.alloc.alloc_lo() for _ in range(nl)]
                self._place(sreq)
                self._swap_in(sreq)
                sreq.swapped = None
                sreq.state = RUNNING if sreq.pos >= sreq.prompt_len \
                    else PREFILLING
                resumed.append(sreq)
            else:
                self.waiting.pop(0)
                self._place(sreq)
                sreq.state = PREFILLING
                self._attach_prefix(sreq)
            admitted.append(sreq)
        return admitted, resumed

    # -- prefix caching -------------------------------------------------
    def prefix_quantum(self) -> int:
        """Prefix-match granularity: the *aligned* chunk length.  Every
        cache-off non-final chunk spans exactly this many tokens
        (`_align_chunk_end`), so a match that is a multiple of it restarts
        prefill on a boundary the cache-off engine would also have used —
        identical chunk splits mean identical online-softmax merge order,
        which is what makes cache-on tokens bit-identical."""
        c, w = self.cfg.prefill_chunk, self.cfg.transform_window
        return (c // w) * w if 1 < w <= c else c

    def _prefix_on(self) -> bool:
        return self.cfg.prefix_caching and self.cfg.needs_kv_pages

    def probe_prefix(self, prompt: np.ndarray) -> int:
        """Side-effect-free: tokens a fresh admission of ``prompt`` would
        serve from the cache right now — the submit-time capacity check's
        prefix credit."""
        if not self._prefix_on():
            return 0
        prompt = np.asarray(prompt)
        limit = max(int(prompt.shape[0]) - 1, 0)
        return self.alloc.peek_prefix(prompt, limit, self.prefix_quantum())

    def _attach_prefix(self, sreq: SchedRequest) -> None:
        """Fresh admission: adopt the longest cached prefix of the prompt.
        The match is capped at ``prompt_len - 1`` so at least one prompt
        token always runs through prefill (the final chunk computes the
        first sampled logit).  A match ending mid-page triggers
        copy-on-write: the partial page is duplicated (engine device copy)
        before this request's first chunk scatters into it, and the shared
        original's reference is dropped."""
        if not self._prefix_on():
            return
        limit = sreq.prompt_len - 1
        m = self.alloc.lookup_prefix(sreq.prompt, limit,
                                     self.prefix_quantum()) \
            if limit > 0 else None
        if self._on_prefix is not None:
            self._on_prefix(sreq, m)
        if m is None:
            return
        if m.cow is not None:
            pool, idx = m.cow
            pages = m.hi_pages if pool == "hi" else m.lo_pages
            src = pages[idx]
            try:
                dst = self.alloc.alloc_hi() if pool == "hi" \
                    else self.alloc.alloc_lo()
            except OutOfBlocks:
                # raced out of the copy page lookup_prefix checked for:
                # fall back to an uncached start rather than fail
                self.alloc.release(m.hi_pages, m.lo_pages)
                return
            if self._cow is not None:
                self._cow(sreq, pool, src, dst)
            pages[idx] = dst
            self.alloc.release([src] if pool == "hi" else [],
                               [src] if pool == "lo" else [])
        sreq.hi_pages = m.hi_pages
        sreq.lo_pages = m.lo_pages
        sreq.pos = m.matched
        sreq.prefix_matched = m.matched

    def register_prefix(self, sreq: SchedRequest) -> int:
        """Register the request's fully-materialized prompt pages in the
        prefix cache (the engine calls this after every completed prefill
        chunk, before any release).  Returns new registrations."""
        if not self._prefix_on():
            return 0
        return self.alloc.register_prefix(sreq.prompt, sreq.pos,
                                          sreq.hi_pages, sreq.lo_pages)

    def _place(self, sreq: SchedRequest) -> None:
        sreq.slot = heapq.heappop(self._free_slots)
        sreq.admit_seq = self._admit_counter
        self._admit_counter += 1
        self.active.append(sreq)

    def _align_chunk_end(self, sreq: SchedRequest, end: int) -> int:
        """Transform-aware chunk boundary: align a *non-final* chunk end
        down to a multiple of ``transform_window`` tokens from the chunk
        start, so the per-chunk STaMP sequence transform never operates on
        a split transform block.  Chunk starts stay aligned by induction
        (every earlier non-final chunk had aligned length).  The final
        chunk keeps the exact prompt end.  window > chunk budget cannot be
        aligned — the per-chunk transform covers the whole chunk, so there
        is no intra-chunk window to preserve and the end is kept as is
        (the documented fallback)."""
        w = self.cfg.transform_window
        if w <= 1 or end >= sreq.prompt_len:
            return end
        span = (end - sreq.pos) // w * w
        return sreq.pos + span if span > 0 else end

    def _apply_watermark(self, skip: List[SchedRequest]) -> None:
        """High-watermark early preemption (``preempt_watermark`` < 1.0):
        while page occupancy exceeds the watermark fraction, swap out the
        latest-admitted page-holder so upcoming reservations find planned
        headroom instead of hitting exhaustion mid-plan.  Requests admitted
        *this step* are exempt — evicting one the same step it came in
        would thrash swap-in/swap-out without ever making progress."""
        wm = self.cfg.preempt_watermark
        if wm >= 1.0 or not self.cfg.needs_kv_pages:
            return
        cap_hi, cap_lo = self.alloc.capacity()
        total = cap_hi + cap_lo
        if total == 0:
            return
        while True:
            # evictable (zero-ref cached) pages count as headroom: they are
            # reclaimed inside alloc_* on demand, so cache occupancy alone
            # must never trigger a preemption
            avail_hi, avail_lo = self.alloc.available_counts()
            if total - avail_hi - avail_lo <= wm * total:
                return
            cands = [r for r in self.active
                     if (r.hi_pages or r.lo_pages) and r not in skip]
            if len(cands) <= 1:
                return               # never evict the only page-holder
            self._preempt(max(cands, key=lambda r: (r.arrival, r.uid)))

    def _pick_prefills(self) -> List[PrefillWork]:
        """Strict FCFS over PREFILLING requests, ``(arrival, uid)`` order:
        up to ``max_prefills`` of them get a chunk this step.  The first
        candidate that cannot reserve its pages stops the scan — a later
        arrival never prefills past an earlier blocked one."""
        cands = sorted((r for r in self.active if r.state == PREFILLING),
                       key=lambda r: (r.arrival, r.uid))
        out: List[PrefillWork] = []
        for sreq in cands[: self.cfg.max_prefills]:
            if sreq.state != PREFILLING:
                continue             # preempted by an earlier reservation
            end = min(sreq.pos + self.cfg.prefill_chunk, sreq.prompt_len)
            end = self._align_chunk_end(sreq, end)
            if not self._reserve(sreq, end):
                break
            out.append(PrefillWork(sreq, sreq.pos, end))
        return out

    def _ensure_decode_capacity(self) -> None:
        """Every RUNNING slot writes one token this step; make sure the page
        holding that position exists.  On exhaustion the latest arrival is
        evicted — possibly the requester itself, if nothing younger holds
        pages (earlier arrivals are never sacrificed for later ones)."""
        for sreq in sorted((r for r in self.active if r.state == RUNNING),
                           key=lambda r: r.arrival):
            if sreq.state != RUNNING:
                continue             # preempted earlier in this very loop
            if not self._reserve(sreq, sreq.pos + 1):
                # no younger page-holder exists, so sreq is the youngest:
                # swap itself out rather than rob an earlier arrival
                self._preempt(sreq)

    def _pages_for(self, sreq: SchedRequest, pos: int) -> tuple[int, int]:
        """Page demand for positions [0, pos) — zero for a pageless stack
        (pure SSM: the per-slot state is the whole cache and is already
        accounted by the slot the request holds)."""
        if not self.cfg.needs_kv_pages:
            return 0, 0
        return sreq.pages_for(pos, self.cache_cfg)

    def _reserve(self, sreq: SchedRequest, upto: int) -> bool:
        """Grow the request's page lists to cover positions [0, upto),
        preempting later arrivals as needed."""
        nh, nl = self._pages_for(sreq, upto)
        need_hi = nh - len(sreq.hi_pages)
        need_lo = nl - len(sreq.lo_pages)
        if need_hi <= 0 and need_lo <= 0:
            return True
        while not self.alloc.can_allocate(max(need_hi, 0), max(need_lo, 0)):
            victim = self._pick_victim(exclude=sreq, after=sreq.arrival)
            if victim is None:
                # Nobody younger holds pages.  This used to raise
                # OutOfBlocks when sreq was alone (tearing down the whole
                # engine); capacity-infeasible requests are now rejected at
                # submit() and anything else that lands here — injected
                # exhaustion, a transiently blocked resume — is a per-step
                # "no" the caller degrades around (preempt-self / wait),
                # with the engine watchdog as the livelock backstop.
                return False
            self._preempt(victim)
        sreq.hi_pages += [self.alloc.alloc_hi() for _ in range(need_hi)]
        sreq.lo_pages += [self.alloc.alloc_lo() for _ in range(need_lo)]
        return True

    def _pick_victim(self, exclude: Optional[SchedRequest],
                     after: Optional[int] = None) -> Optional[SchedRequest]:
        cands = [r for r in self.active
                 if r is not exclude and (r.hi_pages or r.lo_pages)]
        if after is not None:
            cands = [r for r in cands if r.arrival > after]
        if not cands:
            return None
        # (arrival, uid): equal-arrival candidates evict reproducibly —
        # `max` alone would pick whichever tied request came first in the
        # active list, an artifact of admission history
        return max(cands, key=lambda r: (r.arrival, r.uid))

    def _preempt(self, victim: SchedRequest) -> None:
        # A prefill reservation runs ahead of execution (`_pick_prefill`
        # covers [0, end) while only [0, pos) is materialized), so a victim
        # caught mid-plan can hold more pages than its materialized prefix.
        # Those extra pages carry no data: release them before the swap so
        # the saved page set always equals the pages_for(pos) re-allocation
        # at resume (extract/insert page counts must agree).
        nh, nl = self._pages_for(victim, victim.pos)
        extra_hi, extra_lo = victim.hi_pages[nh:], victim.lo_pages[nl:]
        if extra_hi or extra_lo:
            victim.hi_pages = victim.hi_pages[:nh]
            victim.lo_pages = victim.lo_pages[:nl]
            self.alloc.free(extra_hi, extra_lo)
        self._swap_out(victim)       # copies pages to host BEFORE freeing
        self.alloc.free(victim.hi_pages, victim.lo_pages)
        victim.hi_pages, victim.lo_pages = [], []
        self.active.remove(victim)
        heapq.heappush(self._free_slots, victim.slot)
        victim.slot = -1
        victim.state = WAITING
        victim.preemptions += 1
        self.num_preemptions += 1
        self._step_preempted.append(victim)
        self.submit(victim)          # re-enters at its original arrival rank

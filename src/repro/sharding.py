"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Physical meshes (see ``repro.launch.mesh``):

* single-pod: ``(16, 16)`` over ``("data", "model")``
* multi-pod:  ``(2, 16, 16)`` over ``("pod", "data", "model")``

Policy:

* **FSDP** — parameters, gradients and optimizer moments are sharded over the
  data axes on the dimension *not* used for tensor parallelism (ZeRO-3 via
  GSPMD: the all-gather happens at use).
* **TP** — the flattened head / ffn / expert dimension is sharded over
  ``model``.  We deliberately shard the *flat* projections (e.g.
  ``n_heads·head_dim``) rather than the head axis so meshes larger than the
  head count (MiniCPM: 36 heads, Arctic: 56) still divide.
* **Sequence/context parallelism** — activations between blocks are either
  replicated over ``model`` (baseline) or sequence-sharded (``seq_sharded
  =True``, the Megatron-SP analogue — a hillclimb lever).  Decode KV caches
  are always context-parallel: sequence axis over ``model``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    multi_pod: bool = False
    seq_sharded: bool = False          # Megatron-SP-style residual sharding
    fsdp_over_pod: bool = True         # include 'pod' in the FSDP axes
    serve_replicated_weights: bool = False   # inference: drop the FSDP axis
    # (int4 weights fit replicated over 'data'; kills the per-layer
    #  all-gather that FSDP pays on every decode step)

    @property
    def batch_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def fsdp_axes(self):
        if self.serve_replicated_weights:
            return ()
        if self.multi_pod and self.fsdp_over_pod:
            return ("pod", "data")
        # bare string, not ("data",): identical GSPMD semantics, but older
        # jax PartitionSpec __eq__ does not normalize 1-tuples to strings
        return "data"

    # -- parameter rules ---------------------------------------------------

    def param_spec(self, path: str, ndim: int) -> P:
        """Rule table keyed on parameter-tree path substrings.  Stacked
        (scanned) parameters carry a leading period axis mapped to None.
        Packed-int4 serving weights ("…/wq/q", "…/wq/scale") and fused-path
        prepared weights ("…/wq/iq", "…/wq/isw", "…/wq/izw") inherit the
        parent weight's rule (scale/zp/isw/izw have a broadcast leading
        dim)."""
        fsdp, tp = self.fsdp_axes, "model"
        packed_leaf = None
        for suffix in ("/q", "/scale", "/zp", "/iq", "/isw", "/izw"):
            if path.endswith(suffix):
                packed_leaf = suffix[1:]
                path = path[: -len(suffix)]
                break
        rules = [
            # embeddings / lm head
            (r"embed$", P(tp, fsdp)),
            (r"head$", P(fsdp, tp)),
            # attention projections (flat head dims; wqkv = fused-path
            # concatenated self-attention weights, same layout)
            (r"(wq|wk|wv|wqkv|xwq|xwk|xwv)$", P(fsdp, tp)),
            (r"(wo|xwo)$", P(tp, fsdp)),
            (r"(bq|bk|bv)$", P(tp)),
            # dense mlp
            (r"(wi_gate|wi_up|dwi_gate|dwi_up)$", P(fsdp, tp)),
            (r"(wo_mlp|dwo)$", P(tp, fsdp)),
            # moe — expert axis over 'model' (expert-parallel).  The fused
            # path's prepared int8 expert buffers (we_*/iq stacked
            # (E, din, dout) codes with (E, 1, dout) isw/izw) inherit these
            # rules through the suffix strip above, so each model shard
            # holds only its own experts' codes and the grouped kernel's
            # capacity buckets stay local to the expert shard.
            (r"gate_w$", P(fsdp, None)),
            (r"(we_gate|we_up)$", P(tp, fsdp, None)),
            (r"we_down$", P(tp, None, fsdp)),
            # mamba
            (r"in_proj$", P(fsdp, tp)),
            (r"out_proj$", P(tp, fsdp)),
            (r"(conv_w|a_log|d_skip|dt_bias|ssm_norm)$", P()),
            # norms / scalars
            (r"(ln1|ln2|lnx|final_norm|enc_final_norm)$", P()),
        ]
        spec = None
        for pat, s in rules:
            if re.search(pat, path):
                spec = s
                break
        if spec is None:
            spec = P()
        if packed_leaf in ("scale", "zp", "isw", "izw") and len(spec) >= 2:
            # (…, 1, dout): keep only the output-dim sharding
            spec = P(*spec[:-2], None, spec[-1])
        # stacked-layer leading axis
        extra = ndim - len(spec)
        if extra > 0:
            spec = P(*([None] * extra), *spec)
        return spec

    def params_shardings(self, params_shapes: Pytree) -> Pytree:
        paths_and_leaves = jax.tree_util.tree_flatten_with_path(params_shapes)
        flat, treedef = paths_and_leaves
        out = []
        for path, leaf in flat:
            parts = []
            for k in path:
                if hasattr(k, "key"):
                    parts.append(str(k.key))
                elif hasattr(k, "idx"):
                    parts.append(str(k.idx))
                else:
                    parts.append(str(k))
            name = "/".join(parts)
            out.append(self.named(self.param_spec(name, len(leaf.shape))))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- activation / data rules -------------------------------------------

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def tokens(self) -> P:
        return P(self.batch_axes, None)

    def acts(self) -> P:
        """Residual-stream constraint between blocks."""
        if self.seq_sharded:
            return P(self.batch_axes, "model", None)
        return P(self.batch_axes, None, None)

    def frontend_embeds(self) -> P:
        return P(self.batch_axes, None, None)

    def kv_cache(self) -> P:
        """(periods, b, s, kv, hd)-style caches: batch over data, sequence
        over model (context-parallel decode)."""
        return P(None, self.batch_axes, "model", None, None)

    def kv_cache_packed(self) -> P:
        return self.kv_cache()

    def kv_scale(self) -> P:
        return P(None, self.batch_axes, "model", None)

    def decode_kv_spec(self, global_batch: int) -> P:
        """(b, s, kv, hd) dequantized cache slice during decode: keep the
        sequence axis context-parallel so softmax reduces in place instead of
        GSPMD replicating the cache."""
        data = 1
        for ax in self.batch_axes:
            data *= self.mesh.shape[ax]
        if global_batch >= data:
            return P(self.batch_axes, "model", None, None)
        return P(None, tuple(self.batch_axes) + ("model",), None, None)

    def ssm_state(self) -> P:
        # (periods, [pos,] b, h, p, n): batch over data, heads over model
        return P(None, self.batch_axes, "model", None, None)

    def conv_cache(self) -> P:
        return P(None, self.batch_axes, None, "model")

    def constraint(self, x: jax.Array, spec: P) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.named(spec))


def constrain(x, policy: Optional[ShardingPolicy], spec_fn):
    """No-op when no policy is supplied (single-device tests)."""
    if policy is None:
        return x
    return policy.constraint(x, spec_fn(policy))

"""Unified LM: dense / MoE / hybrid / SSM / enc-dec, train + prefill + decode.

Layer stacking follows the period plan from ``ModelConfig.layer_plan()``:
periods are `lax.scan`'d (compact HLO at 512-way SPMD), layers inside a
period are unrolled.  Parameters are stored f32 and cast to bf16 at use
(classic mixed precision); serving paths optionally swap the large matmuls
for packed-int4 weights (paper's W4) and always run the mixed-precision
quantized KV cache + STaMP activation fake-quant when enabled.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stamp import StampConfig, stamp_fake_quant
from repro.core.quant import fake_quant
from repro.obs import quantstats as QS
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig, ShapeConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.sharding import ShardingPolicy, constrain

Array = jax.Array
Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Inference-time quantization configuration (the paper's W4A4KV4)."""

    stamp: Optional[StampConfig] = None          # activation STaMP at prefill
    kv: KV.KVCacheConfig = KV.KVCacheConfig()
    weight_bits: Optional[int] = None            # 4 => packed-int4 weights
    cache_capacity: Optional[int] = None         # reserve room for decode
    fused_cache_attention: bool = False          # Pallas kernel decode path
    # (TPU deployment; on CPU runs in interpret mode — see
    #  kernels/cache_attention.py for the traffic analysis)
    fused_decode_matmul: bool = False            # single-token int8 kernel
    # against prepared weights (kernels/decode_matmul.py) instead of the
    # per-step bf16 dequant of the same buffers
    paged: Optional["PKV.PagedCacheConfig"] = None   # block-paged cache
    # (continuous-batching engine; None = contiguous per-slot cache)
    numerics_guard: bool = False  # serving engines check step outputs for
    # NaN/Inf and quarantine the offending request (engine.py) — the
    # low-precision escape hatch: sub-8-bit activation formats are one
    # outlier away from saturation, and one poisoned request must not
    # take down the batch
    quant_telemetry: bool = False  # per-STaMP-site quant-health stats
    # (clip rate, hi-token coverage, scale range, saturation — see
    # repro/obs/quantstats.py) returned alongside the step outputs as
    # on-device scalar reductions in the SAME program: zero extra device
    # dispatches per step.  Opt-in: changes the arity of prefill /
    # paged_prefill_chunk / paged_unified_step returns


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dense_init(key, din, dout, dtype, std=None):
    std = std if std is not None else (1.0 / np.sqrt(din))
    return (jax.random.normal(key, (din, dout), jnp.float32) * std).astype(dtype)


def init_layer_params(key, spec: LayerSpec, cfg: ModelConfig,
                      dtype=jnp.float32) -> dict:
    keys = iter(jax.random.split(key, 24))
    d = cfg.d_model
    p: dict = {}
    if spec.mixer == "attn":
        p["ln1"] = jnp.ones((d,), dtype)
        p["wq"] = _dense_init(next(keys), d, cfg.q_dim, dtype)
        p["wk"] = _dense_init(next(keys), d, cfg.kv_dim, dtype)
        p["wv"] = _dense_init(next(keys), d, cfg.kv_dim, dtype)
        p["wo"] = _dense_init(next(keys), cfg.q_dim, d, dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
            p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
            p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
        if cfg.encoder_layers:  # decoder layers carry cross-attention
            p["lnx"] = jnp.ones((d,), dtype)
            p["xwq"] = _dense_init(next(keys), d, cfg.q_dim, dtype)
            p["xwk"] = _dense_init(next(keys), d, cfg.kv_dim, dtype)
            p["xwv"] = _dense_init(next(keys), d, cfg.kv_dim, dtype)
            p["xwo"] = _dense_init(next(keys), cfg.q_dim, d, dtype)
    elif spec.mixer == "mamba":
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        conv_dim = di + 2 * n
        p["ln1"] = jnp.ones((d,), dtype)
        p["in_proj"] = _dense_init(next(keys), d, 2 * di + 2 * n + h, dtype)
        p["conv_w"] = (jax.random.normal(next(keys), (cfg.conv_width, conv_dim),
                                         jnp.float32) * 0.1).astype(dtype)
        p["a_log"] = jnp.zeros((h,), jnp.float32)
        p["dt_bias"] = jnp.full((h,), -2.0, jnp.float32)
        p["d_skip"] = jnp.ones((h,), jnp.float32)
        p["ssm_norm"] = jnp.ones((di,), dtype)
        p["out_proj"] = _dense_init(next(keys), di, d, dtype)
    if spec.ffn in ("mlp", "moe_dense"):
        prefix = "d" if spec.ffn == "moe_dense" else ""
        p["ln2"] = jnp.ones((d,), dtype)
        p[f"{prefix}wi_gate"] = _dense_init(next(keys), d, cfg.d_ff, dtype)
        p[f"{prefix}wi_up"] = _dense_init(next(keys), d, cfg.d_ff, dtype)
        p[f"{prefix}wo_mlp"] = _dense_init(next(keys), cfg.d_ff, d, dtype)
    if spec.ffn in ("moe", "moe_dense"):
        e, f = cfg.num_experts, cfg.expert_d_ff
        p["ln2"] = jnp.ones((d,), dtype)
        p["gate_w"] = _dense_init(next(keys), d, e, dtype)
        std = 1.0 / np.sqrt(d)
        p["we_gate"] = (jax.random.normal(next(keys), (e, d, f), jnp.float32)
                        * std).astype(dtype)
        p["we_up"] = (jax.random.normal(next(keys), (e, d, f), jnp.float32)
                      * std).astype(dtype)
        p["we_down"] = (jax.random.normal(next(keys), (e, f, d), jnp.float32)
                        * (1.0 / np.sqrt(f))).astype(dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    pro, period, nper = cfg.layer_plan()
    k_embed, k_head, k_pro, k_per, k_enc = jax.random.split(key, 5)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(k_head, cfg.d_model, cfg.padded_vocab,
                                     dtype)
    if pro:
        pro_keys = jax.random.split(k_pro, len(pro))
        params["prologue"] = tuple(
            init_layer_params(k, s, cfg, dtype) for k, s in zip(pro_keys, pro))
    per_keys = jax.random.split(k_per, nper)
    stacked = jax.vmap(
        lambda k: tuple(init_layer_params(kk, s, cfg, dtype)
                        for kk, s in zip(jax.random.split(k, len(period)), period))
    )(per_keys)
    params["period"] = stacked
    if cfg.encoder_layers:
        enc_spec = LayerSpec("attn", "mlp")
        enc_cfg = dataclasses.replace(cfg, encoder_layers=0)  # no cross in enc
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = {
            "period": jax.vmap(
                lambda k: (init_layer_params(k, enc_spec, enc_cfg, dtype),)
            )(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# (possibly quantized) linears
# ---------------------------------------------------------------------------


def _linear(x: Array, w, b=None) -> Array:
    """Matmul accepting a plain array, a packed-int4 dict
    ``{"q": (din/2, dout) uint8, "scale": (1, dout), "zp": (1, dout)}`` or a
    fused-path prepared dict ``{"iq", "isw", "izw"}`` (signed int8 codes —
    used directly by decode/no-STaMP call sites that share the serving
    params)."""
    if isinstance(w, dict) and "iq" in w:
        if _FUSED_DECODE_MATMUL and x.ndim >= 2 and x.shape[-2] == 1:
            # decode-shaped call (one token per slot): consume the cached
            # int8 codes directly in the fused kernel instead of
            # re-materializing the bf16 weight every step
            from repro.kernels import ops as kops
            lead = x.shape[:-1]
            y = kops.stamp_decode_matmul(
                x.reshape(-1, x.shape[-1]), w["iq"], w["isw"], w["izw"],
                b, out_dtype=x.dtype)
            return y.reshape(*lead, y.shape[-1])
        # target-dtype arithmetic for the same reason as _dequant_packed:
        # the dequant intermediate is what FSDP all-gathers, and the signed
        # codes / zero points are integers in [-128, 127] — exact in bf16
        # (prepare_linear anchors the quant range at zero to guarantee it)
        wd = ((w["iq"].astype(x.dtype) - w["izw"].astype(x.dtype)) *
              w["isw"].astype(x.dtype))
    elif isinstance(w, dict):
        wd = _dequant_packed(w, x.dtype)
    else:
        wd = w.astype(x.dtype)
    y = x @ wd
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _use_fused(stamp: Optional[StampConfig], w) -> bool:
    """Dispatch to the fused integer kernel only when the serving params hold
    prepared int8 buffers for this site *and* STaMP is active in fused mode
    (prefill; decode passes stamp=None and takes the dequant `_linear`)."""
    return (stamp is not None and stamp.enabled
            and stamp.execution == "fused"
            and isinstance(w, dict) and "iq" in w)


def _dequant_packed(w: dict, dtype) -> Array:
    # arithmetic entirely in the target dtype: an f32 dequant intermediate
    # becomes the tensor GSPMD all-gathers for FSDP-sharded weights (2×
    # the bytes of bf16, 8× the packed bytes); zp ≤ 15 and int4 codes are
    # exact in bf16 (§Perf decode iter 4).
    q = KV.unpack_nibbles(jnp.swapaxes(w["q"], -1, -2)).astype(dtype)
    q = jnp.swapaxes(q, -1, -2)                              # (din, dout)
    return (q - w["zp"].astype(dtype)) * w["scale"].astype(dtype)


def quantize_weights_for_serving(params: Pytree, bits: int = 4) -> Pytree:
    """Pack the large matmul weights to int4 (nibbles along d_in).  Norms,
    biases, embeddings and small SSM params stay bf16/f32."""
    big = ("wq", "wk", "wv", "wo", "xwq", "xwk", "xwv", "xwo",
           "wi_gate", "wi_up", "wo_mlp", "dwi_gate", "dwi_up", "dwo_mlp",
           "we_gate", "we_up", "we_down", "in_proj", "out_proj")

    def visit(tree):
        if isinstance(tree, dict):
            return {k: (pack_weight(v, bits) if k in big else visit(v))
                    for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(visit(t) for t in tree)
        return tree

    return visit(params)


# Per-site fused-wiring table: every prefill-path STaMP linear and how the
# fused integer kernel consumes its prepared int8 buffers.
#
#   single — one `stamp_quant_matmul` call (the attention out-proj feeds
#            the raw (b, s, nh, hd) attention output; the head merge fuses
#            with the kernel's in-VMEM quantize);
#   pair   — the SwiGLU gate/up pair shares ONE transform+quantize through
#            the dual-output kernel (`stamp_quant_dual_matmul`, silu·mul
#            epilogue);
#   merged — wq/wk/wv concatenate into one "wqkv" buffer at prepare time so
#            prefill issues a single kernel call over the full QKV width.
#
#   grouped — the stacked (E, din, dout) expert buffers prepare in one
#            `prepare_linear` pass (per-output-channel scales per expert)
#            and feed `stamp_quant_grouped_matmul`, which walks capacity
#            buckets with the router occupancy scalar-prefetched.
#
# Cross-attention projections (xw*) stay un-prepared: the paper applies no
# sequence transform at pooled-conditioning sites (Table 4).
FUSED_SITES = {
    "wo": "single",              # attention out-proj (head-merge fused)
    "wo_mlp": "single", "dwo_mlp": "single",
    "in_proj": "single", "out_proj": "single",   # mamba projections
    "wi_gate": "pair", "wi_up": "pair",
    "dwi_gate": "pair", "dwi_up": "pair",
    "we_gate": "grouped", "we_up": "grouped", "we_down": "grouped",
}
_QKV = ("wq", "wk", "wv")
_QKV_BIAS = ("bq", "bk", "bv")
_PAIRS = (("wi_gate", "wi_up"), ("dwi_gate", "dwi_up"))


def fused_site_matrix(cfg: ModelConfig, stamp: Optional[StampConfig],
                      feature_rot=None) -> dict:
    """Eligibility audit: every STaMP site this architecture instantiates,
    mapped to ``fused`` or ``reference`` with structured reason codes.

    The per-config half of ``repro.analysis.contracts`` (and the serve-time
    init log): config-level ineligibility comes from
    `repro.core.stamp.fused_ineligibility`, site-level structural
    ineligibility (MoE expert einsums, cross-attention, the encoder) is
    stated here explicitly instead of falling through an implicit branch.
    Cells: ``{"status", "kernel", "wiring", "layers", "reasons"}`` keyed by
    the telemetry site label (``qkv``/``wo``/``gate_up``/``wo_mlp``/
    ``moe``/``in_proj``/``out_proj``/``cross_attn``/``encoder``).
    """
    from repro.core.stamp import fused_ineligibility
    base = (("stamp_disabled",) if stamp is None
            else fused_ineligibility(stamp, feature_rot))
    pro, period, nper = cfg.layer_plan()
    specs = pro + period * nper
    matrix: dict = {}

    def add(site, kernel, wiring, site_reasons=()):
        reasons = tuple(site_reasons) + (() if site_reasons else base)
        cell = matrix.setdefault(site, {
            "status": "fused" if not reasons else "reference",
            "kernel": kernel if not reasons else None,
            "wiring": wiring,
            "layers": 0,
            "reasons": list(reasons),
        })
        cell["layers"] += 1

    for spec in specs:
        if spec.mixer == "attn":
            add("qkv", "stamp_quant_matmul", "merged_wqkv")
            add("wo", "stamp_quant_matmul", "single_head_merge")
        elif spec.mixer == "mamba":
            add("in_proj", "stamp_quant_matmul", "single")
            add("out_proj", "stamp_quant_matmul", "single")
        if spec.ffn in ("mlp", "moe_dense"):
            add("gate_up", "stamp_quant_dual_matmul", "pair")
            add("wo_mlp", "stamp_quant_matmul", "single")
        if spec.ffn in ("moe", "moe_dense"):
            # capacity-dispatched (b, E, C, d) expert tensors run through
            # the grouped kernel: quantize-once dispatch + occupancy-
            # prefetched int8 expert GEMMs (config-level eligibility only)
            add("moe", "stamp_quant_grouped_matmul", "grouped_dispatch")
    if cfg.encoder_layers:
        # pooled-conditioning sites carry no sequence transform (Table 4)
        for _ in range(len(specs)):
            add("cross_attn", None, "reference_xattn",
                site_reasons=("site_cross_attn_no_seq_transform",))
        for _ in range(cfg.encoder_layers):
            add("encoder", None, "reference_encoder",
                site_reasons=("site_encoder_unstamped",))
    return matrix


def prepare_fused_weights(params: Pytree, stamp: StampConfig) -> Pytree:
    """Hoist the fused sites' weights into cached int8 buffers
    ``{"iq", "isw", "izw"}`` (per-output-channel scales, signed codes);
    self-attention wq/wk/wv merge into one ``"wqkv"`` entry and their biases
    into ``"bqkv"`` (concatenated **once here**, not per forward call), and
    each gate/up pair stacks into one `prepare_linear` call.

    Runs once at engine/benchmark setup; stacked ``(nper, din, dout)`` period
    weights prepare in one shot and slice cleanly under `lax.scan`.  Packed
    int4 dicts from :func:`quantize_weights_for_serving` are dequantized
    first and re-coded at ``stamp.fused_weight_bits``.  No-op when the config
    cannot run the fused kernel.
    """
    from repro.core.stamp import fused_eligible, prepare_linear
    if not fused_eligible(stamp):
        return params

    def raw(w):
        return _dequant_packed(w, jnp.float32) if isinstance(w, dict) \
            else w.astype(jnp.float32)

    def prep(w):
        p = prepare_linear(raw(w), bits=stamp.fused_weight_bits)
        return {"iq": p.qw, "isw": p.sw, "izw": p.zw}

    def prep_pair(wg, wu):
        # stacked (2, din, dout) prepare: per-output-channel scales make it
        # identical to two separate prepares, in one pass over the pair
        p = prepare_linear(jnp.stack([raw(wg), raw(wu)]),
                           bits=stamp.fused_weight_bits)
        return ({"iq": p.qw[0], "isw": p.sw[0], "izw": p.zw[0]},
                {"iq": p.qw[1], "isw": p.sw[1], "izw": p.zw[1]})

    def visit(tree):
        if isinstance(tree, dict):
            items = dict(tree)
            out = {}
            if all(k in items for k in _QKV) and "wqkv" not in items:
                # per-output-channel scales make prepare(concat) identical
                # to concat(prepare): quantize the merged buffer directly
                raws = [raw(items.pop(k)) for k in _QKV]
                out["wqkv"] = prep(jnp.concatenate(raws, axis=-1))
                if all(k in items for k in _QKV_BIAS):
                    out["bqkv"] = jnp.concatenate(
                        [items.pop(k) for k in _QKV_BIAS], axis=-1)
            for kg, ku in _PAIRS:
                if kg in items and ku in items and \
                        not (isinstance(items[kg], dict)
                             and "iq" in items[kg]):
                    out[kg], out[ku] = prep_pair(items.pop(kg),
                                                 items.pop(ku))
            for k, v in items.items():
                if k == "encoder":
                    # the encoder never runs STaMP (stamp=None in
                    # _encoder_forward): quantizing it is pure precision loss
                    out[k] = v
                elif k in FUSED_SITES and \
                        not (isinstance(v, dict) and "iq" in v):
                    out[k] = prep(v)
                else:
                    out[k] = visit(v)
            return out
        if isinstance(tree, tuple):
            return tuple(visit(t) for t in tree)
        return tree

    return visit(params)


def pack_weight(w: Array, bits: int = 4) -> dict:
    """(…, din, dout) → packed dict; per-output-channel asymmetric scales."""
    n = float(2**bits - 1)
    wf = w.astype(jnp.float32)
    mn = jnp.min(wf, axis=-2, keepdims=True)
    mx = jnp.max(wf, axis=-2, keepdims=True)
    scale = jnp.maximum((mx - mn) / n, 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(wf / scale) + zp, 0.0, n)
    qt = jnp.swapaxes(q, -1, -2)                             # (dout, din)
    packed = KV.pack_nibbles(qt)
    return {"q": jnp.swapaxes(packed, -1, -2), "scale": scale, "zp": zp}


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


_FUSED_CACHE_ATTENTION = False
_FUSED_DECODE_MATMUL = False


def kw_fused(kv_cfg) -> bool:
    return _FUSED_CACHE_ATTENTION


def set_fused_cache_attention(enabled: bool) -> None:
    """Route decode attention through the Pallas packed-cache kernel
    (kernels/cache_attention.py for the contiguous layout,
    kernels/paged_attention.py for the paged one).  Module-level switch so
    the functional layer code stays signature-stable; the serving engine
    sets it from ``ServeConfig.fused_cache_attention``."""
    global _FUSED_CACHE_ATTENTION
    _FUSED_CACHE_ATTENTION = enabled


def set_fused_decode_matmul(enabled: bool) -> None:
    """Route decode-shaped linears over prepared int8 weights through
    `kernels/decode_matmul.stamp_decode_matmul` (no per-step bf16 weight
    re-materialization).  Set from ``ServeConfig.fused_decode_matmul`` at
    each decode entry point and reset to False by every prefill/train/eval
    entry (`model_hidden`, `paged_prefill_chunk`): the `_linear` dispatch
    keys only on the token dimension being 1, so a stale True from an
    earlier decode would silently skip the STaMP transform on any later
    length-1-sequence forward."""
    global _FUSED_DECODE_MATMUL
    _FUSED_DECODE_MATMUL = enabled


def _collect_telemetry(serve: ServeConfig) -> bool:
    """Static (Python-level) gate for quant telemetry: only meaningful
    when a STaMP config is actually quantizing.  Being static, default
    configs see the exact historical return arities."""
    return (serve.quant_telemetry and serve.stamp is not None
            and serve.stamp.enabled)


def _maybe_stamp(x: Array, stamp: Optional[StampConfig],
                 site: Optional[str] = None) -> Array:
    if stamp is None or not stamp.enabled:
        return x
    return stamp_fake_quant(x, stamp, site=site)


def _split_heads(x: Array, nh: int, hd: int) -> Array:
    return x.reshape(*x.shape[:-1], nh, hd)


def _merge_heads(x: Array) -> Array:
    return x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])


def _attn_qkv(p: dict, h: Array, cfg: ModelConfig,
              stamp: Optional[StampConfig]) -> tuple[Array, Array, Array]:
    """QKV projections off the normed input (shared by the prefill, decode
    and unified paths so their dispatch rules cannot diverge)."""
    if "wqkv" in p:
        # merged prepared int8 QKV (prepare_fused_weights): the merged
        # "bqkv" bias was concatenated there too — once at prepare time,
        # not per layer call
        bqkv = p.get("bqkv")
        if bqkv is None and p.get("bq") is not None:
            # legacy prepared tree (merged weight, per-site bias leaves):
            # fall back to the per-call concat rather than dropping biases
            bqkv = jnp.concatenate([p["bq"], p["bk"], p["bv"]], axis=-1)
        if _use_fused(stamp, p["wqkv"]):
            # ONE kernel call: the sequence transform + quantize of h runs
            # once (kernel scratch), amortized over the full QKV width
            qkv = L.stamp_fused_linear(h, p["wqkv"], bqkv, stamp,
                                       site="qkv")
        else:
            # decode / reference execution against the same int8 buffers
            qkv = _linear(_maybe_stamp(h, stamp, site="qkv"),
                          p["wqkv"], bqkv)
        q, k, v = jnp.split(
            qkv, [cfg.q_dim, cfg.q_dim + cfg.kv_dim], axis=-1)
        return q, k, v
    h = _maybe_stamp(h, stamp, site="qkv")
    return (_linear(h, p["wq"], p.get("bq")),
            _linear(h, p["wk"], p.get("bk")),
            _linear(h, p["wv"], p.get("bv")))


def _attn_out(p: dict, attn: Array, x: Array,
              stamp: Optional[StampConfig]) -> Array:
    """Out-projection + residual (shared across paths)."""
    if _use_fused(stamp, p["wo"]):
        # fused out-proj: the raw head-split attention output goes straight
        # into the kernel — its stamped quantize fuses with the head-merge
        # reshape, so no merged (b, s, nh·hd) activation round-trips HBM
        return x + L.stamp_fused_linear(attn, p["wo"], None, stamp,
                                        merge_heads=True, site="wo")
    out = _maybe_stamp(_merge_heads(attn), stamp, site="wo")
    return x + _linear(out, p["wo"])


def attn_block(
    p: dict, x: Array, cfg: ModelConfig, *,
    mode: str, positions: Array, policy: Optional[ShardingPolicy],
    stamp: Optional[StampConfig], kv_cfg: KV.KVCacheConfig,
    cache_entry: Optional[dict] = None, pos_scalar: Optional[Array] = None,
    enc_out: Optional[Array] = None, causal: bool = True,
    cache_capacity: Optional[int] = None, paged: Optional[dict] = None,
) -> tuple[Array, Optional[dict]]:
    hd, nh, kvh = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    h = L.rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    q, k, v = _attn_qkv(p, h, cfg, stamp)
    q = apply_rope_heads(q, positions, cfg, nh, hd)
    k = apply_rope_heads(k, positions, cfg, kvh, hd)
    v = _split_heads(v, kvh, hd)

    new_entry: Optional[dict] = None
    if mode == "decode" and paged is not None:
        # continuous batching: per-slot write through the block tables,
        # attention over the mapped pages only
        assert cache_entry is not None
        pcfg = paged["cfg"]
        new_entry = PKV.write_tokens(cache_entry, k, v, paged["pages"],
                                     paged["offsets"], paged["is_hi"], pcfg)
        length = paged["lengths"]
        if pcfg.quant.quantized and kw_fused(kv_cfg):
            from repro.kernels.paged_attention import paged_decode_attention
            attn = paged_decode_attention(new_entry, q, length,
                                          paged["hi_table"],
                                          paged["lo_table"],
                                          pcfg.block_size)
        else:
            segs = PKV.gather_segments(new_entry, paged["hi_table"],
                                       paged["lo_table"], pcfg, x.dtype)
            attn = L.decode_attention_segments(q, segs, length=length)
    elif mode == "decode":
        assert cache_entry is not None
        new_entry = KV.write_token(cache_entry, k, v, pos_scalar, kv_cfg)
        length = jnp.asarray(pos_scalar).reshape(-1) + 1
        if kv_cfg.quantized and kw_fused(kv_cfg):
            from repro.kernels.cache_attention import cache_decode_attention
            attn = cache_decode_attention(new_entry, q, length)
        elif kv_cfg.quantized:
            (k_hi, v_hi), (k_lo, v_lo) = KV.dequantize_segments(
                new_entry, kv_cfg, x.dtype)
            if policy is not None:
                spec = policy.decode_kv_spec(k_lo.shape[0])
                k_lo = policy.constraint(k_lo, spec)
                v_lo = policy.constraint(v_lo, spec)
            hi_len = k_hi.shape[1]
            attn = L.decode_attention_segments(
                q, [(k_hi, v_hi, 0), (k_lo, v_lo, hi_len)], length=length)
        else:
            kf, vf = KV.dequantize_full(new_entry, kv_cfg, x.dtype)
            if policy is not None:
                spec = policy.decode_kv_spec(kf.shape[0])
                kf = policy.constraint(kf, spec)
                vf = policy.constraint(vf, spec)
            attn = L.decode_attention(q, kf, vf, length=length)
    elif mode == "prefill" and paged is not None:
        # chunked prefill into the paged cache: write this chunk's K/V
        # through the block table, attend to the cached prefix + the raw
        # chunk.  The first chunk has no prefix (start = 0) and the same
        # call reduces to pure causal self-attention over the chunk.
        assert cache_entry is not None
        pcfg = paged["cfg"]
        new_entry = PKV.write_chunk(cache_entry, k, v, paged["pages"],
                                    paged["offsets"], paged["is_hi"], pcfg)
        # first and continuation chunks share the chunked call (start = 0
        # masks the cached segments exactly — see chunked_prefill_attention)
        # so the two-call and unified engines run row-identical math
        segs = PKV.gather_segments(new_entry, paged["hi_table"],
                                   paged["lo_table"], pcfg, x.dtype)
        attn = L.chunked_prefill_attention(q, segs, k, v, paged["start"])
    else:
        attn = L.flash_attention(q, k, v, causal=causal)
        if mode == "prefill":
            new_entry = KV.quantize_full(k, v, kv_cfg, capacity=cache_capacity)
    x = _attn_out(p, attn, x, stamp)

    if enc_out is not None and "xwq" in p:   # cross-attention (enc-dec)
        hx = L.rms_norm(x, p["lnx"].astype(x.dtype), cfg.norm_eps)
        qx = _split_heads(_linear(hx, p["xwq"]), nh, hd)
        if mode == "decode" and cache_entry is not None and "xk" in cache_entry:
            kx = cache_entry["xk"].astype(x.dtype)
            vx = cache_entry["xv"].astype(x.dtype)
            ax = L.decode_attention(qx, kx, vx)
        else:
            kx = _split_heads(_linear(enc_out, p["xwk"]), kvh, hd)
            vx = _split_heads(_linear(enc_out, p["xwv"]), kvh, hd)
            ax = L.flash_attention(qx, kx, vx, causal=False)
            if mode == "prefill":
                new_entry = dict(new_entry or {})
                new_entry["xk"] = kx.astype(jnp.bfloat16)
                new_entry["xv"] = vx.astype(jnp.bfloat16)
        ox = _merge_heads(ax)
        # paper Fig. 5 / Table 4: no sequence transform on cross-attn to_out
        # (pooled conditioning breaks the Toeplitz structure) — per-token
        # quant only.
        if stamp is not None and stamp.enabled:
            ox = fake_quant(ox, stamp.lo_bits, axis=-1)
        x = x + _linear(ox, p["xwo"])
        if mode == "decode" and cache_entry is not None and "xk" in cache_entry:
            new_entry = dict(new_entry or {})
            new_entry["xk"] = cache_entry["xk"]
            new_entry["xv"] = cache_entry["xv"]
    return x, new_entry


def apply_rope_heads(flat: Array, positions: Array, cfg: ModelConfig,
                     nh: int, hd: int) -> Array:
    return L.apply_rope(_split_heads(flat, nh, hd), positions, cfg.rope_theta)


def attn_block_unified(
    p: dict, x: tuple, cfg: ModelConfig, *,
    stamp: Optional[StampConfig], kv_cfg: KV.KVCacheConfig,
    cache_entry: dict, paged: dict,
) -> tuple[tuple, dict]:
    """One attention block of the **unified ragged step**: the prefill
    chunk rows ``(n_pf, C, d)`` and the decode slots ``(S, 1, d)`` run in
    one program — QKV per region (prefill under STaMP, decode transform
    free, exactly the two-call dispatch), ONE combined K/V scatter over the
    flattened token stream, then attention per span: decode spans over
    their mapped pages, prefill spans causally within the chunk against
    their own block-table prefix.  The XLA fallback runs ONE
    `chunked_prefill_attention` call for all chunk rows: a first row's
    ``pf_start = 0`` masks its cached segments to an exactly-zero merge
    contribution, so no separate flash variant (and no evaluate-both-and-
    ``jnp.where`` select) is needed — first/continuation chunks share one
    compiled program and each row's math is bit-identical to the two-call
    engine's chunk call (the parity contract).  With the Pallas path
    enabled both regions go through ONE `paged_ragged_attention` grid
    instead.
    """
    x_pf, x_dec = x
    hd, nh, kvh = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    pcfg = paged["cfg"]
    n_pf, c_len = x_pf.shape[:2]
    s_slots = x_dec.shape[0]

    h_pf = L.rms_norm(x_pf, p["ln1"].astype(x_pf.dtype), cfg.norm_eps)
    h_dec = L.rms_norm(x_dec, p["ln1"].astype(x_dec.dtype), cfg.norm_eps)
    q_pf, k_pf, v_pf = _attn_qkv(p, h_pf, cfg, stamp)
    q_dec, k_dec, v_dec = _attn_qkv(p, h_dec, cfg, None)
    pos_pf = paged["pf_positions"]                     # (n_pf, C)
    pos_dec = paged["dec_positions"][:, None]          # (S, 1)
    q_pf = apply_rope_heads(q_pf, pos_pf, cfg, nh, hd)
    k_pf = apply_rope_heads(k_pf, pos_pf, cfg, kvh, hd)
    v_pf = _split_heads(v_pf, kvh, hd)
    q_dec = apply_rope_heads(q_dec, pos_dec, cfg, nh, hd)
    k_dec = apply_rope_heads(k_dec, pos_dec, cfg, kvh, hd)
    v_dec = _split_heads(v_dec, kvh, hd)

    # ONE scatter covers every token this step writes: all chunk tokens in
    # span order, then one token per decode slot (pads/inactive slots are
    # routed to the null page by the host-built index arrays)
    k_flat = jnp.concatenate([k_pf.reshape(n_pf * c_len, kvh, hd),
                              k_dec.reshape(s_slots, kvh, hd)], axis=0)
    v_flat = jnp.concatenate([v_pf.reshape(n_pf * c_len, kvh, hd),
                              v_dec.reshape(s_slots, kvh, hd)], axis=0)
    new_entry = PKV.write_ragged(cache_entry, k_flat, v_flat,
                                 paged["pages"], paged["offsets"],
                                 paged["is_hi"], pcfg)

    if pcfg.quant.quantized and kw_fused(kv_cfg):
        from repro.kernels.paged_attention import paged_ragged_attention
        attn_pf, attn_dec = paged_ragged_attention(
            new_entry, q_pf, q_dec, paged["span_starts"],
            paged["span_lengths"], paged["span_ht"], paged["span_lt"],
            pcfg.block_size)
    else:
        segs_dec = PKV.gather_segments(new_entry, paged["dec_ht"],
                                       paged["dec_lt"], pcfg, x_dec.dtype)
        attn_dec = L.decode_attention_segments(q_dec, segs_dec,
                                               length=paged["dec_lengths"])
        # chunk rows: ONE branch covers first and continuation chunks.  A
        # first row's empty cached prefix (pf_start = 0) masks every
        # segment and the online-softmax merge correction underflows to
        # exactly zero, so the single chunked call IS the no-prefix result
        # for those rows.  (The previous fallback evaluated BOTH variants
        # and jnp.where-selected per row — paying the flash O(C²) scores on
        # top of the segment attention for every chunk row, every step.)
        segs_pf = PKV.gather_segments(new_entry, paged["pf_ht"],
                                      paged["pf_lt"], pcfg, x_pf.dtype)
        attn_pf = L.chunked_prefill_attention(q_pf, segs_pf, k_pf, v_pf,
                                              paged["pf_start"])

    return (_attn_out(p, attn_pf, x_pf, stamp),
            _attn_out(p, attn_dec, x_dec, None)), new_entry


def _mamba_in(p: dict, x: Array, cfg: ModelConfig,
              stamp: Optional[StampConfig]) -> tuple[Array, Array, Array]:
    """Norm + in-projection + split (shared by the prefill, decode and
    unified paths so their dispatch rules cannot diverge)."""
    di, n = cfg.d_inner, cfg.ssm_state
    h = L.rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    if _use_fused(stamp, p["in_proj"]):
        # single-output fused kernel on the pre-mixer projection
        proj = L.stamp_fused_linear(h, p["in_proj"], None, stamp,
                                    site="in_proj")
    else:
        proj = _linear(_maybe_stamp(h, stamp, site="in_proj"),
                       p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return z, xbc, dt


def _mamba_out(p: dict, yh: Array, z: Array, x: Array, cfg: ModelConfig,
               stamp: Optional[StampConfig], decode: bool) -> Array:
    """Gate + norm + out-projection + residual (shared across paths)."""
    y = yh.reshape(*yh.shape[:-2], cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["ssm_norm"].astype(x.dtype), cfg.norm_eps)
    # decode always passes stamp=None, so _use_fused is False there — the
    # same contract that keeps the in_proj dispatch above off the
    # sequence-transform kernel during decode
    if _use_fused(stamp, p["out_proj"]):
        return x + L.stamp_fused_linear(y, p["out_proj"], None, stamp,
                                        site="out_proj")
    y = _maybe_stamp(y, stamp, site="out_proj") if not decode else y
    return x + _linear(y, p["out_proj"])


def _mamba_step(p: dict, xbc: Array, dt: Array, state: Array,
                conv_cache: Array, cfg: ModelConfig, dtype
                ) -> tuple[Array, Array, Array]:
    """One-token recurrence: ``xbc`` (b, 1, conv_dim), ``dt`` (b, 1, h),
    ``state`` (b, h, p, n) f32, ``conv_cache`` (b, width-1, conv_dim).
    Returns (yh (b, 1, h, p) f32, new_state, new_conv)."""
    di, n, nh, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xp = jnp.concatenate([conv_cache.astype(dtype), xbc], axis=1)
    w = p["conv_w"].astype(dtype)
    y = sum(xp[:, i:i + 1] * w[i][None, None] for i in range(w.shape[0]))
    xbc_c = jax.nn.silu(y)
    new_conv = xp[:, 1:]
    x_ssm, b_mat, c_mat = jnp.split(xbc_c, [di, di + n], axis=-1)
    xh = x_ssm.reshape(*x_ssm.shape[:-1], nh, pd)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[:, 0] * a[None])                          # (b, h)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh[:, 0].astype(jnp.float32),
                     b_mat[:, 0].astype(jnp.float32), dt[:, 0])
    state = state * da[..., None, None] + upd
    yh = jnp.einsum("bn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32), state)
    yh = yh[:, None] + p["d_skip"][None, None, :, None] * \
        xh.astype(jnp.float32)
    return yh, state, new_conv


def _mamba_masked_step(p: dict, xbc: Array, dt: Array, state_all: Array,
                       conv_all: Array, act: Array, cfg: ModelConfig, dtype
                       ) -> tuple[Array, Array, Array]:
    """Masked one-token recurrence over the slot-dense pool: compute the
    update for every real slot row (``state_all``/``conv_all`` carry the
    extra null-slot row, excluded here), then keep inactive rows' state
    bit-for-bit — a slot with no RUNNING request (its token is a null pad)
    must not advance the recurrence with garbage.  Shared by the two-call
    decode step and the unified step's decode region so the parity tests
    compare one implementation with itself."""
    s_slots = act.shape[0]
    state, conv_cache = state_all[:s_slots], conv_all[:s_slots]
    yh, state_new, conv_new = _mamba_step(p, xbc, dt, state, conv_cache,
                                          cfg, dtype)
    state_new = jnp.where(act[:, None, None, None], state_new, state)
    conv_new = jnp.where(act[:, None, None], conv_new,
                         conv_cache.astype(dtype))
    return yh, state_new, conv_new


def _mamba_scan(p: dict, xbc: Array, dt: Array, cfg: ModelConfig, *,
                conv_cache: Optional[Array], init_state: Optional[Array],
                lengths: Optional[Array], dtype
                ) -> tuple[Array, Array, Array]:
    """Multi-token conv + SSD over a (possibly right-padded) span, stateful
    across calls: ``conv_cache`` / ``init_state`` carry the recurrence in
    from the previous chunk, ``lengths`` (b,) marks each row's valid token
    count.  Masking ``dt`` to zero past the valid length makes the SSD
    recurrence a *no-op* there (decay ``exp(0·a) = 1``, update weight 0),
    so the returned ``state`` is exactly the state after the last valid
    token — pad tokens never advance the recurrence (full rows multiply
    ``dt`` by 1.0: bit-identical to the unmasked path).  ``conv_tail`` is
    likewise sliced at the valid boundary.  Outputs past a row's length are
    garbage the caller discards."""
    di, n, nh, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    if lengths is not None:
        mask = jnp.arange(xbc.shape[1])[None, :] < lengths[:, None]
        dt = dt * mask[..., None].astype(dt.dtype)
    xbc_c, conv_tail = L.causal_conv1d(xbc, p["conv_w"].astype(dtype),
                                       cache=conv_cache, lengths=lengths)
    x_ssm, b_mat, c_mat = jnp.split(xbc_c, [di, di + n], axis=-1)
    xh = x_ssm.reshape(*x_ssm.shape[:-1], nh, pd)
    yh, state = L.ssd_chunked(xh, dt, p["a_log"], b_mat, c_mat,
                              init_state=init_state)
    yh = yh.astype(jnp.float32) + p["d_skip"][None, None, :, None] * \
        xh.astype(jnp.float32)
    return yh, state, conv_tail


def mamba_block(
    p: dict, x: Array, cfg: ModelConfig, *,
    mode: str, policy: Optional[ShardingPolicy],
    stamp: Optional[StampConfig],
    cache_entry: Optional[dict] = None, paged: Optional[dict] = None,
    seq_lengths: Optional[Array] = None,
) -> tuple[Array, Optional[dict]]:
    z, xbc, dt = _mamba_in(p, x, cfg, stamp)

    new_entry: Optional[dict] = None
    if mode == "decode" and paged is not None:
        # continuous batching: the cache entry is the slot-dense pool
        # (num_slots + 1 rows; the last is the null slot)
        assert cache_entry is not None
        state_all, conv_all = cache_entry["state"], cache_entry["conv"]
        yh, state_new, conv_new = _mamba_masked_step(
            p, xbc, dt, state_all, conv_all, paged["dec_active"], cfg,
            x.dtype)
        s_slots = x.shape[0]
        new_entry = {
            "state": state_all.at[:s_slots].set(state_new),
            "conv": conv_all.at[:s_slots].set(
                conv_new.astype(conv_all.dtype)),
        }
    elif mode == "decode":
        assert cache_entry is not None
        yh, state, new_conv = _mamba_step(p, xbc, dt, cache_entry["state"],
                                          cache_entry["conv"], cfg, x.dtype)
        new_entry = {"state": state,
                     "conv": new_conv.astype(cache_entry["conv"].dtype)}
    elif mode == "prefill" and paged is not None:
        # chunked prefill into the slot pool: the scan is *stateful* across
        # chunk boundaries — conv tail + SSM state of the previous chunk
        # come from this request's slot row, the chunk's final state goes
        # back to it (two-call parity path; the unified step runs the same
        # math in `mamba_block_unified`).
        assert cache_entry is not None
        state_all, conv_all = cache_entry["state"], cache_entry["conv"]
        slot, valid = paged["slot"], paged["valid"]
        if paged["first"]:           # static in the two-call pair
            conv0 = jnp.zeros((1,) + conv_all.shape[1:], x.dtype)
            state0 = jnp.zeros((1,) + state_all.shape[1:], jnp.float32)
        else:
            conv0 = conv_all[slot][None].astype(x.dtype)
            state0 = state_all[slot][None]
        yh, state_f, conv_tail = _mamba_scan(
            p, xbc, dt, cfg, conv_cache=conv0, init_state=state0,
            lengths=jnp.reshape(valid, (1,)), dtype=x.dtype)
        new_entry = {
            "state": state_all.at[slot].set(state_f[0]),
            "conv": conv_all.at[slot].set(conv_tail[0].astype(conv_all.dtype)),
        }
    else:
        yh, state, conv_tail = _mamba_scan(
            p, xbc, dt, cfg, conv_cache=None, init_state=None,
            lengths=seq_lengths, dtype=x.dtype)
        if mode == "prefill":
            new_entry = {"state": state, "conv": conv_tail.astype(jnp.bfloat16)}
    return _mamba_out(p, yh, z, x, cfg, stamp, decode=mode == "decode"), \
        new_entry


def mamba_block_unified(
    p: dict, x: tuple, cfg: ModelConfig, *,
    stamp: Optional[StampConfig], cache_entry: dict, paged: dict,
) -> tuple[tuple, dict]:
    """One Mamba block of the **unified ragged step** over the slot-dense
    state pool: the prefill chunk rows ``(n_pf, C, d)`` run the stateful
    chunked scan (per span — conv tail + SSM state gathered from each
    span's slot row, first chunks start from zeros via the traced
    ``pf_first`` mask, ``dt`` masked past the valid length so pads never
    advance the recurrence) and the decode slots ``(S, 1, d)`` advance the
    one-token recurrence with inactive slots masked — in one program, with
    ONE write per state array: the masked decode update covers the slot
    array, then the chunk rows scatter their final state at their own slot
    (a request is either prefilling or running, never both, so the writes
    are disjoint; unused chunk rows scatter to the null slot — row ``S`` —
    exactly as masked K/V writes route to the null page)."""
    x_pf, x_dec = x
    state_all, conv_all = cache_entry["state"], cache_entry["conv"]
    s_slots = x_dec.shape[0]

    # ---- prefill region: STaMP path, stateful per-span scan ----
    z_pf, xbc_pf, dt_pf = _mamba_in(p, x_pf, cfg, stamp)
    pf_slots = paged["pf_slots"]                   # (n_pf,), dummies -> S
    first = paged["pf_first"]
    conv0 = jnp.where(first[:, None, None], 0.0,
                      conv_all[pf_slots].astype(x_pf.dtype)
                      ).astype(x_pf.dtype)
    state0 = jnp.where(first[:, None, None, None], 0.0, state_all[pf_slots])
    yh_pf, state_f, conv_tail = _mamba_scan(
        p, xbc_pf, dt_pf, cfg, conv_cache=conv0, init_state=state0,
        lengths=paged["pf_valid"], dtype=x_pf.dtype)

    # ---- decode region: transform-free one-token recurrence, masked ----
    z_dec, xbc_dec, dt_dec = _mamba_in(p, x_dec, cfg, None)
    yh_dec, state_new, conv_new = _mamba_masked_step(
        p, xbc_dec, dt_dec, state_all, conv_all, paged["dec_active"], cfg,
        x_dec.dtype)

    st = state_all.at[:s_slots].set(state_new)
    st = st.at[pf_slots].set(state_f)
    cv = conv_all.at[:s_slots].set(conv_new.astype(conv_all.dtype))
    cv = cv.at[pf_slots].set(conv_tail.astype(conv_all.dtype))
    new_entry = {"state": st, "conv": cv}

    return (_mamba_out(p, yh_pf, z_pf, x_pf, cfg, stamp, decode=False),
            _mamba_out(p, yh_dec, z_dec, x_dec, cfg, None, decode=True)), \
        new_entry


def ffn_block(p: dict, x: Array, spec: LayerSpec, cfg: ModelConfig, *,
              stamp: Optional[StampConfig]) -> Array:
    if spec.ffn == "none":
        return x
    h = L.rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
    # h stays raw here: the fused gate/up pair quantizes it inside the dual
    # kernel; only reference-path consumers see the stamped round trip
    # (computed once, shared between the MoE branch and un-fused gate/up)
    hq = None
    out = jnp.zeros_like(x)
    if spec.ffn in ("moe", "moe_dense"):
        gate_w = (p["gate_w"] if not isinstance(p["gate_w"], dict)
                  else _dequant_packed(p["gate_w"], jnp.float32))
        # both paths see the SAME stamped round trip (routing on it keeps
        # kept/dropped token sets bit-identical fused vs reference)
        hq = _maybe_stamp(h, stamp, site="moe")
        if (_use_fused(stamp, p["we_gate"]) and _use_fused(stamp, p["we_up"])
                and _use_fused(stamp, p["we_down"])):
            # grouped kernel path: quantize each token once, dispatch int8
            # codes, run the gate/up/down expert stack in ONE Pallas call
            out = out + L.moe_ffn_fused(
                hq, gate_w, p["we_gate"], p["we_up"], p["we_down"],
                cfg.experts_per_token, cfg.capacity_factor,
                group_size=cfg.moe_group_size)
        else:
            we_gate = _expert_w(p["we_gate"], x.dtype)
            we_up = _expert_w(p["we_up"], x.dtype)
            we_down = _expert_w(p["we_down"], x.dtype)
            out = out + L.moe_ffn(hq, gate_w, we_gate, we_up, we_down,
                                  cfg.experts_per_token, cfg.capacity_factor,
                                  group_size=cfg.moe_group_size)
    if spec.ffn in ("mlp", "moe_dense"):
        prefix = "d" if spec.ffn == "moe_dense" else ""
        wg, wu = p[f"{prefix}wi_gate"], p[f"{prefix}wi_up"]
        if _use_fused(stamp, wg) and _use_fused(stamp, wu):
            # ONE dual-output kernel call: the shared input's transform +
            # quantize runs once (VMEM scratch) and drives both GEMMs,
            # silu·mul epilogue included
            g = L.stamp_fused_dual_linear(h, wg, wu, stamp, site="gate_up")
        else:
            hq = (_maybe_stamp(h, stamp, site="gate_up")
                  if hq is None else hq)
            g = jax.nn.silu(_linear(hq, wg)) * _linear(hq, wu)
        if _use_fused(stamp, p[f"{prefix}wo_mlp"]):
            out = out + L.stamp_fused_linear(g, p[f"{prefix}wo_mlp"], None,
                                             stamp, site="wo_mlp")
        else:
            out = out + _linear(_maybe_stamp(g, stamp, site="wo_mlp"),
                                p[f"{prefix}wo_mlp"])
    return x + out


def _expert_w(w, dtype):
    if isinstance(w, dict) and "iq" in w:
        # prepared stacked (E, din, dout) int8 codes (decode / no-STaMP
        # call sites share the serving params): exact bf16 dequant — codes
        # and zero points are integers in [-128, 127]
        return ((w["iq"].astype(dtype) - w["izw"].astype(dtype))
                * w["isw"].astype(dtype))
    if isinstance(w, dict):
        return _dequant_packed(w, dtype)
    return w.astype(dtype)


def apply_block(spec: LayerSpec, p: dict, x: Array, cfg: ModelConfig, **kw
                ) -> tuple[Array, Optional[dict]]:
    stamp = kw.get("stamp")
    if kw["mode"] == "unified":
        # unified ragged step: x is the (prefill_rows, decode_slots) pair;
        # prefill keeps the STaMP path, decode the transform-free one —
        # per region, inside one program.  Attention mixes through the
        # paged pools, Mamba through the slot-dense state pool.
        if spec.mixer == "attn":
            x, entry = attn_block_unified(p, x, cfg, stamp=stamp,
                                          kv_cfg=kw["kv_cfg"],
                                          cache_entry=kw["cache_entry"],
                                          paged=kw["paged"])
        elif spec.mixer == "mamba":
            x, entry = mamba_block_unified(p, x, cfg, stamp=stamp,
                                           cache_entry=kw["cache_entry"],
                                           paged=kw["paged"])
        else:
            entry = None
        x_pf = ffn_block(p, x[0], spec, cfg, stamp=stamp)
        x_dec = ffn_block(p, x[1], spec, cfg, stamp=None)
        return (x_pf, x_dec), entry
    if spec.mixer == "attn":
        x, entry = attn_block(p, x, cfg, mode=kw["mode"],
                              positions=kw["positions"], policy=kw.get("policy"),
                              stamp=stamp, kv_cfg=kw["kv_cfg"],
                              cache_entry=kw.get("cache_entry"),
                              pos_scalar=kw.get("pos_scalar"),
                              enc_out=kw.get("enc_out"),
                              causal=kw.get("causal", True),
                              cache_capacity=kw.get("cache_capacity"),
                              paged=kw.get("paged"))
    elif spec.mixer == "mamba":
        x, entry = mamba_block(p, x, cfg, mode=kw["mode"],
                               policy=kw.get("policy"), stamp=stamp,
                               cache_entry=kw.get("cache_entry"),
                               paged=kw.get("paged"),
                               seq_lengths=kw.get("seq_lengths"))
    else:
        entry = None
    x = ffn_block(p, x, spec, cfg, stamp=stamp)
    return x, entry


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def run_stack(
    params: dict, x: Array, cfg: ModelConfig, *,
    mode: str, positions: Array, policy: Optional[ShardingPolicy],
    stamp: Optional[StampConfig] = None,
    kv_cfg: KV.KVCacheConfig = KV.KVCacheConfig(quantized=False),
    cache: Optional[dict] = None, pos_scalar: Optional[Array] = None,
    enc_out: Optional[Array] = None, causal: bool = True, remat: bool = True,
    cache_capacity: Optional[int] = None, paged: Optional[dict] = None,
    seq_lengths: Optional[Array] = None,
) -> tuple[Array, Optional[dict]]:
    """Run prologue (unrolled) + periods (scanned).  Returns (x, cache).

    ``seq_lengths`` (b,) marks per-row valid prompt lengths for
    right-padded prefill: attention is pad-safe by construction (causal
    mask + per-slot logit reads), but the Mamba recurrence is sequential —
    without the mask, pad tokens after a short prompt would keep advancing
    the SSM state the decode steps then continue from."""
    pro, period, nper = cfg.layer_plan()
    kw = dict(mode=mode, positions=positions, policy=policy, stamp=stamp,
              kv_cfg=kv_cfg, pos_scalar=pos_scalar, enc_out=enc_out,
              causal=causal, cache_capacity=cache_capacity, paged=paged,
              seq_lengths=seq_lengths)

    new_pro_cache = {}
    for i, spec in enumerate(pro):
        entry = None if cache is None else cache.get(f"pro{i}")
        x, ne = apply_block(spec, params["prologue"][i], x, cfg,
                            cache_entry=entry, **kw)
        if ne is not None:
            new_pro_cache[f"pro{i}"] = ne

    stateful = [j for j, s in enumerate(period) if s.mixer in ("attn", "mamba")]
    cache_per = None
    if cache is not None:
        cache_per = {str(j): cache[str(j)] for j in stateful
                     if str(j) in cache}

    if mode == "decode" and cache_per is not None and False:
        # DISABLED (§Perf decode iter 6): carrying the cache and updating at
        # a dynamic layer index forces XLA to COPY the full stacked buffers
        # every layer (read-before-write kills aliasing) — 4×0.67 GB/layer
        # measured.  The xs/ys path below only moves per-layer slices, and
        # with one-hot token writes it no longer triggers GSPMD gathers.
        def body(carry, p_slice):
            xc, cache_c, idx = carry
            cache_next = dict(cache_c)
            for j, spec in enumerate(period):
                entry = None
                if str(j) in cache_c:
                    entry = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, idx, 0, keepdims=False), cache_c[str(j)])
                xc, ne = apply_block(spec, p_slice[j], xc, cfg,
                                     cache_entry=entry, **kw)
                if ne is not None:
                    cache_next[str(j)] = jax.tree.map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd, idx, 0), cache_next[str(j)], ne)
            xc = constrain(xc, policy, lambda pol: pol.acts())
            return (xc, cache_next, idx + 1), ()

        (x, cache_out, _), _ = jax.lax.scan(
            body, (x, cache_per, jnp.zeros((), jnp.int32)),
            params["period"])
        new_cache = dict(cache_out)
        new_cache.update(new_pro_cache)
        return x, new_cache

    # quant telemetry: records made by the prologue layers above live at
    # the outer trace level — drain them NOW so the scan body (traced
    # next) cannot capture them as closure constants and stack them
    # nper×.  The body drains its own records and returns them as extra
    # scan outputs; absorb() reduces the stacked period axis back out.
    pro_telem = QS.drain()

    def body(xc, xs):
        p_slice, c_slice = xs
        new_entries = {}
        for j, spec in enumerate(period):
            entry = None if c_slice is None else c_slice.get(str(j))
            xc, ne = apply_block(spec, p_slice[j], xc, cfg,
                                 cache_entry=entry, **kw)
            if ne is not None:
                new_entries[str(j)] = ne
        xc = constrain(xc, policy, lambda pol: pol.acts())
        return xc, (new_entries, QS.drain())

    if mode == "train" and remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    xs = (params["period"], cache_per)
    x, (period_cache, period_telem) = jax.lax.scan(body, x, xs)
    QS.absorb(period_telem)
    QS.merge_flat(pro_telem)
    new_cache = None
    if mode in ("prefill", "decode", "unified"):
        new_cache = dict(period_cache)
        new_cache.update(new_pro_cache)
    return x, new_cache


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------


def chunked_xent(x: Array, head, labels: Array, chunk: int = 512) -> Array:
    """Cross-entropy without materializing (b, s, vocab): scan over sequence
    chunks (each chunk's logits live only inside the scan body).  Labels < 0
    are ignored (VLM patch positions)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nch = s // chunk
    xs = x.reshape(b, nch, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, nch, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(tot, inp):
        xc, lc = inp
        logits = _linear(xc, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((logz - gold) * valid)
        return (tot[0] + loss, tot[1] + jnp.sum(valid)), ()

    (loss, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return loss / jnp.maximum(cnt, 1.0)


def _embed(params, tokens: Array, dtype=jnp.bfloat16) -> Array:
    return jnp.take(params["embed"], tokens, axis=0).astype(dtype)


def _head_weight(params):
    if "head" in params:
        return params["head"]
    return params["embed"].T


def _encoder_forward(params, frames: Array, cfg: ModelConfig,
                     policy, mode: str) -> Array:
    enc = params["encoder"]
    enc_cfg = dataclasses.replace(cfg, encoder_layers=0)
    pos = jnp.arange(frames.shape[1])[None, :]
    x = frames

    def body(xc, p_slice):
        xc, _ = apply_block(LayerSpec("attn", "mlp"), p_slice[0], xc, enc_cfg,
                            mode="train", positions=pos, policy=policy,
                            stamp=None,
                            kv_cfg=KV.KVCacheConfig(quantized=False),
                            causal=False)
        xc = constrain(xc, policy, lambda pol: pol.acts())
        return xc, ()

    if mode == "train":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, enc["period"])
    return L.rms_norm(x, enc["final_norm"].astype(x.dtype), cfg.norm_eps)


def model_hidden(params, batch: dict, cfg: ModelConfig, *,
                 mode: str, policy, stamp=None,
                 kv_cfg=KV.KVCacheConfig(quantized=False),
                 remat: bool = True,
                 cache_capacity: Optional[int] = None,
                 seq_lengths: Optional[Array] = None
                 ) -> tuple[Array, Optional[dict], Array]:
    """Shared train/prefill forward.  Returns (hidden, cache, labels)."""
    # non-decode entry: clear the process-global decode-matmul flag so a
    # previous fused decode can't divert a length-1 forward off the STaMP
    # transform path (see set_fused_decode_matmul)
    set_fused_decode_matmul(False)
    compute_dtype = jnp.bfloat16
    labels = batch.get("labels")
    enc_out = None
    if cfg.frontend == "frames" or cfg.encoder_layers:
        enc_out = _encoder_forward(params, batch["frames"].astype(compute_dtype),
                                   cfg, policy, mode)
        x = _embed(params, batch["tokens"], compute_dtype)
    elif cfg.frontend == "patch":
        tok = _embed(params, batch["tokens"], compute_dtype)
        x = jnp.concatenate([batch["patches"].astype(compute_dtype), tok],
                            axis=1)
    else:
        x = _embed(params, batch["tokens"], compute_dtype)
    x = constrain(x, policy, lambda pol: pol.acts())
    positions = jnp.arange(x.shape[1])[None, :]
    x, cache = run_stack(params, x, cfg, mode=mode, positions=positions,
                         policy=policy, stamp=stamp, kv_cfg=kv_cfg,
                         enc_out=enc_out, remat=remat,
                         cache_capacity=cache_capacity,
                         seq_lengths=seq_lengths)
    x = L.rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return x, cache, labels


def train_loss(params, batch: dict, cfg: ModelConfig,
               policy: Optional[ShardingPolicy] = None,
               remat: bool = True) -> Array:
    x, _, labels = model_hidden(params, batch, cfg, mode="train",
                                policy=policy, remat=remat)
    return chunked_xent(x, _head_weight(params), labels)


def prefill(params, batch: dict, cfg: ModelConfig,
            serve: ServeConfig, policy: Optional[ShardingPolicy] = None,
            last_pos: Optional[Array] = None) -> tuple[Array, dict]:
    """Full-sequence forward with STaMP activation quantization, producing
    next-token logits and the mixed-precision quantized KV cache.

    ``last_pos`` (b,) selects each row's logit position — right-padded
    batches read the logits at their true last prompt token instead of the
    final (pad) column.  Default: the last position for every row.  When
    given, it also masks the Mamba recurrence past each row's length
    (``seq_lengths = last_pos + 1``): attention never sees pad tokens
    (causal), but an SSM state *would* keep absorbing them — decode must
    continue from the state at the true last token.
    """
    seq_lengths = None if last_pos is None else \
        jnp.asarray(last_pos, jnp.int32) + 1
    collect = _collect_telemetry(serve)
    if collect:
        QS.begin()
    try:
        x, cache, _ = model_hidden(params, batch, cfg, mode="prefill",
                                   policy=policy, stamp=serve.stamp,
                                   kv_cfg=serve.kv, remat=False,
                                   cache_capacity=serve.cache_capacity,
                                   seq_lengths=seq_lengths)
    finally:
        telem = QS.end() if collect else None
    if last_pos is None:
        x_last = x[:, -1:]
    else:
        x_last = jnp.take_along_axis(x, last_pos[:, None, None], axis=1)
    logits = _linear(x_last, _head_weight(params))[:, 0]
    if collect:
        return logits.astype(jnp.float32), cache, telem
    return logits.astype(jnp.float32), cache


def decode_step(params, cache: dict, tokens: Array, pos: Array,
                cfg: ModelConfig, serve: ServeConfig,
                policy: Optional[ShardingPolicy] = None
                ) -> tuple[Array, dict]:
    """One-token decode against the quantized cache.  ``tokens``: (b,) int32;
    ``pos``: scalar int32 current length (lockstep batch) or (b,) int32
    per-slot lengths (continuous batching / right-padded prompts)."""
    set_fused_cache_attention(serve.fused_cache_attention)
    set_fused_decode_matmul(serve.fused_decode_matmul)
    compute_dtype = jnp.bfloat16
    x = _embed(params, tokens[:, None], compute_dtype)
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else \
        jnp.full((1, 1), pos, jnp.int32)
    x, new_cache = run_stack(params, x, cfg, mode="decode",
                             positions=positions, policy=policy,
                             stamp=None, kv_cfg=serve.kv, cache=cache,
                             pos_scalar=pos)
    x = L.rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = _linear(x[:, 0], _head_weight(params))
    return logits.astype(jnp.float32), new_cache


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               serve: ServeConfig) -> dict:
    """Zero-initialized decode cache for every stateful layer position."""
    pro, period, nper = cfg.layer_plan()
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    cache: dict = {}

    def attn_entry(periods):
        entry = KV.init_layer_cache(periods, batch, seq, kvh, hd, serve.kv)
        if cfg.encoder_layers:
            s_enc = max(seq // cfg.frame_ratio, 1)
            entry["xk"] = jnp.zeros((periods, batch, s_enc, kvh, hd),
                                    jnp.bfloat16)
            entry["xv"] = jnp.zeros((periods, batch, s_enc, kvh, hd),
                                    jnp.bfloat16)
        return entry

    def ssm_entry(periods):
        di, n, nh, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "state": jnp.zeros((periods, batch, nh, pd, n), jnp.float32),
            "conv": jnp.zeros((periods, batch, cfg.conv_width - 1,
                               di + 2 * n), jnp.bfloat16),
        }

    for j, spec in enumerate(period):
        if spec.mixer == "attn":
            cache[str(j)] = attn_entry(nper)
        elif spec.mixer == "mamba":
            cache[str(j)] = ssm_entry(nper)
    for i, spec in enumerate(pro):
        if spec.mixer == "attn":
            cache[f"pro{i}"] = jax.tree.map(lambda a: a[0], attn_entry(1))
        elif spec.mixer == "mamba":
            cache[f"pro{i}"] = jax.tree.map(lambda a: a[0], ssm_entry(1))
    return cache


# ---------------------------------------------------------------------------
# continuous batching (paged cache) entry points
# ---------------------------------------------------------------------------


def init_paged_cache(cfg: ModelConfig, pcfg: "PKV.PagedCacheConfig",
                     num_slots: Optional[int] = None) -> dict:
    """Zero cache state for every stateful layer position: page pools for
    attention (block ids shared across layer positions — one allocation
    covers the whole stack, so each position gets its own pool arrays but
    the same geometry) and, for hybrid / pure-SSM stacks, slot-dense
    per-slot conv + SSM state (``num_slots`` = the engine's decode slot
    count; row ``num_slots`` is the null slot — see
    `PKV.init_ssm_slots`)."""
    pro, period, nper = cfg.layer_plan()
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    if cfg.encoder_layers:
        raise NotImplementedError(
            "paged serving does not cover encoder-decoder stacks: the "
            "cross-attention K/V is computed once from the encoder output "
            "and held dense per request — serve these through "
            "BucketedEngine (--engine bucketed)")
    specs = list(period) + list(pro)
    if any(s.mixer == "mamba" for s in specs) and num_slots is None:
        raise ValueError(
            "hybrid/SSM stacks hold slot-dense SSM state: init_paged_cache "
            "needs num_slots (the engine's max_slots) to size the per-slot "
            "state pool")
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state

    def ssm_pool(periods):
        return PKV.init_ssm_slots(periods, num_slots, cfg.conv_width,
                                  conv_dim, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state)

    cache: dict = {}
    for j, spec in enumerate(period):
        if spec.mixer == "attn":
            cache[str(j)] = PKV.init_pools(nper, kvh, hd, pcfg)
        elif spec.mixer == "mamba":
            cache[str(j)] = ssm_pool(nper)
    for i, spec in enumerate(pro):
        if spec.mixer == "attn":
            cache[f"pro{i}"] = jax.tree.map(
                lambda a: a[0], PKV.init_pools(1, kvh, hd, pcfg))
        elif spec.mixer == "mamba":
            cache[f"pro{i}"] = jax.tree.map(lambda a: a[0], ssm_pool(1))
    return cache


def paged_prefill_chunk(params, pools: dict, tokens: Array, start: Array,
                        hi_table: Array, lo_table: Array, pages: Array,
                        offsets: Array, is_hi: Array, last_index: Array,
                        cfg: ModelConfig, serve: ServeConfig,
                        first: bool, slot: Optional[Array] = None,
                        policy: Optional[ShardingPolicy] = None
                        ) -> tuple[Array, dict]:
    """One prefill chunk of one request into the paged cache.

    **Two-call parity path**: the unified engine runs prefill and decode
    through one `paged_unified_step` program; this entry (and
    `paged_decode_step`) is kept as the PR-3 step pair —
    ``PagedEngineConfig(step_mode="two_call")`` — so the parity tests can
    pin the unified step bit-for-bit against it.

    ``tokens``: (1, C) right-padded chunk; ``start``: scalar int32 tokens
    already cached — *however* they got there: earlier chunks of this
    request, a preemption swap-in, or a prefix-cache hit (the scheduler
    admits with ``pos = matched`` and the first chunk simply starts at an
    arbitrary ``start > 0``; the chunked attention reads the cached
    segment through the block table and masks ``kpos >= start``, so no
    extra plumbing exists for the prefix case);
    ``pages/offsets/is_hi``: (C,) host-computed write
    targets (pad tokens routed to the null page); ``last_index``: scalar
    chunk-local index of the prompt's final token (its logits are the
    request's first-token distribution — only meaningful on the last
    chunk); ``first``: static — Mamba layers key their chunk-state
    initialization on it (attention needs no branch: ``start = 0`` makes
    the chunked call pure causal self-attention); ``slot``: scalar int32
    decode-slot index of the request — Mamba layers carry their conv/SSM
    state across chunk boundaries through that row of the slot-dense state
    pool (required for hybrid/SSM stacks, ignored by attention-only ones).

    STaMP's sequence transform is applied per chunk (the transform window
    is the chunk, not the whole prompt): identical to the bucketed engine
    when the prompt fits one chunk, a documented approximation beyond that.
    """
    set_fused_cache_attention(serve.fused_cache_attention)
    # prefill must run the STaMP transform even at chunk width 1 — never
    # the (transform-free) decode matmul
    set_fused_decode_matmul(False)
    compute_dtype = jnp.bfloat16
    x = _embed(params, tokens, compute_dtype)
    x = constrain(x, policy, lambda pol: pol.acts())
    c = tokens.shape[1]
    positions = (start + jnp.arange(c))[None, :]
    paged = {"cfg": serve.paged, "hi_table": hi_table, "lo_table": lo_table,
             "pages": pages, "offsets": offsets, "is_hi": is_hi,
             "start": start, "first": first,
             # slot-dense SSM state routing (hybrid stacks): the chunk's
             # valid token count is last_index + 1 on every chunk (final
             # chunks end at the prompt's last token by construction)
             "slot": slot, "valid": last_index + 1}
    collect = _collect_telemetry(serve)
    if collect:
        QS.begin()
    try:
        x, new_pools = run_stack(params, x, cfg, mode="prefill",
                                 positions=positions, policy=policy,
                                 stamp=serve.stamp, kv_cfg=serve.kv,
                                 cache=pools, paged=paged, remat=False)
    finally:
        telem = QS.end() if collect else None
    x = L.rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    x_last = jnp.take_along_axis(x, last_index[None, None, None], axis=1)
    logits = _linear(x_last, _head_weight(params))[:, 0]
    if collect:
        return logits.astype(jnp.float32), new_pools, telem
    return logits.astype(jnp.float32), new_pools


def paged_unified_step(params, pools: dict, pf_tokens: Array,
                       pf_start: Array, pf_length: Array, pf_first: Array,
                       pf_last_index: Array, pf_slots: Array,
                       dec_tokens: Array, dec_positions: Array,
                       dec_active: Array, hi_table: Array,
                       lo_table: Array, pages: Array, offsets: Array,
                       is_hi: Array, cfg: ModelConfig, serve: ServeConfig,
                       policy: Optional[ShardingPolicy] = None
                       ) -> tuple[Array, Array, dict]:
    """ONE device program per engine step: every planned prefill chunk and
    the whole decode slot array run as a single ragged batch.

    The flattened token stream is ``n_pf`` chunk spans of ``C`` tokens
    (right-padded rows of ``pf_tokens``) followed by one 1-token span per
    decode slot; the scheduler's per-span ``(query_start, query_len)``
    metadata arrives here as the span-ordered arrays below.  Inside the
    program the prefill region is built **span-major** — ``(n_pf, C, d)``,
    one batch row per span — so every sequence-axis op (the STaMP
    transform above all) applies per span and never across the flattened
    batch: the segment rule `repro.core.stamp.fold_segments` defines,
    satisfied here by construction rather than by a runtime fold (the
    ``seg_len`` stamp APIs serve callers that do hold a flattened
    carrier).  The decode region keeps the two-call path's exact
    ``(S, 1, d)`` shapes.

    ``pf_tokens``: (n_pf, C) int32 right-padded chunks (n_pf may be 0 —
    the all-decode fast case delegates to the `paged_decode_step` graph,
    single-token integer matmuls included);
    ``pf_start``: (n_pf,) tokens already cached per chunk row;
    ``pf_length``: (n_pf,) materialized length after this chunk
    (= start + valid tokens);
    ``pf_first``: (n_pf,) bool — consumed by the Mamba chunk-state
    initialization (attention needs no per-row branch: ``pf_start = 0``
    already reduces a no-prefix row to causal self-attention);
    ``pf_last_index``: (n_pf,) chunk-local index whose logits are the
    request's next-token distribution (meaningful on final chunks);
    ``pf_slots``: (n_pf,) decode-slot index per chunk row — Mamba layers
    carry conv/SSM state across chunk boundaries through that row of the
    slot-dense state pool (unused dummy rows point at the null slot, index
    ``S``);
    ``dec_tokens / dec_positions``: (S,) as in `paged_decode_step`;
    ``dec_active``: (S,) bool — True where a RUNNING request occupies the
    slot; where False the slot's (null) token must leave the per-slot
    conv/SSM state untouched (attention needs no mask: its null-page
    writes are never read);
    ``hi_table / lo_table``: (n_pf + S, ·) span-ordered block tables —
    chunk spans first (each row is that request's own table), then the
    slot array;
    ``pages / offsets / is_hi``: (n_pf·C + S,) write targets for the
    flattened token stream (pads and inactive slots → null page).

    Returns ``(pf_logits (n_pf, V), dec_logits (S, V), new_pools)``.
    """
    n_pf, c_len = pf_tokens.shape
    collect = _collect_telemetry(serve)
    if n_pf == 0:
        # all-decode fast case: decode runs transform-free (stamp=None),
        # so there is nothing to record — but the return arity must match
        # the collecting branch
        dec_logits, new_pools = paged_decode_step(
            params, pools, dec_tokens, dec_positions, hi_table, lo_table,
            pages, offsets, is_hi, cfg, serve, dec_active, policy)
        pf_logits = jnp.zeros((0, dec_logits.shape[-1]), jnp.float32)
        if collect:
            return pf_logits, dec_logits, new_pools, {}
        return pf_logits, dec_logits, new_pools
    assert policy is None, "unified step is single-device for now"
    set_fused_cache_attention(serve.fused_cache_attention)
    # both regions live in ONE trace, so the decode-matmul dispatch relies
    # on `_linear`'s token-dim shape guard: the (S, 1, d) decode
    # sub-tensors may take the single-token integer kernel, the (n_pf, C,
    # d) chunk rows never match it.  C == 1 would alias the two — keep the
    # transform path in that corner.
    set_fused_decode_matmul(serve.fused_decode_matmul and c_len > 1)
    compute_dtype = jnp.bfloat16
    # span-major from the start: embedding is per-token, so the (n_pf, C,
    # d) per-span view of the flattened batch is built directly
    x_pf = _embed(params, pf_tokens, compute_dtype)
    x_dec = _embed(params, dec_tokens[:, None], compute_dtype)
    pos_pf = pf_start[:, None] + jnp.arange(c_len)[None, :]
    paged = {"cfg": serve.paged,
             "span_ht": hi_table, "span_lt": lo_table,
             "span_starts": jnp.concatenate([pf_start, dec_positions]),
             "span_lengths": jnp.concatenate([pf_length,
                                              dec_positions + 1]),
             "pf_ht": hi_table[:n_pf], "pf_lt": lo_table[:n_pf],
             "dec_ht": hi_table[n_pf:], "dec_lt": lo_table[n_pf:],
             "pf_positions": pos_pf, "pf_start": pf_start,
             "pf_first": pf_first, "dec_positions": dec_positions,
             "dec_lengths": dec_positions + 1,
             "pages": pages, "offsets": offsets, "is_hi": is_hi,
             # slot-dense SSM state routing (hybrid stacks)
             "pf_slots": pf_slots, "pf_valid": pf_length - pf_start,
             "dec_active": dec_active}
    if collect:
        QS.begin()
    try:
        x, new_pools = run_stack(params, (x_pf, x_dec), cfg,
                                 mode="unified", positions=None,
                                 policy=policy, stamp=serve.stamp,
                                 kv_cfg=serve.kv, cache=pools,
                                 paged=paged, remat=False)
    finally:
        telem = QS.end() if collect else None
    x_pf, x_dec = x
    head = _head_weight(params)
    x_pf = L.rms_norm(x_pf, params["final_norm"].astype(x_pf.dtype),
                      cfg.norm_eps)
    x_last = jnp.take_along_axis(x_pf, pf_last_index[:, None, None], axis=1)
    pf_logits = _linear(x_last, head)[:, 0]
    x_dec = L.rms_norm(x_dec, params["final_norm"].astype(x_dec.dtype),
                       cfg.norm_eps)
    dec_logits = _linear(x_dec[:, 0], head)
    if collect:
        return (pf_logits.astype(jnp.float32),
                dec_logits.astype(jnp.float32), new_pools, telem)
    return (pf_logits.astype(jnp.float32), dec_logits.astype(jnp.float32),
            new_pools)


def paged_decode_step(params, pools: dict, tokens: Array, positions: Array,
                      hi_table: Array, lo_table: Array, pages: Array,
                      offsets: Array, is_hi: Array,
                      cfg: ModelConfig, serve: ServeConfig,
                      active: Optional[Array] = None,
                      policy: Optional[ShardingPolicy] = None
                      ) -> tuple[Array, dict]:
    """One decode step for the whole slot array against the paged cache.

    **Two-call parity path** (see `paged_prefill_chunk`) — and the graph
    the unified step delegates to for its all-decode fast case (n_pf = 0),
    single-token integer matmuls (`kernels/decode_matmul.py`) included.

    ``tokens``: (S,) int32 last token per slot; ``positions``: (S,) int32
    per-slot lengths (the incoming token's position); ``pages/offsets/
    is_hi``: (S,) write targets (inactive slots routed to the null page).
    Requests join and leave the slot array between steps — shapes stay
    static, inactivity is expressed entirely through the host-built index
    arrays and the per-slot lengths — except for Mamba layers, whose
    recurrence has no null page to hide behind: ``active`` (S,) bool masks
    the per-slot conv/SSM state update so an inactive slot's state is
    left untouched rather than advanced with a garbage token (defaults to
    all-active for the attention-only callers that predate it).
    """
    set_fused_cache_attention(serve.fused_cache_attention)
    set_fused_decode_matmul(serve.fused_decode_matmul)
    compute_dtype = jnp.bfloat16
    x = _embed(params, tokens[:, None], compute_dtype)
    if active is None:
        active = jnp.ones(tokens.shape, bool)
    paged = {"cfg": serve.paged, "hi_table": hi_table, "lo_table": lo_table,
             "pages": pages, "offsets": offsets, "is_hi": is_hi,
             "lengths": positions + 1, "dec_active": active}
    x, new_pools = run_stack(params, x, cfg, mode="decode",
                             positions=positions[:, None], policy=policy,
                             stamp=None, kv_cfg=serve.kv, cache=pools,
                             pos_scalar=positions, paged=paged)
    x = L.rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = _linear(x[:, 0], _head_weight(params))
    return logits.astype(jnp.float32), new_pools

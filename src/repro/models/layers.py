"""Model building blocks, written for GSPMD-friendly lowering.

Design constraints (CPU-only container, 512-way dry-run compiles):

* memory-bounded attention: double-scan flash-style accumulation so a 32k
  prefill never materializes an (s × s) score tensor;
* GShard-style capacity-based MoE dispatch (einsum form — partitions cleanly
  with experts on the 'model' mesh axis);
* chunked Mamba2 / SSD with a `lax.scan` over chunks (state-passing);
* every op keeps the feature/flattened-head dims divisible by the TP axis —
  head-count itself may not divide the mesh (MiniCPM: 36 heads), which GSPMD
  handles via the flat projections.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.obs import quantstats as QS

Array = jax.Array


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------


def rms_norm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    # f32 statistics.  (A bf16-square variant with f32 reduction dtype was
    # tried to stop XLA hoisting the x→f32 convert out of the remat'd
    # backward loop — it *increased* per-device HBM traffic 15–43% on the
    # dry run, so the explicit cast stays; see EXPERIMENTS.md §Perf iter 1.)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim)).astype(np.float32)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., s, h, hd); positions: broadcastable (..., s)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# fused STaMP linear (integer deployment path)
# ---------------------------------------------------------------------------


def stamp_fused_linear(x: Array, w: dict, b: Optional[Array],
                       stamp_cfg, merge_heads: bool = False,
                       site: Optional[str] = None) -> Array:
    """Run one STaMP linear through the fused Pallas integer kernel.

    ``w`` is a prepared-weight dict ``{"iq": (din, dout) int8, "isw": (1,
    dout), "izw": (1, dout)}`` built by `repro.models.lm.prepare_fused_weights`
    — the int8 buffers are reused across calls (no per-call dequant).  The
    kernel applies the sequence transform, mixed-precision quantization,
    integer GEMM and inverse transform in one VMEM residency, so the
    activation never materializes an intermediate in HBM.

    ``merge_heads=True`` marks ``x`` as the raw head-split ``(b, s, nh,
    hd)`` attention output (out-proj site): the head-merge reshape fuses
    with the kernel's in-VMEM quantize instead of materializing a merged
    activation first.
    """
    from repro.core.stamp import PreparedLinear, stamp_linear
    prep = PreparedLinear(qw=w["iq"], sw=w["isw"], zw=w["izw"], bias=b)
    return stamp_linear(x, None, None, stamp_cfg, prepared=prep,
                        merge_heads=merge_heads, site=site)


def stamp_fused_dual_linear(x: Array, w_gate: dict, w_up: dict,
                            stamp_cfg, site: Optional[str] = None) -> Array:
    """SwiGLU front half ``silu(x·Wg)·(x·Wu)`` through the dual-output
    fused kernel: the sequence transform + mixed-precision quantize of the
    shared input run ONCE (VMEM scratch) and drive both integer GEMMs; the
    silu·mul epilogue combines the pair in-VMEM, so the whole gate/up stage
    costs one HBM read of ``x`` and one write of the product."""
    from repro.core.stamp import PreparedLinear, stamp_dual_linear
    pg = PreparedLinear(qw=w_gate["iq"], sw=w_gate["isw"],
                        zw=w_gate["izw"], bias=None)
    pu = PreparedLinear(qw=w_up["iq"], sw=w_up["isw"],
                        zw=w_up["izw"], bias=None)
    return stamp_dual_linear(x, None, None, stamp_cfg,
                             prepared_gate=pg, prepared_up=pu,
                             epilogue="silu_mul", site=site)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnChunks:
    q: int = 2048
    kv: int = 2048


def flash_attention(
    q: Array,                # (b, sq, h, hd)
    k: Array,                # (b, skv, kv, hd)
    v: Array,
    causal: bool = True,
    chunks: AttnChunks = AttnChunks(),
    q_offset: int = 0,
) -> Array:
    """Memory-bounded attention: outer scan over query chunks, inner scan
    over KV chunks with running (max, sum, acc) — the standard online-softmax
    recurrence.  GQA query heads are *grouped* against their KV head
    (no materialized KV repeat).  Causal masking is applied per
    (q-chunk, kv-chunk) pair; fully-masked pairs still lower (XLA cannot
    skip data-dependent work in a scan) — the wasted half of causal FLOPs is
    accounted for in the roofline's MODEL_FLOPS/HLO ratio.
    """
    b, sq, h, hd = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g

    cq = min(chunks.q, sq)
    ckv = min(chunks.kv, skv)
    nq, nkv = sq // cq, skv // ckv
    assert sq % cq == 0 and skv % ckv == 0, (sq, cq, skv, ckv)

    scale = 1.0 / np.sqrt(hd)
    # (nq, b, g, rep, cq, hd) / (nkv, b, g, ckv, hd)
    qc = q.reshape(b, nq, cq, g, rep, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nkv, ckv, g, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nkv, ckv, g, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(cq)
    k_pos_base = jnp.arange(ckv)

    def q_body(_, qi_and_chunk):
        qi, qck = qi_and_chunk
        # NOTE (§Perf arctic iter 4, REVERTED): casting operands to bf16
        # with preferred_element_type=f32 left arctic's f32 collectives
        # untouched and cost prefill an extra score-sized bf16
        # materialization of `p` per KV block (−15 % on every prefill
        # cell).  f32 operands restored.
        qck32 = qck.astype(jnp.float32) * scale

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_body(carry, kv_in):
            m, l, acc = carry
            ki, kck, vck = kv_in
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qck32,
                           kck.astype(jnp.float32))
            if causal:
                qpos = q_offset + qi * cq + q_pos_base
                kpos = ki * ckv + k_pos_base
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vck.astype(jnp.float32))
            return (m_new, l_new, acc_new), ()

        init = (jnp.full((b, g, rep, cq), -1e30, jnp.float32),
                jnp.zeros((b, g, rep, cq), jnp.float32),
                jnp.zeros((b, g, rep, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_body, init, (jnp.arange(nkv), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    # (nq, b, g, rep, cq, hd) -> (b, sq, h, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    return out


def decode_attention(
    q: Array,                # (b, 1, h, hd)
    k_cache: Array,          # (b, s, kv, hd)  — bf16 (already dequantized)
    v_cache: Array,
    length: Optional[Array] = None,
) -> Array:
    """Single-token attention over the full cache, GQA-grouped.  When the
    cache's sequence axis is sharded over the 'model' mesh axis, GSPMD turns
    the softmax max/sum reductions into all-reduces — the TPU-native
    split-KV decode."""
    out = decode_attention_segments(q, [(k_cache, v_cache, 0)],
                                    length=length)
    return out


def decode_attention_segments(
    q: Array,                      # (b, 1, h, hd)
    segments: list,                # [(k, v, position_offset), ...]
    length: Optional[Array] = None,
) -> Array:
    """Decode attention over disjoint cache segments with a score-level
    merge: the mixed-precision cache's hi (64-token int8) and lo (int4)
    regions are attended separately and their scores concatenated — K/V are
    never concatenated along the GSPMD-sharded sequence axis (that concat
    reshards the whole cache by a 64-token offset every layer; §Perf).
    Matmuls keep bf16 operands with f32 accumulation (MXU-native)."""
    b, _, h, hd = q.shape
    g = segments[0][0].shape[2]
    rep = h // g
    scale = 1.0 / np.sqrt(hd)
    qg = (q.reshape(b, g, rep, hd) * scale).astype(segments[0][0].dtype)

    # per-segment online-softmax statistics, merged at the end — NO
    # cross-segment concatenation (concatenating a replicated 64-token hi
    # segment with a 16-way-sharded lo segment makes GSPMD replicate the
    # whole thing, dragging the packed cache through an all-gather).
    parts = []
    for k_seg, v_seg, offset in segments:
        s_seg = k_seg.shape[1]
        sc = jnp.einsum("bgrd,bsgd->bgrs", qg, k_seg,
                        preferred_element_type=jnp.float32)
        if length is not None:
            pos = offset + jnp.arange(s_seg)[None, None, None, :]
            mask = pos < length[:, None, None, None]
            sc = jnp.where(mask, sc, -1e30)
        m = jnp.max(sc, axis=-1)                        # (b, g, rep)
        p = jnp.exp(sc - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bgrs,bsgd->bgrd", p.astype(k_seg.dtype), v_seg,
                       preferred_element_type=jnp.float32)
        parts.append((m, l, o))
    m_tot = parts[0][0]
    for m, _, _ in parts[1:]:
        m_tot = jnp.maximum(m_tot, m)
    l_tot = jnp.zeros_like(m_tot)
    o_tot = jnp.zeros_like(parts[0][2])
    for m, l, o in parts:
        corr = jnp.exp(m - m_tot)
        l_tot = l_tot + l * corr
        o_tot = o_tot + o * corr[..., None]
    out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def chunked_prefill_attention(
    q: Array,                      # (b, c, h, hd) — chunk queries
    segments: list,                # [(k, v, position_offset), ...] cached
    k_self: Array,                 # (b, c, kv, hd) — this chunk's raw K
    v_self: Array,
    start: Array,                  # scalar or (b,) int32: tokens cached
) -> Array:
    """Attention for one continuous-batching prefill chunk: queries at
    global positions ``start + i`` attend to the **cached prefix** (the
    dequantized paged segments, strictly ``kpos < start`` — the chunk's own
    freshly written tokens are excluded so they aren't double-counted) and
    **causally to the raw chunk itself**.  Same per-segment online-softmax
    merge as `decode_attention_segments`, generalized to multiple query
    rows; a fully-masked segment's ``m = −1e30`` correction underflows to
    exactly zero.

    ``start`` may be a scalar (one chunk, the two-call engine) or a ``(b,)``
    vector (the unified ragged step batches several requests' chunks as
    rows, each with its own cached-prefix length)."""
    b, c, h, hd = q.shape
    g = k_self.shape[2]
    rep = h // g
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, c, g, rep, hd).astype(jnp.float32) * scale
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    qpos = start[:, None] + jnp.arange(c)[None, :]           # (b, c)

    parts = []

    def score_part(k_seg, v_seg, mask):          # mask: (b, c, s_seg) bool
        sc = jnp.einsum("bcgrd,bsgd->bgrcs", qg,
                        k_seg.astype(jnp.float32))
        sc = jnp.where(mask[:, None, None], sc, -1e30)
        m = jnp.max(sc, axis=-1)                 # (b, g, rep, c)
        p = jnp.exp(sc - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bgrcs,bsgd->bgrcd", p, v_seg.astype(jnp.float32))
        parts.append((m, l, o))

    for k_seg, v_seg, offset in segments:
        kpos = offset + jnp.arange(k_seg.shape[1])
        score_part(k_seg, v_seg,
                   jnp.broadcast_to(
                       (kpos[None, None, :] < start[:, None, None]),
                       (b, c, k_seg.shape[1])))
    kpos_self = start[:, None] + jnp.arange(k_self.shape[1])  # (b, c_kv)
    score_part(k_self, v_self,
               kpos_self[:, None, :] <= qpos[:, :, None])

    m_tot = parts[0][0]
    for m, _, _ in parts[1:]:
        m_tot = jnp.maximum(m_tot, m)
    l_tot = jnp.zeros_like(m_tot)
    o_tot = jnp.zeros_like(parts[0][2])
    for m, l, o in parts:
        corr = jnp.exp(m - m_tot)
        l_tot = l_tot + l * corr
        o_tot = o_tot + o * corr[..., None]
    out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def swiglu_mlp(x: Array, wi_gate: Array, wi_up: Array, wo: Array) -> Array:
    g = x @ wi_gate
    u = x @ wi_up
    return (jax.nn.silu(g) * u) @ wo


def _moe_fold(x: Array, group_size: int) -> tuple[Array, Array, int]:
    """Fold ``(bsz, seq, d)`` into fixed routing groups ``(b, gs, d)`` with
    the pad-tail validity mask (pad tokens must not occupy expert slots a
    real token would have used)."""
    bsz, seq, d = x.shape
    gs = min(group_size, seq)
    pad = -seq % gs
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((bsz, pad, d), x.dtype)], axis=1)
    seq_p = seq + pad
    x = x.reshape(bsz * (seq_p // gs), gs, d)
    valid = (jnp.arange(seq_p) < seq)                          # (seq_p,)
    valid = jnp.broadcast_to(valid[None], (bsz, seq_p)) \
        .reshape(x.shape[0], gs).astype(jnp.float32)
    return x, valid, seq_p


def moe_route(
    x: Array,                 # (b, s, d) — one folded routing group per row
    gate_w: Array,            # (d, E)
    experts_per_token: int,
    capacity_factor: float,
    valid: Array,             # (b, s) f32 pad mask
) -> tuple[Array, Array, Array]:
    """GShard capacity routing, shared VERBATIM by the reference and fused
    MoE paths — both consume the same combine/dispatch tensors, so kept and
    capacity-dropped token sets are bit-identical by construction.

    Returns ``(combine (b,s,E,C) in x.dtype, dispatch, counts (b,E)
    int32)``.  ``counts`` is each expert bucket's kept-token occupancy —
    kept slots form a prefix of ``[0, C)`` (the capacity cumsum assigns
    positions in flat routing order), which is what lets the grouped
    kernel's scalar-prefetch table clamp empty capacity tails.  When a
    quant-telemetry scope is open, per-expert load / drop counters ride
    the same collection protocol as the site stats.
    """
    b, s, _ = x.shape
    e = gate_w.shape[-1]
    k = experts_per_token
    cap = max(int(np.ceil(s * k / e * capacity_factor)), 1)

    logits = (x.astype(jnp.float32) @ gate_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (b, s, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # (b, s, k, E)
    onehot = onehot * valid[:, :, None, None]                  # drop padding
    # position of each (token, choice) within its expert queue, top-1 first
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, k * s, e)   # (b, k*s, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (b, k*s, E)
    pos = pos.reshape(b, k, s, e).transpose(0, 2, 1, 3)        # (b, s, k, E)
    keep = (pos < cap) * onehot                                # drop overflow
    pos_cap = jnp.einsum("bske,bske->bsk", pos, keep)          # position id
    cap_onehot = jax.nn.one_hot(pos_cap, cap, dtype=jnp.float32)  # (b,s,k,C)
    # (b, s, E, C) combine weights — cast to the compute dtype immediately:
    # routing positions need exact f32 cumsums, but the big dispatch/combine
    # einsums (and their cotangents, which GSPMD moves through expert
    # all-to-alls) must stay bf16 (§Perf arctic iter 3).
    combine = jnp.einsum("bsk,bske,bskc->bsec",
                         gate_vals, keep, cap_onehot).astype(x.dtype)
    dispatch = (combine > 0).astype(x.dtype)
    counts = jnp.sum(keep, axis=(1, 2)).astype(jnp.int32)      # (b, E)
    if QS.active():
        QS.record_extra("moe_router", {
            "expert_tokens": jnp.sum(keep, axis=(0, 1, 2)),    # (E,)
            "dropped_tokens": jnp.sum(onehot) - jnp.sum(keep),
            "capacity_slots": jnp.asarray(float(b * e * cap),
                                          jnp.float32),
        })
    return combine, dispatch, counts


def moe_ffn(
    x: Array,                 # (b, s, d)
    gate_w: Array,            # (d, E)
    w_gate: Array,            # (E, d, f)
    w_up: Array,              # (E, d, f)
    w_down: Array,            # (E, f, d)
    experts_per_token: int,
    capacity_factor: float,
    group_size: int = 1024,
) -> Array:
    """GShard/Switch-style capacity-based top-k MoE (reference path).

    Tokens are routed in fixed groups of ``group_size`` (the batch axis is
    folded with sequence sub-blocks), so the dispatch/combine tensors are
    (G, g, E, C) with C = k·g/E·cf — total footprint linear in ``group_size``
    and independent of sequence length.  Partitions over ('data' → G,
    'model' → E) without ragged ops; the einsum forms lower to
    all-to-all-like collectives under GSPMD.  Overflowing tokens are dropped
    (standard capacity semantics).

    A sequence length that doesn't divide ``group_size`` pads the tail
    group with zero tokens; padding is masked out of routing *before* the
    capacity cumsum (`_moe_fold`) and carries zero combine weight, so it
    never contributes to any output.
    """
    bsz, seq, d = x.shape
    x, valid, seq_p = _moe_fold(x, group_size)
    combine, dispatch, _ = moe_route(x, gate_w, experts_per_token,
                                     capacity_factor, valid)

    xin = jnp.einsum("bsec,bsd->becd", dispatch, x)            # (b, E, C, d)
    g = jnp.einsum("becd,edf->becf", xin, w_gate.astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xin, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("becf,efd->becd", h, w_down.astype(x.dtype))
    y = jnp.einsum("bsec,becd->bsd", combine, out)
    return y.reshape(bsz, seq_p, d)[:, :seq]


def moe_ffn_fused(
    x: Array,                 # (b, s, d) — the stamped round-trip activation
    gate_w: Array,            # (d, E) full-precision router
    w_gate: dict,             # {"iq": (E, d, f) int8, "isw", "izw"} prepared
    w_up: dict,
    w_down: dict,             # {"iq": (E, f, d) int8, ...}
    experts_per_token: int,
    capacity_factor: float,
    group_size: int = 1024,
) -> Array:
    """Capacity MoE through the grouped STaMP kernel.

    Routing is `moe_route` on the SAME stamped activation the reference
    path sees (bit-identical kept/dropped sets).  Then, instead of
    dispatching bf16 activations into ``(b, E, C, d)`` and re-materializing
    bf16 expert weights per call, each token is quantized ONCE
    (`token_quantize` — however many of its top-k buckets it lands in), the
    dispatch gather moves int8 codes, and `stamp_quant_grouped_matmul` runs
    the gate/up/down expert stack as grouped int8 GEMMs in one kernel with
    the per-bucket occupancy as its scalar-prefetch table.
    """
    from repro.core.stamp import token_quantize
    from repro.kernels import ops as kops
    bsz, seq, d = x.shape
    xg, valid, seq_p = _moe_fold(x, group_size)
    combine, dispatch, counts = moe_route(xg, gate_w, experts_per_token,
                                          capacity_factor, valid)
    b, _, e, cap = combine.shape
    qd, sd, zd = token_quantize(xg)
    # slot c of expert e holds the c-th kept token in sequence order, so
    # the argmax over the one-hot sequence axis IS the gather index;
    # empty slots gather token 0 and are zeroed by the kernel's count mask
    src = jnp.argmax(dispatch, axis=1)                         # (b, E, C)
    idx = src.reshape(b, e * cap, 1)

    def gather(t):
        return jnp.take_along_axis(t, idx, axis=1).reshape(b, e, cap, -1)

    ye = kops.stamp_quant_grouped_matmul(
        gather(qd), gather(sd), gather(zd), counts,
        w_gate["iq"], w_gate["isw"], w_gate["izw"],
        w_up["iq"], w_up["isw"], w_up["izw"],
        w_down["iq"], w_down["isw"], w_down["izw"])
    y = jnp.einsum("bsec,becd->bsd", combine, ye.astype(x.dtype))
    return y.reshape(bsz, seq_p, d)[:, :seq]


# ---------------------------------------------------------------------------
# Mamba2 / SSD (chunked, state-passing scan)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: Array,        # (b, s, h, p)   — per-head inputs
    dt: Array,       # (b, s, h)      — softplus'd step sizes
    a_log: Array,    # (h,)           — per-head log decay (A = -exp(a_log))
    b_mat: Array,    # (b, s, n)      — input projection B (single group)
    c_mat: Array,    # (b, s, n)      — output projection C
    chunk: int = 256,
    init_state: Optional[Array] = None,   # (b, h, p, n)
) -> tuple[Array, Array]:
    """State Space Duality (Mamba2 §6) chunked algorithm.

    Within a chunk the recurrence is computed in its quadratic 'attention'
    dual form; across chunks a `lax.scan` carries the (b, h, p, n) state.
    Returns (y, final_state).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))                    # (h,)
    dta = dt.astype(jnp.float32) * a[None, None, :]            # (b, s, h)

    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtac = dta.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    dtc = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(state, inp):
        xk, dtak, dtk, bk, ck = inp        # leading dim = b
        # cumulative decay within the chunk
        cum = jnp.cumsum(dtak, axis=1)                      # (b, c, h)
        # intra-chunk 'attention' matrix L_ij = exp(cum_i - cum_j) (i >= j)
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # (b, c, c, h)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        l = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        # scores: C_i · B_j weighted by decay and dt_j
        cb = jnp.einsum("bin,bjn->bij", ck, bk)             # (b, c, c)
        w = cb[..., None] * l * dtk[:, None, :, :]          # (b, c, c, h)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xk)
        # contribution of the incoming state
        decay_in = jnp.exp(cum)                             # (b, c, h)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ck, state, decay_in)
        # chunk summary -> next state
        decay_out = jnp.exp(cum[:, -1:, :] - cum)           # (b, c, h)
        state_new = (state * jnp.exp(cum[:, -1])[:, :, None, None]
                     + jnp.einsum("bjn,bjhp,bjh,bjh->bhpn",
                                  bk, xk, decay_out, dtk))
        return state_new, (y_intra + y_inter)

    state, yc = jax.lax.scan(body, init_state, (xc, dtac, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y.astype(x.dtype), state


def causal_conv1d(x: Array, w: Array, cache: Optional[Array] = None,
                  lengths: Optional[Array] = None) -> tuple[Array, Array]:
    """Depthwise causal conv along seq.  x: (b, s, d); w: (width, d).
    Returns (y, new_cache) where cache holds the last (width-1) inputs.

    ``lengths`` (b,) int32 marks each row's valid token count when ``x`` is
    right-padded: the returned cache is then the (width-1) inputs ending at
    the *valid* boundary, not the padded tail — the conv state a decode
    step must continue from.  Outputs past a row's length are garbage the
    caller discards (causality keeps valid outputs exact either way), and
    ``lengths=None`` (or full rows) reproduces the unsliced tail
    bit-for-bit."""
    width = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    if width <= 1:
        new_cache = cache
    elif lengths is None:
        new_cache = xp[:, -(width - 1):]
    else:
        # row r's tail = xp[r, lengths[r] : lengths[r] + width - 1]
        # (xp coordinates: the cache prefix shifts x by width-1, so index
        # `lengths` is the first of the last width-1 *valid* inputs);
        # lengths <= s keeps the gather in range without clamping
        idx = lengths[:, None] + jnp.arange(width - 1)[None, :]
        new_cache = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return jax.nn.silu(y), new_cache

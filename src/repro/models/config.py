"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` expresses dense GQA transformers, MoE (with optional
dense-residual), Mamba2/SSD, hybrid interleaves (Jamba), encoder–decoder
(Seamless), and VLM/audio backbones with stubbed modality frontends.

Layer heterogeneity is expressed as a *period pattern*: the model is
``prologue + num_periods × period`` layers, where each layer is a
``LayerSpec(mixer, ffn)``.  Homogeneous models have a period of one layer;
Jamba has a period of eight (1 attention + 7 Mamba, MoE every other layer).
Periods are scanned (small HLO), layers inside a period are unrolled.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # attn | mamba | none
    ffn: str = "mlp"           # mlp | moe | moe_dense (Arctic residual) | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // num_heads
    qkv_bias: bool = False                # Qwen2
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                     # expert hidden size (if != d_ff)
    dense_residual: bool = False          # Arctic: FFN = dense MLP + MoE
    moe_period: int = 1                   # MoE every k-th layer (Jamba: 2)
    first_layer_dense: bool = False       # Kimi-K2: layer 0 is dense MLP
    capacity_factor: float = 1.25
    moe_group_size: int = 1024            # routing group (GShard-style)
    # --- hybrid / ssm ---
    attn_period: int = 0                  # Jamba: 1 attention per 8 layers
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # --- encoder-decoder ---
    encoder_layers: int = 0               # >0 => enc-dec (Seamless)
    # --- modality frontend stubs ---
    frontend: Optional[str] = None        # 'patch' (VLM) | 'frames' (audio)
    num_patches: int = 576                # LLaVA anyres merged patches
    frame_ratio: int = 4                  # audio frames = seq // frame_ratio
    # --- misc ---
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    schedule: str = "cosine"              # 'wsd' for MiniCPM
    sub_quadratic: bool = False           # True for ssm/hybrid (long_500k ok)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron-style) so the
        embedding/head shard evenly over the TP axis and align to the MXU."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def layer_plan(self) -> tuple[Tuple[LayerSpec, ...], Tuple[LayerSpec, ...], int]:
        """Returns (prologue, period_pattern, num_periods)."""
        n = self.num_layers
        if self.family == "ssm":
            return (), (LayerSpec("mamba", "none"),), n
        if self.family == "hybrid":
            period = []
            p = self.attn_period or 8
            for i in range(p):
                mixer = "attn" if i == (p // 2) else "mamba"
                # MoE every `moe_period`-th layer within the period
                ffn = "moe" if (self.num_experts and i % self.moe_period ==
                                (self.moe_period - 1)) else "mlp"
                period.append(LayerSpec(mixer, ffn))
            if n % p:
                raise ValueError(
                    f"{self.name}: {n} layers not divisible by period {p}")
            return (), tuple(period), n // p
        if self.family == "moe":
            spec = LayerSpec("attn", "moe_dense" if self.dense_residual else "moe")
            if self.first_layer_dense:
                return (LayerSpec("attn", "mlp"),), (spec,), n - 1
            return (), (spec,), n
        # dense / vlm / audio backbones
        return (), (LayerSpec("attn", "mlp"),), n

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6·N·D."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        mlp = 3 * d * self.d_ff
        moe = 0
        if self.num_experts:
            moe = self.num_experts * 3 * d * self.expert_d_ff + d * self.num_experts
        di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
        groups_dim = 2 * ns  # B and C projections (single group)
        mamba = (d * (2 * di + groups_dim + nh)   # in_proj (x, z, B, C, dt)
                 + di * d                          # out_proj
                 + di * self.conv_width + nh * 2 + di)  # conv, A/dt bias, D
        total = 0
        pro, period, nper = self.layer_plan()
        for spec in pro + period * nper:
            if spec.mixer == "attn":
                total += attn
            elif spec.mixer == "mamba":
                total += mamba
            if spec.ffn == "mlp":
                total += mlp
            elif spec.ffn == "moe":
                total += moe
            elif spec.ffn == "moe_dense":
                total += moe + mlp
            total += 2 * d  # norms
        if self.encoder_layers:
            # encoder self-attn + ffn, and decoder cross-attn blocks
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.num_layers * (attn + d)  # cross-attn + norm
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params, for MoE MODEL_FLOPS = 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        full_moe = self.num_experts * 3 * self.d_model * self.expert_d_ff
        active_moe = self.experts_per_token * 3 * self.d_model * self.expert_d_ff
        pro, period, nper = self.layer_plan()
        n_moe_layers = sum(1 for s in pro + period * nper
                           if s.ffn in ("moe", "moe_dense"))
        return self.param_count() - n_moe_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped (pure full-attention arch; long_500k needs sub-quadratic)"
    return True, ""

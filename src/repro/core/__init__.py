"""STaMP core: quantizers, sequence/feature transforms, bit allocation."""

from repro.core.stamp import StampConfig, stamp_linear, stamp_fake_quant  # noqa: F401
from repro.core.quant import (  # noqa: F401
    fake_quant,
    fake_quant_per_block,
    mixed_precision_bits,
    rtn_quantize_weight,
    sqnr_db,
)

"""Feature-dimension transforms and baselines the paper combines with STaMP.

These implement the comparison/combination methods of Tables 1–2 and §4:

* **Hadamard / QuaRot** [Ashkboos et al. 2024] — orthogonal feature rotation
  ``X → X·R`` with ``R⁻¹`` folded into the weights, plus QuaRot's 10 %
  min-max range shrink.
* **SmoothQuant** [Xiao et al. 2023] — per-channel scale migration
  ``X → X·diag(s)⁻¹``, ``W → diag(s)·W`` with
  ``s_j = max|X_j|^α / max|W_j|^{1−α}``.
* **ViDiT-Q SDCB** [Zhao et al. 2025] — static channel balancing from
  calibration stats (α = 0.01 for the DiT setup, §B.1).
* **SVDQuant** [Li et al. 2025] — absorb outliers into a high-precision
  low-rank branch ``W ≈ L₁L₂ + ΔW_q``; activations/residual quantized.
* **FlatQuant-lite** [Sun et al. 2025] — a learned per-layer affine
  (diagonal ∘ Hadamard) minimizing the layer-output quantization MSE with a
  few STE gradient steps on calibration data (lightweight stand-in for the
  full Kronecker-factored FlatQuant).

Feature transforms are *right* multiplications on activations — exactly the
``R`` of Eq. 4/6 — hence freely composable with STaMP's left transform ``L``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q

Array = jax.Array


# ---------------------------------------------------------------------------
# Hadamard (QuaRot)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def hadamard_matrix(d: int) -> np.ndarray:
    """Orthonormal Hadamard-like rotation for any ``d``.

    For ``d = 2^k`` this is the Sylvester Hadamard.  Otherwise we factor
    ``d = 2^k · m`` and use ``H_{2^k} ⊗ I_m`` — orthonormal, mixes within
    2^k-sized groups (the standard fallback when no exact Hadamard of size d
    is available).
    """
    k = 0
    m = d
    while m % 2 == 0:
        m //= 2
        k += 1
    h = np.array([[1.0]])
    for _ in range(k):
        h = np.block([[h, h], [h, -h]])
    h = h / np.sqrt(h.shape[0])
    if m > 1:
        h = np.kron(h, np.eye(m))
    return h.astype(np.float32)


def random_hadamard(d: int, key: jax.Array) -> Array:
    """QuaRot's randomized Hadamard ``H · diag(±1)`` (still orthonormal)."""
    signs = jax.random.rademacher(key, (d,), dtype=jnp.float32)
    return jnp.asarray(hadamard_matrix(d)) * signs[None, :]


# ---------------------------------------------------------------------------
# SmoothQuant / SDCB channel scaling
# ---------------------------------------------------------------------------


def smoothquant_scales(act_absmax: Array, w_absmax: Array,
                       alpha: float = 0.5) -> Array:
    """``s_j = max|X_j|^α / max|W_j|^{1−α}`` (SmoothQuant Eq. 4)."""
    a = jnp.maximum(act_absmax, 1e-5) ** alpha
    w = jnp.maximum(w_absmax, 1e-5) ** (1.0 - alpha)
    return a / w


def sdcb_scales(act_absmax: Array, w_absmax: Array,
                alpha: float = 0.01) -> Array:
    """ViDiT-Q's static channel balancing — SmoothQuant with the DiT-tuned
    α = 0.01 (§B.1), i.e. scaling almost entirely towards the weights."""
    return smoothquant_scales(act_absmax, w_absmax, alpha=alpha)


# ---------------------------------------------------------------------------
# SVDQuant-style low-rank absorption
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SVDQuantWeight:
    """``W ≈ l1 @ l2 (fp) + residual (int)`` — the residual carries much less
    dynamic range, so 4-bit RTN on it is accurate (SVDQuant §3)."""

    l1: Array               # (d_in, r) fp16/bf16
    l2: Array               # (r, d_out)
    residual: Q.QuantizedWeight

    def dequant(self, dtype=jnp.bfloat16) -> Array:
        return (self.l1 @ self.l2).astype(dtype) + self.residual.dequant(dtype)


def svdquant_decompose(w: Array, rank: int = 32,
                       bits: int = 4) -> SVDQuantWeight:
    wf = np.asarray(w, np.float32)
    u, s, vt = np.linalg.svd(wf, full_matrices=False)
    l1 = u[:, :rank] * s[:rank][None, :]
    l2 = vt[:rank]
    resid = wf - l1 @ l2
    rq = Q.rtn_quantize_weight(jnp.asarray(resid), bits=bits, axis=0)
    return SVDQuantWeight(l1=jnp.asarray(l1), l2=jnp.asarray(l2), residual=rq)


# ---------------------------------------------------------------------------
# FlatQuant-lite: learned diagonal ∘ Hadamard
# ---------------------------------------------------------------------------


def flatquant_lite_fit(
    x_calib: Array,
    w: Array,
    bits: int = 4,
    steps: int = 100,
    lr: float = 1e-2,
) -> tuple[Array, Array]:
    """Learn ``R = diag(exp θ) · H`` minimizing ‖Q(X R) R⁻¹ W − X W‖².

    Returns ``(R, R⁻¹)``; the inverse is analytic
    (``R⁻¹ = Hᵀ · diag(exp −θ)``), so it can be folded into the weights like
    any other feature transform.
    """
    d = x_calib.shape[-1]
    h = jnp.asarray(hadamard_matrix(d))
    ref = x_calib @ w

    def loss(theta):
        r = (jnp.exp(theta)[:, None]) * h          # diag(e^θ) @ H
        r_inv = h.T * jnp.exp(-theta)[None, :]
        tx = x_calib @ r
        tq = Q.fake_quant(tx, bits, axis=-1)
        y = (tq @ r_inv) @ w
        return jnp.mean((y - ref) ** 2)

    theta = jnp.zeros((d,), jnp.float32)
    grad = jax.jit(jax.grad(loss))
    # plain Adam, few steps — FlatQuant trains 15 epochs; this is the lite
    # calibration-time variant.
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    for t in range(1, steps + 1):
        g = grad(theta)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-8)
    r = (jnp.exp(theta)[:, None]) * h
    r_inv = h.T * jnp.exp(-theta)[None, :]
    return r, r_inv


def fold_feature_transform(w: Array, r: Array) -> Array:
    """Fold ``R⁻¹`` into a (d_in, d_out) weight: ``W' = R⁻¹ W``.

    For orthonormal R, ``R⁻¹ = Rᵀ``; for the FlatQuant diag∘H form the
    caller passes the analytic inverse directly.
    """
    return r.T @ w


# ---------------------------------------------------------------------------
# method registry used by the benchmark harness
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FeatureTransformSpec:
    """A calibrated feature-transform: R applied to activations, R⁻¹ already
    folded into the weight supplied at construction time."""

    name: str
    r: Optional[Array]        # None = identity
    r_inv: Optional[Array]
    act_scale: Optional[Array] = None   # SmoothQuant/SDCB diag scaling

    def apply_to_activation(self, x: Array) -> Array:
        if self.act_scale is not None:
            x = x / self.act_scale.astype(x.dtype)
        if self.r is not None:
            x = x @ self.r.astype(x.dtype)
        return x

    def fold_into_weight(self, w: Array) -> Array:
        if self.r_inv is not None:
            w = self.r_inv.astype(w.dtype) @ w
        if self.act_scale is not None:
            w = w * self.act_scale[:, None].astype(w.dtype)
        return w


def build_feature_transform(
    name: str,
    d: int,
    *,
    x_calib: Optional[Array] = None,
    w: Optional[Array] = None,
    key: Optional[jax.Array] = None,
    bits: int = 4,
) -> FeatureTransformSpec:
    """Factory over the paper's feature-transform baselines."""
    if name in ("none", "identity", "rtn", "svdquant"):
        # SVDQuant is a *weight* decomposition — activations untransformed;
        # the low-rank branch is handled by the caller.
        return FeatureTransformSpec(name, None, None)
    if name in ("hadamard", "quarot"):
        r = (random_hadamard(d, key) if key is not None
             else jnp.asarray(hadamard_matrix(d)))
        return FeatureTransformSpec(name, r, r.T)
    if name in ("smoothquant", "sdcb", "vidit-q"):
        assert x_calib is not None and w is not None
        alpha = 0.5 if name == "smoothquant" else 0.01
        s = smoothquant_scales(
            jnp.max(jnp.abs(x_calib.reshape(-1, d)), axis=0),
            jnp.max(jnp.abs(w), axis=1),
            alpha=alpha)
        return FeatureTransformSpec(name, None, None, act_scale=s)
    if name == "flatquant":
        assert x_calib is not None and w is not None
        r, r_inv = flatquant_lite_fit(x_calib.reshape(-1, d), w, bits=bits)
        return FeatureTransformSpec(name, r, r_inv)
    raise ValueError(f"unknown feature transform {name!r}")

"""Analytical error bounds from the paper (Eq. 3, Theorem 1, Appendix A.3).

These are exercised by property tests to *prove the implementation matches
the paper's math*: the measured quantization error must never exceed the
bounds, orthogonal transforms must leave the error of the transformed tensor
equal to the round-trip error (Eq. 10), and energy concentration + mixed
precision must beat the uniform scheme (A.3 / Fig. 2b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q

Array = jax.Array


def eq3_bound(x: Array, bits) -> Array:
    """Per-token bound ``d/4 · range(x_i)² / (2^b − 1)²`` summed over tokens
    (Eq. 3).  ``x`` is (..., s, d)."""
    d = x.shape[-1]
    rng = jnp.max(x, axis=-1) - jnp.min(x, axis=-1)        # (..., s)
    n = 2.0 ** jnp.asarray(bits, jnp.float32) - 1.0
    return jnp.sum(d / 4.0 * rng.astype(jnp.float32) ** 2 / n**2)


def theorem1_bound(tx: Array, bits) -> Array:
    """``d/2 · Σ_i ‖(LX)_i‖² / (2^{b_i} − 1)²`` (Eq. 8) evaluated on the
    already-transformed activations ``tx = L X``."""
    d = tx.shape[-1]
    energy = jnp.sum(tx.astype(jnp.float32) ** 2, axis=-1)  # (..., s)
    n = 2.0 ** jnp.asarray(bits, jnp.float32) - 1.0
    return jnp.sum(d / 2.0 * energy / n**2)


def measured_error(x: Array, bits, axis: int = -1) -> Array:
    """Empirical ``‖Q(x) − x‖²`` with per-token min-max scales."""
    q = Q.fake_quant(x.astype(jnp.float32), bits, axis=axis,
                     out_dtype=jnp.float32)
    return Q.quant_error(x, q)


def uniform_vs_concentrated(energies: Array, avg_bits: float, d: int) -> tuple:
    """Appendix A.3: compare the Thm-1 bound for (a) uniform energy+bits and
    (b) max concentration with Eq.-18 bits.  Returns (uniform, concentrated);
    Jensen guarantees concentrated ≤ uniform."""
    e = jnp.asarray(energies, jnp.float32)
    s = e.shape[-1]
    total_e = jnp.sum(e)
    uniform = d / 2.0 * s * (total_e / s) / (2.0 ** (2 * avg_bits))
    log_e = jnp.log2(jnp.maximum(e, 1e-20))
    concentrated = d / 2.0 * s * 2.0 ** (jnp.mean(log_e) - 2 * avg_bits)
    return uniform, concentrated

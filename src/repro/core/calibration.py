"""Calibration pass: sequence-autocorrelation, KLT, and energy statistics.

The paper's §3.2 estimates ``S = E[X Xᵀ]`` per quantization site on a small
calibration set; the KLT basis is its eigenbasis, and energy profiles under
each candidate transform drive the bit allocation (§3.3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitalloc, transforms

Array = jax.Array


@dataclasses.dataclass
class SiteStats:
    """Running statistics for one quantization site (a linear-layer input)."""

    autocorr: np.ndarray       # (s, s) running mean of X Xᵀ
    act_absmax: np.ndarray     # (d,) running max |X| per feature channel
    count: int = 0

    @classmethod
    def empty(cls, seq_len: int, d: int) -> "SiteStats":
        return cls(np.zeros((seq_len, seq_len), np.float64),
                   np.zeros((d,), np.float32), 0)

    def update(self, x: Array) -> None:
        """Accumulate one batch ``(b, s, d)``."""
        xf = np.asarray(x, np.float32)
        b = xf.shape[0]
        s = np.einsum("bsd,btd->st", xf, xf) / xf.shape[0]
        self.autocorr = (self.autocorr * self.count + s * b) / (self.count + b)
        self.act_absmax = np.maximum(self.act_absmax,
                                     np.abs(xf).reshape(-1, xf.shape[-1]).max(0))
        self.count += b

    def klt(self) -> np.ndarray:
        return transforms.klt_basis(self.autocorr)

    def energy_profile(self, kind: str, levels: int = 3,
                       hw: Optional[tuple[int, int]] = None) -> np.ndarray:
        """Diagonal of ``L S Lᵀ`` — per-token energy under transform L
        (Eq. 9), computed directly on the autocorrelation so no activations
        need to be re-read."""
        s = self.autocorr.shape[0]
        eye = jnp.eye(s, dtype=jnp.float32)
        if kind == "klt":
            l = jnp.asarray(self.klt())
        else:
            # build L by transforming the identity (columns = basis action)
            l = transforms.sequence_transform(
                eye[None], kind, axis=-2, levels=levels, hw=hw)[0]
        sa = jnp.asarray(self.autocorr, jnp.float32)
        return np.asarray(jnp.einsum("is,st,it->i", l, sa, l))


def toeplitz_fraction(autocorr: np.ndarray) -> float:
    """How Toeplitz the autocorrelation is: fraction of energy explained by
    the diagonal-mean Toeplitz projection.  Close to 1 on natural text/image
    activations (Fig. 3a) — the premise for DCT ≈ KLT (Szegő)."""
    s = autocorr.shape[0]
    t = np.zeros_like(autocorr)
    for k in range(-s + 1, s):
        d = np.diagonal(autocorr, k)
        np.fill_diagonal(t[max(0, -k):, max(0, k):], d.mean())
    num = float((t**2).sum())
    den = float((autocorr**2).sum()) + 1e-12
    return num / den


@dataclasses.dataclass
class CalibrationResult:
    """Per-site calibration artifacts consumed by the PTQ pipeline."""

    klt_bases: Dict[str, np.ndarray]
    energies: Dict[str, np.ndarray]
    act_absmax: Dict[str, np.ndarray]
    num_hi: Dict[str, int]


def calibrate(
    sites: Dict[str, Iterable[Array]],
    transform: str = "dwt",
    levels: int = 3,
    avg_budget: float = 4.125,
    hi: int = 8,
    lo: int = 4,
    compute_klt: bool = False,
) -> CalibrationResult:
    """Run the full calibration pass over per-site activation batches."""
    klts: Dict[str, np.ndarray] = {}
    energies: Dict[str, np.ndarray] = {}
    absmax: Dict[str, np.ndarray] = {}
    num_hi: Dict[str, int] = {}
    for name, batches in sites.items():
        stats: Optional[SiteStats] = None
        for x in batches:
            if stats is None:
                stats = SiteStats.empty(x.shape[-2], x.shape[-1])
            stats.update(x)
        assert stats is not None, f"no calibration data for site {name}"
        e = stats.energy_profile(transform, levels=levels)
        energies[name] = e
        absmax[name] = stats.act_absmax
        num_hi[name] = bitalloc.greedy_two_level(
            np.sort(e)[::-1], avg_budget, hi=hi, lo=lo)
        if compute_klt:
            klts[name] = stats.klt()
    return CalibrationResult(klts, energies, absmax, num_hi)

"""STaMP: the sequence-transformed, mixed-precision linear layer (Fig. 2a).

The algorithm for ``y = act_quant(X) @ W + β`` under STaMP:

    1.  ``T = L · X``                      (sequence transform, §3)
    2.  ``T = T · R``                      (optional feature transform;
                                            ``R⁻¹`` is pre-folded into W)
    3.  ``Tq = Q(T)``                       (mixed-precision fake quant,
                                            first ``num_hi`` tokens hi-bit)
    4.  ``Y = Tq · W'``                     (W' = R⁻¹ W, possibly int)
    5.  ``y = L⁻¹ · Y + 1βᵀ``               (inverse transform then bias —
                                            Eq. 7 commutation)

``L`` is never materialized: DWT/DCT/WHT are applied as fast operators.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core import transforms as T
from repro.obs import quantstats as QS

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StampConfig:
    """Configuration for STaMP activation quantization.

    Defaults reproduce the paper's headline setting: Haar DWT, 3 levels,
    64 tokens at 8 bits, rest at 4 bits (avg 4.0625–4.125), first-token
    exception on for LLMs (§B.2).
    """

    seq_transform: str = "dwt"       # none|dwt|dwt2d|dct|wht|klt
    levels: Optional[int] = None     # None = auto: log2(seq / num_hi), so the
                                     # low-pass band aligns with the hi-bit
                                     # token budget (total cost stays O(s·d))
    num_hi_tokens: int = 64
    hi_bits: int = 8
    lo_bits: int = 4
    skip_first_token: bool = True    # attention-sink exception (§B.2)
    granularity: str = "token"       # token | block
    block_size: int = 64
    hw: Optional[tuple[int, int]] = None   # (H, W) grid for dwt2d
    enabled: bool = True
    execution: str = "reference"     # reference | fused (Pallas integer path)
    fused_weight_bits: int = 8       # weight codes for on-the-fly prepare

    def bits_vector(self, seq_len: int) -> Array:
        return Q.mixed_precision_bits(seq_len, self.num_hi_tokens,
                                      self.hi_bits, self.lo_bits)

    def resolved_levels(self, seq_len: int) -> int:
        if self.levels is not None:
            return self.levels
        import math
        ratio = max(seq_len / max(self.num_hi_tokens, 1), 2)
        return max(1, int(math.ceil(math.log2(ratio))))

    def average_bits(self, seq_len: int) -> float:
        return Q.average_bits(self.bits_vector(seq_len))


# ---------------------------------------------------------------------------
# segment-aware application (the unified ragged serving step)
# ---------------------------------------------------------------------------
#
# The unified prefill+decode step flattens several requests' tokens into one
# batch.  STaMP's sequence transform is defined per *sequence span* — mixing
# tokens of different requests through the DWT/WHT butterflies would be
# numerically meaningless — so every sequence-axis op on the flattened batch
# must first fold the span structure back into the batch axis.  With the
# uniform span padding the scheduler produces (each prefill chunk padded to
# the same ``seg_len``), that fold is a pure reshape: the transform then
# runs independently per span exactly as it does for a lone chunk, and the
# fused kernels see spans as batch grid rows (their transform+quantize
# scratch is per grid row already, so no kernel change is needed beyond the
# fold).  Decode spans are single tokens — their "transform" is the
# identity, which is why the decode path applies no sequence transform.


def fold_segments(x: Array, seg_len: int) -> Array:
    """View a flattened ``(b, n·seg_len, …)`` ragged batch as
    ``(b·n, seg_len, …)`` so sequence-axis ops (the STaMP transform above
    all) apply per span and never across the flattened batch."""
    b, t = x.shape[0], x.shape[1]
    if t % seg_len:
        raise ValueError(f"flattened length {t} is not a whole number of "
                         f"{seg_len}-token segments")
    return x.reshape(b * (t // seg_len), seg_len, *x.shape[2:])


def unfold_segments(y: Array, batch: int) -> Array:
    """Inverse of :func:`fold_segments`: ``(b·n, seg_len, …)`` back to the
    flattened ``(b, n·seg_len, …)`` layout."""
    bn, seg_len = y.shape[0], y.shape[1]
    return y.reshape(batch, (bn // batch) * seg_len, *y.shape[2:])


def apply_seq_transform(x: Array, cfg: StampConfig, axis: int = -2,
                        basis: Optional[Array] = None) -> Array:
    if not cfg.enabled or cfg.seq_transform == "none":
        return x
    return T.sequence_transform(
        x, cfg.seq_transform, axis=axis,
        levels=cfg.resolved_levels(x.shape[axis]),
        skip_first=cfg.skip_first_token, hw=cfg.hw, basis=basis)


def invert_seq_transform(y: Array, cfg: StampConfig, axis: int = -2,
                         basis: Optional[Array] = None) -> Array:
    if not cfg.enabled or cfg.seq_transform == "none":
        return y
    return T.inverse_sequence_transform(
        y, cfg.seq_transform, axis=axis,
        levels=cfg.resolved_levels(y.shape[axis]),
        skip_first=cfg.skip_first_token, hw=cfg.hw, basis=basis)


def stamp_fake_quant(x: Array, cfg: StampConfig, axis: int = -2,
                     basis: Optional[Array] = None,
                     seg_len: Optional[int] = None,
                     site: Optional[str] = None) -> Array:
    """Full STaMP round trip on an activation: ``L⁻¹ Q(L X)`` — used when a
    consumer needs the activation back in the original domain (e.g. KV-cache
    values feeding non-linear attention math).

    ``seg_len`` marks ``x`` as a flattened ragged batch of uniform
    ``seg_len``-token spans along axis 1: the round trip applies per span
    (see :func:`fold_segments`), identical to running each span alone."""
    if not cfg.enabled:
        return x
    if seg_len is not None and seg_len != x.shape[1]:
        if axis not in (-2, x.ndim - 2):
            raise ValueError("segments fold along axis 1")
        return unfold_segments(
            stamp_fake_quant(fold_segments(x, seg_len), cfg, axis=-2,
                             basis=basis, site=site), x.shape[0])
    # f32 transform + quant statistics: bf16 butterflies perturb the min/max
    # scales enough to flip 4-bit codes, which would make the reference and
    # fused paths (kernel computes in f32) diverge beyond quant tolerance.
    tx = apply_seq_transform(x.astype(jnp.float32), cfg, axis=axis,
                             basis=basis)
    bits = cfg.bits_vector(tx.shape[axis])
    if axis in (-2, x.ndim - 2):     # telemetry assumes (..., s, d) layout
        QS.record(site, tx, bits, cfg.hi_bits)
    if cfg.granularity == "block":
        # per-(token, block) scales — bits stays per-token
        tq = _blockwise_mixed(tx, bits, cfg.block_size)
    else:
        tq = Q.fake_quant(tx, bits, axis=-1)
    return invert_seq_transform(tq, cfg, axis=axis,
                                basis=basis).astype(x.dtype)


def _blockwise_mixed(tx: Array, bits: Array, block_size: int) -> Array:
    *lead, s, d = tx.shape
    if d % block_size:
        return Q.fake_quant(tx, bits, axis=-1)
    xb = tx.reshape(*lead, s, d // block_size, block_size)
    bitsb = bits[:, None]  # per-token bits broadcast over feature blocks
    n = 2.0 ** bitsb - 1.0
    mn = jnp.min(xb, axis=-1, keepdims=True)
    mx = jnp.max(xb, axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / n[..., None], 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(xb / scale) + zp, 0.0, n[..., None])
    deq = ((q - zp) * scale).astype(tx.dtype)
    return deq.reshape(*lead, s, d)


# ---------------------------------------------------------------------------
# fused (integer) execution path
# ---------------------------------------------------------------------------


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("qw", "sw", "zw", "bias"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class PreparedLinear:
    """Deployment weight buffers for the fused path: signed-int8 codes plus
    per-output-channel affine params, quantized **once** at preparation time
    instead of re-materializing bf16 weights on every call."""

    qw: Array               # (din, dout) int8, codes shifted by -2^(b-1)
    sw: Array               # (1, dout) f32 scale
    zw: Array               # (1, dout) f32 zero point (same shift applied)
    bias: Optional[Array]   # (dout,) or None

    def dequant(self, dtype=jnp.bfloat16) -> Array:
        return ((self.qw.astype(jnp.float32) - self.zw) * self.sw).astype(dtype)


def prepare_linear(
    w: Optional[Array] = None,
    b: Optional[Array] = None,
    w_quant: Optional[Q.QuantizedWeight] = None,
    bits: int = 8,
) -> PreparedLinear:
    """Build the fused path's cached weight buffers.

    From ``w_quant`` the existing integer codes are reused bit-exactly
    (shifted into signed storage, zero point shifted identically); from a
    raw ``w`` a per-output-channel asymmetric min-max quantization at
    ``bits`` is applied.  ``axis=-2`` reduction, so stacked ``(layers, din,
    dout)`` weights prepare in one call — the gate/up pair of a SwiGLU MLP
    stacks to ``(2, din, dout)`` and prepares as one call too (per-channel
    scales make the stacked prepare identical to two separate ones); see
    `repro.models.lm.prepare_fused_weights`.
    """
    if w_quant is not None:
        if w_quant.bits > 8:
            raise ValueError("fused path stores weight codes in int8")
        shift = 1 << (w_quant.bits - 1)
        qw = (w_quant.q.astype(jnp.int32) - shift).astype(jnp.int8)
        return PreparedLinear(qw=qw, sw=w_quant.scale.astype(jnp.float32),
                              zw=(w_quant.zero_point - shift).astype(jnp.float32),
                              bias=b)
    if bits > 8:
        raise ValueError("fused path stores weight codes in int8")
    n = float(2**bits - 1)
    shift = float(1 << (bits - 1))
    wf = w.astype(jnp.float32)
    # anchor the range at zero: guarantees zp ∈ [0, n], so the signed-shifted
    # zero point stays a bf16-exact small integer (the decode-path dequant in
    # models/lm.py relies on this; an unanchored one-sided channel would
    # push zp to ±range/step and round in bf16)
    mn = jnp.minimum(jnp.min(wf, axis=-2, keepdims=True), 0.0)
    mx = jnp.maximum(jnp.max(wf, axis=-2, keepdims=True), 0.0)
    sw = jnp.maximum((mx - mn) / n, 1e-8)
    zp = jnp.round(-mn / sw)
    qw = (jnp.clip(jnp.round(wf / sw) + zp, 0.0, n) - shift).astype(jnp.int8)
    return PreparedLinear(qw=qw, sw=sw, zw=zp - shift, bias=b)


def token_quantize(x: Array, bits: int = 8
                   ) -> tuple[Array, Array, Array]:
    """Per-token asymmetric min-max quantize in the **token domain** — the
    grouped MoE path's dispatch-buffer format.  The STaMP round trip
    (transform + mixed-precision quantize + inverse) has already shaped
    ``x``; this re-codes each token once, *before* dispatch, so a top-k
    routed token is quantized a single time however many expert buckets it
    lands in and the dispatch gather moves int8 codes instead of bf16
    activations.  Returns signed int8 codes plus ``(..., 1)`` f32 scale
    and identically shifted zero point (the `_int_gemm` convention)."""
    n = float(2 ** bits - 1)
    shift = float(1 << (bits - 1))
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=-1, keepdims=True)
    mx = jnp.max(xf, axis=-1, keepdims=True)
    s = jnp.maximum((mx - mn) / n, 1e-8)
    z = jnp.round(-mn / s)
    q = (jnp.clip(jnp.round(xf / s) + z, 0.0, n) - shift).astype(jnp.int8)
    return q, s, z - shift


def fused_ineligibility(cfg: StampConfig,
                        feature_rot: Optional[Array] = None
                        ) -> tuple:
    """Why this config canNOT run the fused Pallas kernel, as a tuple of
    structured reason codes (empty == fused-eligible).  The codes are the
    machine-readable half of the eligibility audit
    (``repro.analysis.contracts``): the ROADMAP's "silently fall back"
    configs (dense bases, per-block scales, activation rotations, bit
    widths beyond int8 storage) each map to a stable code here instead of
    an implicit branch fall in :func:`stamp_linear`."""
    from repro.kernels.stamp_matmul import FUSABLE_TRANSFORMS
    reasons = []
    if not cfg.enabled:
        reasons.append("stamp_disabled")
    if cfg.execution != "fused":
        reasons.append("execution_reference")
    if cfg.granularity != "token":
        # per-block scale plumbing has no kernel treatment yet (ROADMAP)
        reasons.append(f"granularity_{cfg.granularity}")
    if cfg.seq_transform not in FUSABLE_TRANSFORMS:
        # dense O(s²) bases / latent-grid reads don't tile
        reasons.append(f"transform_not_fusable:{cfg.seq_transform}")
    if max(cfg.hi_bits, cfg.lo_bits, cfg.fused_weight_bits) > 8:
        # activation AND weight codes live in int8 storage
        reasons.append("bits_exceed_int8")
    if feature_rot is not None:
        reasons.append("feature_rotation")
    return tuple(reasons)


def fused_eligible(cfg: StampConfig, feature_rot: Optional[Array] = None
                   ) -> bool:
    """Whether this config can run the fused Pallas kernel; anything else
    stays on the reference path — see :func:`fused_ineligibility` for the
    structured per-reason breakdown."""
    return not fused_ineligibility(cfg, feature_rot)


def _fused_linear(x: Array, prep: PreparedLinear, cfg: StampConfig,
                  merge_heads: bool = False) -> Array:
    from repro.kernels import ops as kops
    if merge_heads:
        # raw head-split attention output: keep the (nh, hd) axes intact
        # down to the kernel, which merges them on the in-VMEM tile
        *lead, s, nh, hd = x.shape
        xk = x.reshape(-1, s, nh, hd)
    else:
        *lead, s, d = x.shape
        xk = x.reshape(-1, s, d)
    y = kops.stamp_quant_matmul(
        xk, prep.qw, prep.sw, prep.zw, prep.bias,
        transform=cfg.seq_transform, levels=cfg.resolved_levels(s),
        skip_first=cfg.skip_first_token, num_hi=cfg.num_hi_tokens,
        hi_bits=cfg.hi_bits, lo_bits=cfg.lo_bits, out_dtype=x.dtype)
    return y.reshape(*lead, s, y.shape[-1])


def stamp_linear(
    x: Array,
    w: Optional[Array],
    b: Optional[Array],
    cfg: StampConfig,
    *,
    w_quant: Optional[Q.QuantizedWeight] = None,
    basis: Optional[Array] = None,
    feature_rot: Optional[Array] = None,
    prepared: Optional[PreparedLinear] = None,
    merge_heads: bool = False,
    seg_len: Optional[int] = None,
    site: Optional[str] = None,
) -> Array:
    """STaMP linear layer (Fig. 2a).

    ``feature_rot`` is the feature-transform matrix R applied to the
    activation; callers must pre-fold ``R⁻¹`` into ``w`` (QuaRot-style).
    ``w_quant`` replaces ``w`` with its dequantized int approximation
    (W4 path).  The bias is added *after* the inverse sequence transform,
    which is exact per Eq. 7.

    With ``cfg.execution == "fused"`` (and a fusable transform/granularity)
    the whole chain runs in one Pallas kernel on integer weights: pass
    ``prepared`` (see :func:`prepare_linear`) to reuse cached int8 buffers
    across calls; otherwise they are prepared on the fly from ``w_quant``'s
    codes or ``w``.

    ``merge_heads`` marks ``x`` as the raw head-split attention output
    ``(..., s, nh, hd)`` (out-proj site): the fused kernel merges the head
    axes on its in-VMEM tile, the fallback paths merge up front.

    ``seg_len`` marks ``x`` as a flattened ragged batch of uniform
    ``seg_len``-token spans (the unified serving step): the sequence
    transform and its inverse apply per span — spans fold into the batch
    axis, so the fused kernel sees them as independent grid rows and the
    reference path as independent batch rows.
    """
    if seg_len is not None and x.ndim >= 3 and seg_len != x.shape[1]:
        y = stamp_linear(fold_segments(x, seg_len), w, b, cfg,
                         w_quant=w_quant, basis=basis,
                         feature_rot=feature_rot, prepared=prepared,
                         merge_heads=merge_heads, site=site)
        return unfold_segments(y, x.shape[0])
    if fused_eligible(cfg, feature_rot) and \
            (w_quant is None or w_quant.bits <= 8):
        _record_fused(x, cfg, site, merge_heads=merge_heads)
        prep = prepared
        if prep is None:
            prep = prepare_linear(w, b, w_quant=w_quant,
                                  bits=cfg.fused_weight_bits)
        elif b is not None:
            # explicit bias wins over the prepared one (matches the
            # reference fallback below)
            prep = dataclasses.replace(prep, bias=b)
        return _fused_linear(x, prep, cfg, merge_heads=merge_heads)
    if merge_heads:
        x = x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])

    if w is None and w_quant is None and prepared is not None:
        # reference fallback for a caller that only holds prepared buffers
        w = prepared.dequant(x.dtype)
        b = prepared.bias if b is None else b

    if not cfg.enabled:
        wmat = w_quant.dequant(x.dtype) if w_quant is not None else w
        y = x @ wmat
        return y + b if b is not None else y

    tq = _reference_quantize(x, cfg, basis=basis, feature_rot=feature_rot,
                             site=site)
    wmat = w_quant.dequant(x.dtype) if w_quant is not None else w
    y = tq.astype(x.dtype) @ wmat
    y = invert_seq_transform(y, cfg, basis=basis)
    if b is not None:
        y = y + b
    return y


def _reference_quantize(x: Array, cfg: StampConfig,
                        basis: Optional[Array] = None,
                        feature_rot: Optional[Array] = None,
                        site: Optional[str] = None) -> Array:
    """Reference-path transformed + fake-quantized activation (shared by
    the single and dual linears, so their quantization semantics can't
    diverge)."""
    tx = apply_seq_transform(x.astype(jnp.float32), cfg, basis=basis)
    if feature_rot is not None:
        tx = tx @ feature_rot.astype(tx.dtype)
    bits = cfg.bits_vector(tx.shape[-2])
    QS.record(site, tx, bits, cfg.hi_bits)
    if cfg.granularity == "block":
        return _blockwise_mixed(tx, bits, cfg.block_size)
    return Q.fake_quant(tx, bits, axis=-1)


def _record_fused(x: Array, cfg: StampConfig, site: Optional[str],
                  merge_heads: bool = False) -> None:
    """Quant-health telemetry for the fused path: the kernel fuses
    transform→quantize→GEMM into one program, so the transform and the
    per-token scale statistics are recomputed HERE with plain jnp ops —
    extra FLOPs inside the same traced program, never an extra device
    dispatch (the no-op case costs nothing: collection is off at trace
    time unless the entry point opened a scope)."""
    if not QS.active() or site is None or not cfg.enabled:
        return
    xm = x.reshape(*x.shape[:-2], -1) if merge_heads else x
    tx = apply_seq_transform(xm.astype(jnp.float32), cfg)
    QS.record(site, tx, cfg.bits_vector(tx.shape[-2]), cfg.hi_bits)


def stamp_dual_linear(
    x: Array,
    w_gate: Optional[Array],
    w_up: Optional[Array],
    cfg: StampConfig,
    *,
    b_gate: Optional[Array] = None,
    b_up: Optional[Array] = None,
    basis: Optional[Array] = None,
    prepared_gate: Optional[PreparedLinear] = None,
    prepared_up: Optional[PreparedLinear] = None,
    epilogue: str = "silu_mul",
    seg_len: Optional[int] = None,
    site: Optional[str] = None,
):
    """STaMP gate/up pair sharing ONE transform+quantize of ``x``.

    The fused path issues a single dual-output kernel call
    (`kernels.stamp_matmul.stamp_quant_dual_matmul_pallas`): the sequence
    transform and mixed-precision quantize of the shared MLP input run once
    into VMEM scratch and drive both integer GEMMs.  The reference path
    shares the transformed/fake-quantized activation across two plain
    matmuls — mathematically the same single quantization (``L⁻¹`` commutes
    with the right-multiplication), just unfused.

    ``epilogue="silu_mul"`` returns ``silu(gate)·up`` (the SwiGLU front
    half, combined in the original token domain); ``"none"`` the tuple.
    ``seg_len``: flattened uniform-span ragged batch, transformed per span
    (see :func:`stamp_linear`).
    """
    if epilogue not in ("silu_mul", "none"):
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if seg_len is not None and seg_len != x.shape[1]:
        y = stamp_dual_linear(fold_segments(x, seg_len), w_gate, w_up, cfg,
                              b_gate=b_gate, b_up=b_up, basis=basis,
                              prepared_gate=prepared_gate,
                              prepared_up=prepared_up, epilogue=epilogue,
                              site=site)
        if epilogue == "silu_mul":
            return unfold_segments(y, x.shape[0])
        return tuple(unfold_segments(o, x.shape[0]) for o in y)
    if fused_eligible(cfg):
        _record_fused(x, cfg, site)
        prep_g = prepared_gate if prepared_gate is not None else \
            prepare_linear(w_gate, b_gate, bits=cfg.fused_weight_bits)
        prep_u = prepared_up if prepared_up is not None else \
            prepare_linear(w_up, b_up, bits=cfg.fused_weight_bits)
        from repro.kernels import ops as kops
        *lead, s, d = x.shape
        y = kops.stamp_quant_dual_matmul(
            x.reshape(-1, s, d),
            prep_g.qw, prep_g.sw, prep_g.zw,
            prep_u.qw, prep_u.sw, prep_u.zw,
            prep_g.bias if b_gate is None else b_gate,
            prep_u.bias if b_up is None else b_up,
            transform=cfg.seq_transform, levels=cfg.resolved_levels(s),
            skip_first=cfg.skip_first_token, num_hi=cfg.num_hi_tokens,
            hi_bits=cfg.hi_bits, lo_bits=cfg.lo_bits, epilogue=epilogue,
            out_dtype=x.dtype)
        if epilogue == "silu_mul":
            return y.reshape(*lead, s, y.shape[-1])
        return tuple(o.reshape(*lead, s, o.shape[-1]) for o in y)

    def resolve(w, prep, b):
        if w is None and prep is not None:
            w = prep.dequant(x.dtype)
            b = prep.bias if b is None else b
        return w, b

    w_gate, b_gate = resolve(w_gate, prepared_gate, b_gate)
    w_up, b_up = resolve(w_up, prepared_up, b_up)

    if not cfg.enabled:
        g = x @ w_gate
        u = x @ w_up
    else:
        # one shared reference-path quantization, two matmuls
        tq = _reference_quantize(x, cfg, basis=basis,
                                 site=site).astype(x.dtype)
        g = invert_seq_transform(tq @ w_gate, cfg, basis=basis)
        u = invert_seq_transform(tq @ w_up, cfg, basis=basis)
    if b_gate is not None:
        g = g + b_gate
    if b_up is not None:
        u = u + b_up
    if epilogue == "silu_mul":
        return jax.nn.silu(g) * u
    return g, u

"""STaMP: the sequence-transformed, mixed-precision linear layer (Fig. 2a).

The algorithm for ``y = act_quant(X) @ W + β`` under STaMP:

    1.  ``T = L · X``                      (sequence transform, §3)
    2.  ``T = T · R``                      (optional feature transform;
                                            ``R⁻¹`` is pre-folded into W)
    3.  ``Tq = Q(T)``                       (mixed-precision fake quant,
                                            first ``num_hi`` tokens hi-bit)
    4.  ``Y = Tq · W'``                     (W' = R⁻¹ W, possibly int)
    5.  ``y = L⁻¹ · Y + 1βᵀ``               (inverse transform then bias —
                                            Eq. 7 commutation)

``L`` is never materialized: DWT/DCT/WHT are applied as fast operators.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core import transforms as T

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StampConfig:
    """Configuration for STaMP activation quantization.

    Defaults reproduce the paper's headline setting: Haar DWT, 3 levels,
    64 tokens at 8 bits, rest at 4 bits (avg 4.0625–4.125), first-token
    exception on for LLMs (§B.2).
    """

    seq_transform: str = "dwt"       # none|dwt|dwt2d|dct|wht|klt
    levels: Optional[int] = None     # None = auto: log2(seq / num_hi), so the
                                     # low-pass band aligns with the hi-bit
                                     # token budget (total cost stays O(s·d))
    num_hi_tokens: int = 64
    hi_bits: int = 8
    lo_bits: int = 4
    skip_first_token: bool = True    # attention-sink exception (§B.2)
    granularity: str = "token"       # token | block
    block_size: int = 64
    hw: Optional[tuple[int, int]] = None   # (H, W) grid for dwt2d
    enabled: bool = True

    def bits_vector(self, seq_len: int) -> Array:
        return Q.mixed_precision_bits(seq_len, self.num_hi_tokens,
                                      self.hi_bits, self.lo_bits)

    def resolved_levels(self, seq_len: int) -> int:
        if self.levels is not None:
            return self.levels
        import math
        ratio = max(seq_len / max(self.num_hi_tokens, 1), 2)
        return max(1, int(math.ceil(math.log2(ratio))))

    def average_bits(self, seq_len: int) -> float:
        return Q.average_bits(self.bits_vector(seq_len))


def apply_seq_transform(x: Array, cfg: StampConfig, axis: int = -2,
                        basis: Optional[Array] = None) -> Array:
    if not cfg.enabled or cfg.seq_transform == "none":
        return x
    return T.sequence_transform(
        x, cfg.seq_transform, axis=axis,
        levels=cfg.resolved_levels(x.shape[axis]),
        skip_first=cfg.skip_first_token, hw=cfg.hw, basis=basis)


def invert_seq_transform(y: Array, cfg: StampConfig, axis: int = -2,
                         basis: Optional[Array] = None) -> Array:
    if not cfg.enabled or cfg.seq_transform == "none":
        return y
    return T.inverse_sequence_transform(
        y, cfg.seq_transform, axis=axis,
        levels=cfg.resolved_levels(y.shape[axis]),
        skip_first=cfg.skip_first_token, hw=cfg.hw, basis=basis)


def stamp_fake_quant(x: Array, cfg: StampConfig, axis: int = -2,
                     basis: Optional[Array] = None) -> Array:
    """Full STaMP round trip on an activation: ``L⁻¹ Q(L X)`` — used when a
    consumer needs the activation back in the original domain (e.g. KV-cache
    values feeding non-linear attention math)."""
    if not cfg.enabled:
        return x
    tx = apply_seq_transform(x, cfg, axis=axis, basis=basis)
    bits = cfg.bits_vector(tx.shape[axis])
    if cfg.granularity == "block":
        # per-(token, block) scales — bits stays per-token
        tq = _blockwise_mixed(tx, bits, cfg.block_size)
    else:
        tq = Q.fake_quant(tx, bits, axis=-1)
    return invert_seq_transform(tq, cfg, axis=axis, basis=basis)


def _blockwise_mixed(tx: Array, bits: Array, block_size: int) -> Array:
    *lead, s, d = tx.shape
    if d % block_size:
        return Q.fake_quant(tx, bits, axis=-1)
    xb = tx.reshape(*lead, s, d // block_size, block_size)
    bitsb = bits[:, None]  # per-token bits broadcast over feature blocks
    n = 2.0 ** bitsb - 1.0
    mn = jnp.min(xb, axis=-1, keepdims=True)
    mx = jnp.max(xb, axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / n[..., None], 1e-8)
    zp = jnp.round(-mn / scale)
    q = jnp.clip(jnp.round(xb / scale) + zp, 0.0, n[..., None])
    deq = ((q - zp) * scale).astype(tx.dtype)
    return deq.reshape(*lead, s, d)


def stamp_linear(
    x: Array,
    w: Array,
    b: Optional[Array],
    cfg: StampConfig,
    *,
    w_quant: Optional[Q.QuantizedWeight] = None,
    basis: Optional[Array] = None,
    feature_rot: Optional[Array] = None,
) -> Array:
    """STaMP linear layer (Fig. 2a).

    ``feature_rot`` is the feature-transform matrix R applied to the
    activation; callers must pre-fold ``R⁻¹`` into ``w`` (QuaRot-style).
    ``w_quant`` replaces ``w`` with its dequantized int approximation
    (W4 path).  The bias is added *after* the inverse sequence transform,
    which is exact per Eq. 7.
    """
    if not cfg.enabled:
        wmat = w_quant.dequant(x.dtype) if w_quant is not None else w
        y = x @ wmat
        return y + b if b is not None else y

    tx = apply_seq_transform(x, cfg, basis=basis)
    if feature_rot is not None:
        tx = tx @ feature_rot.astype(tx.dtype)
    bits = cfg.bits_vector(tx.shape[-2])
    if cfg.granularity == "block":
        tq = _blockwise_mixed(tx, bits, cfg.block_size)
    else:
        tq = Q.fake_quant(tx, bits, axis=-1)
    wmat = w_quant.dequant(x.dtype) if w_quant is not None else w
    y = tq @ wmat
    y = invert_seq_transform(y, cfg, basis=basis)
    if b is not None:
        y = y + b
    return y

"""The end-to-end PTQ pipeline: calibrate → allocate → quantize → serve.

Mirrors the paper's procedure (§5, B.1–B.2):

1. run calibration batches, capturing block-input activations;
2. estimate sequence autocorrelation / transformed-token energies per site
   and verify the Toeplitz premise (``toeplitz_fraction``);
3. pick the number of high-precision tokens for the bit budget (greedy
   two-level scheme — the paper fixes 64; we derive it and report both);
4. RTN-quantize the weights with min-max range search (B.2);
5. emit a ``ServeConfig`` + packed weights for the serving engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitalloc
from repro.core.calibration import SiteStats, toeplitz_fraction
from repro.core.stamp import StampConfig
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.kvcache import KVCacheConfig


@dataclasses.dataclass
class PTQReport:
    num_hi: int
    avg_bits: float
    toeplitz_fraction: float
    energy_head_fraction: float     # energy in the first num_hi tokens
    sites: int


def capture_block_inputs(params, batch: dict, cfg: ModelConfig,
                         max_blocks: int = 4):
    """Forward pass collecting the residual-stream input of the first
    ``max_blocks`` scan periods (the quantization sites' common input)."""
    taps = []

    x, _, _ = lm.model_hidden(params, batch, cfg, mode="train", policy=None,
                              remat=False)
    # cheap proxy: tap the embedding output and final hidden — the
    # autocorrelation structure is driven by the data's locality and is
    # stable across depth (paper Fig. 3 shows layer 15/20 look alike).
    emb = lm._embed(params, batch["tokens"])
    taps.append(np.asarray(emb, np.float32))
    taps.append(np.asarray(x, np.float32))
    return taps


def calibrate_and_quantize(
    params,
    calib_batches: list,
    cfg: ModelConfig,
    *,
    avg_budget: float = 4.125,
    hi_bits: int = 8,
    lo_bits: int = 4,
    transform: str = "dwt",
    levels: int = 3,
    weight_bits: Optional[int] = 4,
) -> tuple[dict, lm.ServeConfig, PTQReport]:
    stats: Optional[SiteStats] = None
    for batch in calib_batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        for tap in capture_block_inputs(params, b, cfg):
            if stats is None:
                stats = SiteStats.empty(tap.shape[-2], tap.shape[-1])
            stats.update(tap)
    assert stats is not None, "no calibration data"

    tf = toeplitz_fraction(stats.autocorr)
    energies = stats.energy_profile(transform, levels=levels)
    order = np.sort(energies)[::-1]
    num_hi = bitalloc.greedy_two_level(order, avg_budget, hi=hi_bits,
                                       lo=lo_bits)
    num_hi = max(1, min(num_hi, 64))   # paper uses 64; budget may allow less
    head_frac = float(order[:num_hi].sum() / max(order.sum(), 1e-9))

    stamp = StampConfig(seq_transform=transform, levels=levels,
                        num_hi_tokens=num_hi, hi_bits=hi_bits,
                        lo_bits=lo_bits, skip_first_token=True)
    serve = lm.ServeConfig(
        stamp=stamp,
        kv=KVCacheConfig(quantized=True, num_hi=num_hi,
                         hi_bits=hi_bits, lo_bits=lo_bits),
        weight_bits=weight_bits)
    sparams = params
    if weight_bits:
        sparams = lm.quantize_weights_for_serving(
            jax.tree.map(lambda a: jnp.asarray(a, jnp.bfloat16)
                         if a.dtype == jnp.float32 else a, params),
            weight_bits)
    seq = stats.autocorr.shape[0]
    report = PTQReport(
        num_hi=num_hi,
        avg_bits=float((num_hi * hi_bits + (seq - num_hi) * lo_bits) / seq),
        toeplitz_fraction=tf,
        energy_head_fraction=head_frac,
        sites=2)
    return sparams, serve, report

"""Orthogonal sequence transforms (paper §3, §3.2).

All transforms act along an arbitrary ``axis`` (default ``-2``, the sequence
axis of ``(..., s, d)`` activations) and are exactly orthonormal, so
``inverse(forward(x)) == x`` and the Frobenius norm is preserved (the premise
of Theorem 1 / Eq. 10).

Implemented bases, in the paper's cost order:

* **KLT** — eigenbasis of the sequence autocorrelation ``S = E[XXᵀ]``
  (optimal energy compaction; needs calibration; O(s²) apply).
* **DCT-II** (orthonormal) — near-KLT for Toeplitz autocorrelation (Szegő);
  O(s²) as a matrix here, O(s log s) on device via the Pallas/FFT path.
* **WHT** — sign-only Fourier approximation; O(s log s) butterfly.
* **Haar DWT** — O(s) lifting; ``levels`` passes halve the low-pass band each
  time, concentrating energy in the first ``s / 2^levels`` tokens with
  *discrete* energy levels (§3.3 argues this suits 2-level mixed precision).

Non-power-of-two lengths: WHT/DWT operate on the largest admissible prefix at
each stage and pass the remainder through untouched — the resulting operator
is block-diagonal with an identity block, hence still orthonormal.  This also
implements the paper's first-token exception (§B.2) via ``skip_first``:
``L = blockdiag(I₁, L')`` keeps the attention-sink token unmixed.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_SQRT2 = float(np.sqrt(2.0))


# ---------------------------------------------------------------------------
# axis helpers
# ---------------------------------------------------------------------------


def _moveaxis_last(x: Array, axis: int) -> tuple[Array, int]:
    axis = axis % x.ndim
    return jnp.moveaxis(x, axis, -1), axis


def _restore_axis(x: Array, axis: int) -> Array:
    return jnp.moveaxis(x, -1, axis)


# ---------------------------------------------------------------------------
# Haar DWT (lifting form, orthonormal)
# ---------------------------------------------------------------------------


def _haar_level(x: Array) -> Array:
    """One orthonormal Haar pass along the last axis.

    Odd tail elements are passed through (identity block) to keep the
    operator square and orthonormal for any length.
    """
    n = x.shape[-1]
    pairs = n // 2
    even = x[..., : 2 * pairs : 2]
    odd = x[..., 1 : 2 * pairs : 2]
    approx = (even + odd) / _SQRT2
    detail = (even - odd) / _SQRT2
    out = jnp.concatenate([approx, detail], axis=-1)
    if n % 2:
        out = jnp.concatenate([out, x[..., -1:]], axis=-1)
    return out


def _haar_level_inv(y: Array) -> Array:
    n = y.shape[-1]
    pairs = n // 2
    approx = y[..., :pairs]
    detail = y[..., pairs : 2 * pairs]
    even = (approx + detail) / _SQRT2
    odd = (approx - detail) / _SQRT2
    out = jnp.stack([even, odd], axis=-1).reshape(*y.shape[:-1], 2 * pairs)
    if n % 2:
        out = jnp.concatenate([out, y[..., -1:]], axis=-1)
    return out


def haar_dwt(x: Array, levels: int = 3, axis: int = -2,
             skip_first: bool = False) -> Array:
    """Multi-level Haar DWT along ``axis``.

    After each level only the low-pass (first) half is transformed again, so
    energy accumulates in the leading ``s / 2^levels`` coefficients.
    """
    x, axis = _moveaxis_last(x, axis)
    if skip_first:
        head, x0 = x[..., :1], x[..., 1:]
    else:
        head, x0 = None, x
    n = x0.shape[-1]
    lo = n
    out = x0
    for _ in range(levels):
        if lo < 2:
            break
        low = _haar_level(out[..., :lo])
        out = jnp.concatenate([low, out[..., lo:]], axis=-1)
        lo = (lo + 1) // 2 if lo % 2 else lo // 2
    if head is not None:
        out = jnp.concatenate([head, out], axis=-1)
    return _restore_axis(out, axis)


def haar_idwt(y: Array, levels: int = 3, axis: int = -2,
              skip_first: bool = False) -> Array:
    """Inverse of :func:`haar_dwt` (same ``levels``/``skip_first``)."""
    y, axis = _moveaxis_last(y, axis)
    if skip_first:
        head, y0 = y[..., :1], y[..., 1:]
    else:
        head, y0 = None, y
    n = y0.shape[-1]
    # reconstruct the sequence of low-pass band sizes used by the forward
    sizes = [n]
    lo = n
    for _ in range(levels):
        if lo < 2:
            break
        lo = (lo + 1) // 2 if lo % 2 else lo // 2
        sizes.append(lo)
    out = y0
    for lo_prev, lo in zip(sizes[-1:0:-1], sizes[-2::-1]):
        low = _haar_level_inv(out[..., :lo])
        out = jnp.concatenate([low, out[..., lo:]], axis=-1)
    if head is not None:
        out = jnp.concatenate([head, out], axis=-1)
    return _restore_axis(out, axis)


@functools.lru_cache(maxsize=32)
def _subband_order(h: int, w: int, levels: int) -> np.ndarray:
    """Permutation putting the final LL quadrant first, then per-level detail
    subbands — so 'first k tokens' aligns with descending energy.  The
    permutation is orthogonal, so Theorem 1's preconditions still hold."""
    lh, lw = h, w
    sizes = []
    for _ in range(levels):
        if lh < 2 or lw < 2:
            break
        sizes.append((lh, lw))
        lh, lw = lh // 2, lw // 2
    grid = np.arange(h * w).reshape(h, w)
    order = [grid[:lh, :lw].ravel()]          # LL_L first
    for ph, pw in sizes[::-1]:                # coarsest detail bands first
        hh, hw_ = ph // 2, pw // 2
        order.append(grid[:hh, hw_:pw].ravel())    # LH
        order.append(grid[hh:ph, :hw_].ravel())    # HL
        order.append(grid[hh:ph, hw_:pw].ravel())  # HH
    return np.concatenate(order)


def haar_dwt_2d(x: Array, hw: tuple[int, int], levels: int = 3,
                axis: int = -2) -> Array:
    """2-D Haar DWT for LVM activations whose sequence axis flattens an
    ``H × W`` latent grid (paper §5.1 uses 2-D DWT; the block-Toeplitz
    autocorrelation of Fig. 3a comes from exactly this flattening).

    Each level transforms rows then columns of the current low-pass quadrant,
    pushing energy into the top-left ``(H/2ˡ, W/2ˡ)`` corner; the output is
    read out in subband order (LL first) so high-energy coefficients lead the
    sequence.
    """
    h, w = hw
    x, axis = _moveaxis_last(x, axis)
    if x.shape[-1] != h * w:
        raise ValueError(f"sequence {x.shape[-1]} != H*W {h * w}")
    img = x.reshape(*x.shape[:-1], h, w)
    lh, lw = h, w
    for _ in range(levels):
        if lh < 2 or lw < 2:
            break
        quad = img[..., :lh, :lw]
        quad = _haar_level(quad)                      # rows (last axis = W)
        quad = jnp.swapaxes(_haar_level(jnp.swapaxes(quad, -1, -2)), -1, -2)
        img = img.at[..., :lh, :lw].set(quad)
        lh, lw = lh // 2, lw // 2
    out = img.reshape(*x.shape[:-1], h * w)
    perm = jnp.asarray(_subband_order(h, w, levels))
    out = jnp.take(out, perm, axis=-1)
    return _restore_axis(out, axis)


def haar_idwt_2d(y: Array, hw: tuple[int, int], levels: int = 3,
                 axis: int = -2) -> Array:
    h, w = hw
    y, axis = _moveaxis_last(y, axis)
    perm = _subband_order(h, w, levels)
    inv_perm = jnp.asarray(np.argsort(perm))
    y = jnp.take(y, inv_perm, axis=-1)
    img = y.reshape(*y.shape[:-1], h, w)
    sizes = []
    lh, lw = h, w
    for _ in range(levels):
        if lh < 2 or lw < 2:
            break
        sizes.append((lh, lw))
        lh, lw = lh // 2, lw // 2
    for lh, lw in reversed(sizes):
        quad = img[..., :lh, :lw]
        quad = jnp.swapaxes(_haar_level_inv(jnp.swapaxes(quad, -1, -2)), -1, -2)
        quad = _haar_level_inv(quad)
        img = img.at[..., :lh, :lw].set(quad)
    out = img.reshape(*y.shape[:-1], h * w)
    return _restore_axis(out, axis)


# ---------------------------------------------------------------------------
# DCT-II (orthonormal)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def dct_matrix(n: int) -> np.ndarray:
    """Orthonormal DCT-II basis, rows = basis vectors (row 0 = DC)."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * k * (2 * i + 1) / (2 * n))
    m[0] *= np.sqrt(1.0 / n)
    m[1:] *= np.sqrt(2.0 / n)
    return m.astype(np.float32)


def dct(x: Array, axis: int = -2, skip_first: bool = False) -> Array:
    x, axis = _moveaxis_last(x, axis)
    if skip_first:
        head, x0 = x[..., :1], x[..., 1:]
    else:
        head, x0 = None, x
    m = jnp.asarray(dct_matrix(x0.shape[-1]), x0.dtype)
    out = jnp.einsum("...i,ki->...k", x0, m)
    if head is not None:
        out = jnp.concatenate([head, out], axis=-1)
    return _restore_axis(out, axis)


def idct(y: Array, axis: int = -2, skip_first: bool = False) -> Array:
    y, axis = _moveaxis_last(y, axis)
    if skip_first:
        head, y0 = y[..., :1], y[..., 1:]
    else:
        head, y0 = None, y
    m = jnp.asarray(dct_matrix(y0.shape[-1]), y0.dtype)
    out = jnp.einsum("...k,ki->...i", y0, m)
    if head is not None:
        out = jnp.concatenate([head, out], axis=-1)
    return _restore_axis(out, axis)


# ---------------------------------------------------------------------------
# Walsh–Hadamard (fast butterfly, orthonormal, pow2 prefix)
# ---------------------------------------------------------------------------


def _largest_pow2(n: int) -> int:
    return 1 << (n.bit_length() - 1) if n else 0


def wht(x: Array, axis: int = -2, skip_first: bool = False) -> Array:
    """Fast Walsh–Hadamard transform, O(s log s) butterfly (§3.2: retain the
    sign of the Fourier coefficients).  Operates on the largest power-of-two
    prefix; the remainder passes through (identity block)."""
    x, axis = _moveaxis_last(x, axis)
    if skip_first:
        head, x0 = x[..., :1], x[..., 1:]
    else:
        head, x0 = None, x
    n = x0.shape[-1]
    p = _largest_pow2(n)
    body, tail = x0[..., :p], x0[..., p:]
    h = 1
    while h < p:
        shaped = body.reshape(*body.shape[:-1], p // (2 * h), 2, h)
        a = shaped[..., 0, :]
        b = shaped[..., 1, :]
        shaped = jnp.stack([a + b, a - b], axis=-2)
        body = shaped.reshape(*body.shape[:-1], p)
        h *= 2
    body = body / float(np.sqrt(p))
    out = jnp.concatenate([body, tail], axis=-1) if tail.shape[-1] else body
    if head is not None:
        out = jnp.concatenate([head, out], axis=-1)
    return _restore_axis(out, axis)


# orthonormal WHT is involutive on the pow2 block
def iwht(y: Array, axis: int = -2, skip_first: bool = False) -> Array:
    return wht(y, axis=axis, skip_first=skip_first)


# ---------------------------------------------------------------------------
# KLT (calibrated eigenbasis)
# ---------------------------------------------------------------------------


def klt_basis(autocorr: np.ndarray) -> np.ndarray:
    """Rows = eigenvectors of S sorted by descending eigenvalue (§3.2: the
    optimal L is Uᵀ).  ``autocorr`` must be (s, s) symmetric."""
    s = np.asarray(autocorr, np.float64)
    s = (s + s.T) / 2
    vals, vecs = np.linalg.eigh(s)
    order = np.argsort(vals)[::-1]
    return vecs[:, order].T.astype(np.float32)


def apply_matrix(x: Array, m: Array, axis: int = -2,
                 inverse: bool = False) -> Array:
    """Apply an orthonormal basis ``m`` (rows = basis vectors) along
    ``axis``; ``inverse=True`` applies ``mᵀ``."""
    x, axis = _moveaxis_last(x, axis)
    m = jnp.asarray(m, x.dtype)
    eq = "...i,ki->...k" if not inverse else "...k,ki->...i"
    out = jnp.einsum(eq, x, m)
    return _restore_axis(out, axis)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def sequence_transform(
    x: Array,
    kind: str,
    axis: int = -2,
    levels: int = 3,
    skip_first: bool = False,
    hw: Optional[tuple[int, int]] = None,
    basis: Optional[Array] = None,
) -> Array:
    """Dispatch on the paper's transform family names."""
    if kind in ("none", "identity"):
        return x
    if kind == "dwt":
        return haar_dwt(x, levels=levels, axis=axis, skip_first=skip_first)
    if kind == "dwt2d":
        assert hw is not None, "dwt2d needs the (H, W) latent grid"
        return haar_dwt_2d(x, hw, levels=levels, axis=axis)
    if kind == "dct":
        return dct(x, axis=axis, skip_first=skip_first)
    if kind == "wht":
        return wht(x, axis=axis, skip_first=skip_first)
    if kind == "klt":
        assert basis is not None, "klt needs a calibrated basis"
        return apply_matrix(x, basis, axis=axis)
    raise ValueError(f"unknown sequence transform {kind!r}")


def inverse_sequence_transform(
    y: Array,
    kind: str,
    axis: int = -2,
    levels: int = 3,
    skip_first: bool = False,
    hw: Optional[tuple[int, int]] = None,
    basis: Optional[Array] = None,
) -> Array:
    if kind in ("none", "identity"):
        return y
    if kind == "dwt":
        return haar_idwt(y, levels=levels, axis=axis, skip_first=skip_first)
    if kind == "dwt2d":
        assert hw is not None
        return haar_idwt_2d(y, hw, levels=levels, axis=axis)
    if kind == "dct":
        return idct(y, axis=axis, skip_first=skip_first)
    if kind == "wht":
        return iwht(y, axis=axis, skip_first=skip_first)
    if kind == "klt":
        assert basis is not None
        return apply_matrix(y, basis, axis=axis, inverse=True)
    raise ValueError(f"unknown sequence transform {kind!r}")

"""Bit-width allocation (paper §3.3 and Appendix A.2/A.3).

Given per-token energies ``e`` of the *transformed* activations, the optimal
real-valued allocation for a total budget of ``B`` bits is

    b_i* = log2 sqrt(e_i) + (B − Σ log2 sqrt(e_i)) / s        (Eq. 18)

Hardware restricts us to a small set of integer widths, so STaMP's practical
scheme is two-level: first ``num_hi`` tokens at ``hi`` bits, remainder at
``lo`` bits (Fig. 4a, yellow).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_EPS = 1e-12


def optimal_bits(energies: Array, total_bits: float) -> Array:
    """Eq. 18 — water-filling style log-energy allocation (real-valued)."""
    e = jnp.maximum(jnp.asarray(energies, jnp.float32), _EPS)
    log_sqrt_e = 0.5 * jnp.log2(e)
    s = e.shape[-1]
    c = (total_bits - jnp.sum(log_sqrt_e, axis=-1, keepdims=True)) / s
    return log_sqrt_e + c


def bound_value(energies: Array, bits: Array, d: int) -> Array:
    """Theorem-1 upper bound ``d/2 · Σ e_i / (2^{b_i} − 1)²`` for a given
    allocation (used to compare schemes, Fig. 2b)."""
    e = jnp.asarray(energies, jnp.float32)
    denom = (2.0 ** jnp.asarray(bits, jnp.float32) - 1.0) ** 2
    return 0.5 * d * jnp.sum(e / jnp.maximum(denom, _EPS), axis=-1)


def two_level_bits(seq_len: int, num_hi: int, hi: int = 8, lo: int = 4) -> Array:
    """STaMP's practical two-precision vector."""
    idx = jnp.arange(seq_len)
    return jnp.where(idx < num_hi, float(hi), float(lo))


def greedy_two_level(
    energies: np.ndarray,
    avg_budget: float,
    hi: int = 8,
    lo: int = 4,
) -> int:
    """Pick the largest ``num_hi`` (tokens at ``hi`` bits) whose average bit
    width stays within ``avg_budget``; assumes energies are already sorted
    descending (true after DWT/DCT/KLT reordering)."""
    s = len(energies)
    max_hi = int(np.floor(s * (avg_budget - lo) / (hi - lo)))
    return int(np.clip(max_hi, 0, s))


def integer_rounded_allocation(
    energies: np.ndarray,
    total_bits: int,
    min_bits: int = 2,
    max_bits: int = 8,
) -> np.ndarray:
    """Round Eq. 18 to integers with a greedy budget repair: floor, then give
    leftover bits to the tokens with the largest marginal bound reduction.

    Marginal gain of b→b+1 for token i is e_i (1/(2^b−1)² − 1/(2^{b+1}−1)²),
    monotone in e_i / (2^b−1)², so a heap-free argmax loop is exact.
    """
    e = np.maximum(np.asarray(energies, np.float64), _EPS)
    b_star = np.asarray(optimal_bits(jnp.asarray(e), float(total_bits)))
    b = np.clip(np.floor(b_star), min_bits, max_bits).astype(np.int64)
    budget = total_bits - int(b.sum())
    gain = e / (2.0 ** b - 1) ** 2
    while budget > 0:
        i = int(np.argmax(np.where(b < max_bits, gain, -np.inf)))
        if not np.isfinite(gain[i]):
            break
        b[i] += 1
        budget -= 1
        gain[i] = e[i] / (2.0 ** b[i] - 1) ** 2
    while budget < 0:
        i = int(np.argmin(np.where(b > min_bits, gain, np.inf)))
        b[i] -= 1
        budget += 1
        gain[i] = e[i] / (2.0 ** b[i] - 1) ** 2
    return b

"""Integer activation/weight quantization (paper §2.1, Eq. 1).

Conventions
-----------
Activations are ``(..., s, d)`` — sequence axis ``-2``, feature axis ``-1``.
Per-token quantization shares scale/offset across the feature axis (the
paper's ``s_ij = s_i``); per-block shares them across feature blocks of size
``block_size`` (SVDQuant-style, Table 1 uses block 64).

``bits`` may be a scalar or a per-token array broadcastable against the
sequence axis — this is how STaMP's mixed precision is expressed: the same
vectorized quantizer evaluates 8-bit head tokens and 4-bit tail tokens in one
pass (Eq. 1 with ``b_ij = b_i``).

All fake-quant paths are differentiable via a straight-through estimator so
that calibration-time learned transforms (FlatQuant-lite) can backprop
through them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

Array = jax.Array
Bits = Union[int, Array]

_EPS = 1e-8


@jax.custom_jvp
def _round_ste(x: Array) -> Array:
    """Round-to-nearest-even with straight-through gradient."""
    return jnp.round(x)


@_round_ste.defjvp
def _round_ste_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jnp.round(x), t


def _levels(bits: Bits) -> Array:
    """Number of representable steps ``2**b - 1`` (float, supports arrays)."""
    return jnp.asarray(2.0, jnp.float32) ** jnp.asarray(bits, jnp.float32) - 1.0


def minmax_scale_offset(
    x: Array,
    bits: Bits,
    axis: int = -1,
) -> tuple[Array, Array]:
    """Asymmetric min-max scale & zero point (no clipping error, §2.1).

    Returns ``(scale, zero_point)`` with the reduced ``axis`` kept so the
    result broadcasts against ``x``.  ``scale = range / (2^b - 1)`` (the paper
    writes its reciprocal; we store the dequant step size).
    """
    xf = x.astype(jnp.float32)
    mn = jnp.min(xf, axis=axis, keepdims=True)
    mx = jnp.max(xf, axis=axis, keepdims=True)
    n = _levels(bits)
    if isinstance(bits, Array) and bits.ndim:
        # per-token bit vector: align with the sequence axis of the kept-dims
        # shape, i.e. bits has shape (s,) and scale has shape (..., s, 1).
        n = _align_token_axis(n, mn.ndim, axis)
    scale = (mx - mn) / n
    scale = jnp.maximum(scale, _EPS)
    zero_point = _round_ste(-mn / scale)
    return scale, zero_point


def _align_token_axis(v: Array, ndim: int, reduced_axis: int) -> Array:
    """Reshape a per-token vector ``(s,)`` for broadcast against a keepdims
    tensor of rank ``ndim`` whose ``reduced_axis`` was the feature axis."""
    reduced_axis = reduced_axis % ndim
    token_axis = reduced_axis - 1  # sequence axis sits just before features
    shape = [1] * ndim
    shape[token_axis] = v.shape[0]
    return v.reshape(shape)


def quantize(x: Array, scale: Array, zero_point: Array, bits: Bits) -> Array:
    """Eq. 1: ``clamp(round(x / s) + z, 0, 2^b - 1)`` (kept in float for
    differentiability; see :func:`to_int` for the storage cast)."""
    n = _levels(bits)
    if isinstance(bits, Array) and bits.ndim:
        n = _align_token_axis(n, x.ndim, -1)
    q = _round_ste(x.astype(jnp.float32) / scale) + zero_point
    return jnp.clip(q, 0.0, n)


def dequantize(q: Array, scale: Array, zero_point: Array) -> Array:
    """``(q - z) * s`` (§2.1)."""
    return (q - zero_point) * scale


def to_int(q: Array, bits: int) -> Array:
    """Cast a float-held quantized tensor to its integer storage dtype."""
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return q.astype(dtype)


def fake_quant(
    x: Array,
    bits: Bits,
    axis: int = -1,
    out_dtype: Optional[jnp.dtype] = None,
) -> Array:
    """Quantize-dequantize ``Q(x) = Q⁻¹(Q(x))`` with per-``axis``-reduced
    min-max scales.  ``bits`` may be a per-token vector for mixed precision."""
    scale, zp = minmax_scale_offset(x, bits, axis=axis)
    q = quantize(x, scale, zp, bits)
    out = dequantize(q, scale, zp)
    return out.astype(out_dtype or x.dtype)


def fake_quant_per_block(
    x: Array,
    bits: Bits,
    block_size: int,
    out_dtype: Optional[jnp.dtype] = None,
) -> Array:
    """Per-(token, feature-block) quantization (SVDQuant setting, Table 1).

    The feature axis is split into ``d // block_size`` groups, each with its
    own min-max scale.  ``d`` must be divisible by ``block_size``.
    """
    *lead, d = x.shape
    if d % block_size:
        raise ValueError(f"feature dim {d} not divisible by block {block_size}")
    xb = x.reshape(*lead, d // block_size, block_size)
    out = fake_quant(xb, bits, axis=-1, out_dtype=out_dtype)
    return out.reshape(*lead, d)


def mixed_precision_bits(
    seq_len: int,
    num_hi: int,
    hi_bits: int = 8,
    lo_bits: int = 4,
) -> Array:
    """STaMP's two-level bit vector: first ``num_hi`` tokens at ``hi_bits``,
    the rest at ``lo_bits`` (§3.3, Fig. 4a 'yellow' scheme)."""
    idx = jnp.arange(seq_len)
    return jnp.where(idx < num_hi, hi_bits, lo_bits).astype(jnp.float32)


def average_bits(bits: Array) -> float:
    """Effective average bit width of an allocation (e.g. 4.125 for
    64×8b + 1984×4b)."""
    return float(jnp.mean(jnp.asarray(bits, jnp.float32)))


# ---------------------------------------------------------------------------
# Weight quantization (RTN with clip-range search, paper §B.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """Integer weight + affine dequant params (per output channel or block)."""

    q: Array          # int8 storage (int4 values occupy [0, 15])
    scale: Array      # float32, broadcastable against q
    zero_point: Array
    bits: int

    def dequant(self, dtype=jnp.bfloat16) -> Array:
        return dequantize(self.q.astype(jnp.float32), self.scale,
                          self.zero_point).astype(dtype)


def rtn_quantize_weight(
    w: Array,
    bits: int = 4,
    axis: int = 0,
    num_candidates: int = 17,
    min_shrink: float = 0.6,
) -> QuantizedWeight:
    """Round-to-nearest weight quantization with min-max *range search*.

    The paper (§B.2): "we range set the weights by computing the weight
    quantization squared error for a grid of candidate ranges and selecting
    the candidate with lowest error".  We shrink the min-max range by factors
    in ``[min_shrink, 1.0]`` and keep the per-channel argmin.  ``axis`` is the
    reduction axis (input-feature axis for per-output-channel scales).
    """
    wf = w.astype(jnp.float32)
    mn = jnp.min(wf, axis=axis, keepdims=True)
    mx = jnp.max(wf, axis=axis, keepdims=True)
    n = float(2**bits - 1)

    def err_for(shrink):
        smn, smx = mn * shrink, mx * shrink
        scale = jnp.maximum((smx - smn) / n, _EPS)
        zp = jnp.round(-smn / scale)
        q = jnp.clip(jnp.round(wf / scale) + zp, 0.0, n)
        deq = (q - zp) * scale
        err = jnp.sum((deq - wf) ** 2, axis=axis, keepdims=True)
        return err, (scale, zp)

    shrinks = jnp.linspace(min_shrink, 1.0, num_candidates)
    errs, (scales, zps) = jax.vmap(err_for)(shrinks)
    best = jnp.argmin(errs, axis=0)
    scale = jnp.take_along_axis(scales, best[None], axis=0)[0]
    zp = jnp.take_along_axis(zps, best[None], axis=0)[0]
    q = jnp.clip(jnp.round(wf / scale) + zp, 0.0, n)
    return QuantizedWeight(q=q.astype(jnp.int8), scale=scale, zero_point=zp,
                           bits=bits)


def quant_error(x: Array, q: Array) -> Array:
    """Expected squared quantization error ``E‖Q(x) − x‖²`` (Eq. 2)."""
    d = (q.astype(jnp.float32) - x.astype(jnp.float32))
    return jnp.sum(d * d)


def sqnr_db(orig: Array, quant: Array) -> Array:
    """Signal-to-quantized-noise ratio in dB (§5.1)."""
    orig = orig.astype(jnp.float32)
    noise = orig - quant.astype(jnp.float32)
    num = jnp.sum(orig**2)
    den = jnp.maximum(jnp.sum(noise**2), _EPS)
    return 10.0 * jnp.log10(num / den)

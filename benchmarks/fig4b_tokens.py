"""Figure 4b — bit-width ↔ SQNR trade-off vs number of high-precision
tokens (activation quantization only, DWT sequence transform)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lvm_activations, timed
from repro.core import quant as Q
from repro.core.stamp import StampConfig, stamp_fake_quant


def run() -> list[dict]:
    hw = (32, 32)
    x = lvm_activations(batch=4, hw=hw, d=128, seed=0)
    s = hw[0] * hw[1]
    rows = []
    # uniform baselines at increasing bit widths
    for bits in (4, 5, 6):
        q = Q.fake_quant(x, float(bits), axis=-1)
        rows.append({"name": f"fig4b/uniform_a{bits}", "us_per_call": 0.0,
                     "derived": f"avg_bits={bits:.3f},"
                                f"sqnr_db={float(Q.sqnr_db(x, q)):.2f}"})
    # STaMP with growing high-precision budgets
    for num_hi in (0, 16, 64, 128, 256):
        cfg = StampConfig(seq_transform="dwt2d", levels=3, hw=hw,
                          num_hi_tokens=num_hi, skip_first_token=False)
        us, q = timed(lambda: stamp_fake_quant(x, cfg))
        avg = cfg.average_bits(s)
        rows.append({"name": f"fig4b/stamp_hi{num_hi}", "us_per_call": us,
                     "derived": f"avg_bits={avg:.3f},"
                                f"sqnr_db={float(Q.sqnr_db(x, q)):.2f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

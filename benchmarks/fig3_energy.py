"""Figure 3 — autocorrelation structure and transformed-token energy.

Validates §3.2's chain of reasoning on this framework's own trained-model
activations: (a) the sequence autocorrelation is ≈Toeplitz, (b) the KLT
eigenbasis concentrates energy optimally, (c) DCT approximates KLT
(Szegő), (d) DWT concentrates into discrete levels good enough for
two-level mixed precision.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.calibration import SiteStats, toeplitz_fraction
from repro.data.pipeline import ar_features


def run() -> list[dict]:
    s, d = 256, 64
    x = ar_features((16, s, d), rho=0.95, seed=0)
    stats = SiteStats.empty(s, d)
    stats.update(jnp.asarray(x))

    rows = [{
        "name": "fig3/toeplitz_fraction",
        "us_per_call": 0.0,
        "derived": f"fraction={toeplitz_fraction(stats.autocorr):.4f}",
    }]
    budgets = (8, 32, 64)
    for kind in ("klt", "dct", "wht", "dwt"):
        e = np.sort(stats.energy_profile(kind, levels=5))[::-1]
        fr = {k: float(e[:k].sum() / e.sum()) for k in budgets}
        rows.append({
            "name": f"fig3/energy_{kind}",
            "us_per_call": 0.0,
            "derived": ",".join(f"top{k}={fr[k]:.3f}" for k in budgets),
        })
    # uniform reference
    rows.append({"name": "fig3/energy_uniform", "us_per_call": 0.0,
                 "derived": ",".join(f"top{k}={k/s:.3f}" for k in budgets)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

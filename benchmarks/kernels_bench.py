"""Kernel micro-benchmarks (interpret mode on CPU — correctness +
derived TPU traffic estimates; wall times are NOT TPU latencies)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 1024, 256)).astype(np.float32))
    rows = []

    us, _ = timed(lambda: ops.haar_dwt_seq(x, levels=4, interpret=True), reps=2)
    hbm = 2 * x.size * 4
    rows.append({"name": "kernels/haar_dwt_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.walsh_hadamard(x, axis=-2, interpret=True), reps=2)
    rows.append({"name": "kernels/wht_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.quantize_pack(x, bits=4, interpret=True), reps=2)
    rows.append({"name": "kernels/quant_pack_int4", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={int(x.size * 4.5)}"})

    m = k = n = 256
    qx = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    qw = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    ones = jnp.ones((m, 1), jnp.float32)
    onesn = jnp.ones((1, n), jnp.float32)
    us, _ = timed(lambda: ops.int8_matmul(qx, qw, ones, ones, onesn, onesn,
                                          interpret=True), reps=2)
    rows.append({"name": "kernels/int8_matmul_256", "us_per_call": us,
                 "derived": f"tpu_int_macs={2 * m * n * k}"})
    rows.extend(_stamp_linear_rows(rng))
    return rows


def _stamp_linear_rows(rng) -> list[dict]:
    """Fused vs reference STaMP linear (prefill hot path).

    Derived HBM traffic per linear for a (s, din) activation and (din, dout)
    weight, f32 accounting:

    * reference — four activation round trips: transform out+in, fake-quant
      out+in, matmul out+in, inverse write, plus the bf16 weight
      re-materialized from int codes every call;
    * fused — exactly one: read X once, write Y once, stream the int8 weight.
    """
    import dataclasses

    from repro.core.quant import rtn_quantize_weight
    from repro.core.stamp import StampConfig, prepare_linear, stamp_linear

    s, din, dout = 1024, 256, 256
    x = jnp.asarray(rng.normal(size=(1, s, din)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(din, dout)).astype(np.float32) * 0.05)
    cfg_ref = StampConfig(num_hi_tokens=64)
    cfg_fused = dataclasses.replace(cfg_ref, execution="fused")
    # both rows deploy the same int8 weight codes: the reference path
    # dequantizes them to a dense weight every call, the fused path streams
    # them into the kernel directly
    wq = rtn_quantize_weight(w, bits=8, axis=0)
    prep = prepare_linear(w_quant=wq)

    us_ref, _ = timed(
        lambda: stamp_linear(x, w, None, cfg_ref, w_quant=wq), reps=2)
    us_fused, _ = timed(
        lambda: stamp_linear(x, None, None, cfg_fused, prepared=prep), reps=2)

    act, out = s * din * 4, s * dout * 4
    wbytes = din * dout                 # int8 codes read
    ref_bytes = (2 * act            # L·X written + read back
                 + 2 * act          # Q(T) written + read back
                 + 2 * out          # matmul out written + read by inverse
                 + out              # inverse write
                 + act              # original X read
                 + wbytes           # int8 codes read
                 + 2 * din * dout * 2)  # bf16 weight re-materialized:
                                        # dequant write + matmul read
    fused_bytes = act + out + wbytes    # one round trip + int8 weight
    return [
        {"name": "kernels/stamp_linear_reference_1k", "us_per_call": us_ref,
         "derived": f"tpu_hbm_bytes={ref_bytes},act_roundtrips=4"},
        {"name": "kernels/stamp_linear_fused_1k", "us_per_call": us_fused,
         "derived": f"tpu_hbm_bytes={fused_bytes},act_roundtrips=1"},
    ]


if __name__ == "__main__":
    for r in run():
        print(r)

"""Kernel micro-benchmarks (interpret mode on CPU — correctness +
derived TPU traffic estimates; wall times are NOT TPU latencies).

``--out BENCH_kernels.json`` writes the rows as JSON; CI runs that on every
push and commits the refreshed file on main, so the repo accumulates a
per-PR perf trajectory instead of expiring artifacts."""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 1024, 256)).astype(np.float32))
    rows = []

    us, _ = timed(lambda: ops.haar_dwt_seq(x, levels=4, interpret=True), reps=2)
    hbm = 2 * x.size * 4
    rows.append({"name": "kernels/haar_dwt_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.walsh_hadamard(x, axis=-2, interpret=True), reps=2)
    rows.append({"name": "kernels/wht_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.quantize_pack(x, bits=4, interpret=True), reps=2)
    rows.append({"name": "kernels/quant_pack_int4", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={int(x.size * 4.5)}"})

    m = k = n = 256
    qx = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    qw = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    ones = jnp.ones((m, 1), jnp.float32)
    onesn = jnp.ones((1, n), jnp.float32)
    us, _ = timed(lambda: ops.int8_matmul(qx, qw, ones, ones, onesn, onesn,
                                          interpret=True), reps=2)
    rows.append({"name": "kernels/int8_matmul_256", "us_per_call": us,
                 "derived": f"tpu_int_macs={2 * m * n * k}"})
    rows.extend(_stamp_linear_rows(rng))
    rows.extend(fused_site_rows())
    rows.extend(moe_site_rows())
    return rows


def _stamp_linear_rows(rng) -> list[dict]:
    """Fused vs reference STaMP linear (prefill hot path).

    Derived HBM traffic per linear for a (s, din) activation and (din, dout)
    weight, f32 accounting:

    * reference — four activation round trips: transform out+in, fake-quant
      out+in, matmul out+in, inverse write, plus the bf16 weight
      re-materialized from int codes every call;
    * fused — exactly one: read X once, write Y once, stream the int8 weight.
    """
    import dataclasses

    from repro.core.quant import rtn_quantize_weight
    from repro.core.stamp import StampConfig, prepare_linear, stamp_linear

    s, din, dout = 1024, 256, 256
    x = jnp.asarray(rng.normal(size=(1, s, din)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(din, dout)).astype(np.float32) * 0.05)
    cfg_ref = StampConfig(num_hi_tokens=64)
    cfg_fused = dataclasses.replace(cfg_ref, execution="fused")
    # both rows deploy the same int8 weight codes: the reference path
    # dequantizes them to a dense weight every call, the fused path streams
    # them into the kernel directly
    wq = rtn_quantize_weight(w, bits=8, axis=0)
    prep = prepare_linear(w_quant=wq)

    us_ref, _ = timed(
        lambda: stamp_linear(x, w, None, cfg_ref, w_quant=wq), reps=2)
    us_fused, _ = timed(
        lambda: stamp_linear(x, None, None, cfg_fused, prepared=prep), reps=2)

    ref_bytes, fused_bytes = stamp_site_bytes(s, din, dout)
    return [
        {"name": "kernels/stamp_linear_reference_1k", "us_per_call": us_ref,
         "derived": f"tpu_hbm_bytes={ref_bytes},act_roundtrips=4"},
        {"name": "kernels/stamp_linear_fused_1k", "us_per_call": us_fused,
         "derived": f"tpu_hbm_bytes={fused_bytes},act_roundtrips=1"},
    ]


def stamp_site_bytes(s: int, din: int, dout: int,
                     dual: bool = False) -> tuple[int, int]:
    """Derived per-call HBM traffic of one STaMP linear site, f32 activation
    accounting (the reference path materializes f32 intermediates).

    Reference (per linear): transform write+read, fake-quant write+read,
    matmul out write + inverse read, inverse write, original X read, int8
    weight codes read, and the bf16 weight re-materialized from the codes
    (dequant write + matmul read).  A gate/up ``dual`` site shares one
    transform+quant round trip but doubles everything per-projection and
    adds the silu·mul combine (g and u re-read, product written).

    Fused: read X once, write the output once, stream the int8 codes —
    for the dual site both weight sets stream but X is still read once and
    only the silu·mul product is written.
    """
    act, out = s * din * 4, s * dout * 4
    wbytes = din * dout                  # int8 codes read
    wremat = 2 * din * dout * 2          # bf16 dequant write + matmul read
    shared = (2 * act                # L·X written + read back
              + 2 * act              # Q(T) written + read back
              + act)                 # original X read
    per_proj = (2 * out              # matmul out written + read by inverse
                + out                # inverse write
                + wbytes + wremat)
    if not dual:
        return shared + per_proj, act + out + wbytes
    # reference gate/up: hq read by the second matmul too, then the
    # silu·mul combine reads both projections and writes the product
    ref = shared + 2 * per_proj + act + 2 * out + out
    fused = act + out + 2 * wbytes
    return ref, fused


@functools.lru_cache(maxsize=1)
def fused_site_rows() -> list[dict]:
    """Fused-vs-reference rows for EVERY model site wired through the fused
    integer kernels (`repro.models.lm.FUSED_SITES` + the merged QKV):
    attention QKV / out-proj, the MLP gate+up pair and down projection, and
    the Mamba in/out projections.  Cached so `run.py` (which imports this
    from both kernels_bench and table4_sites) measures once."""
    import dataclasses

    from repro.core.stamp import (StampConfig, prepare_linear, stamp_linear,
                                  stamp_dual_linear)

    rng = np.random.default_rng(7)
    s, d = 256, 128
    nh, hd = 4, 32                       # out-proj head split (nh·hd = d)
    di = 2 * d                           # mamba inner dim
    cfg_ref = StampConfig(num_hi_tokens=64)
    cfg_fused = dataclasses.replace(cfg_ref, execution="fused")

    def acts(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))

    def weight(k, n):
        return jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * .05)

    sites = {
        # name -> (din, dout, head-split input?, dual?)
        "attn.qkv": (d, 2 * d, False, False),      # merged q + 2·kv widths
        "attn.out_proj": (d, d, True, False),
        "mlp.gate_up": (d, 2 * d, False, True),
        "mlp.down_proj": (2 * d, d, False, False),
        "mamba.in_proj": (d, 2 * di + 2 * 32 + 16, False, False),
        "mamba.out_proj": (di, d, False, False),
    }
    rows = []
    for name, (din, dout, split, dual) in sites.items():
        x = acts(1, s, nh, din // nh) if split else acts(1, s, din)
        if dual:
            wg, wu = weight(din, dout), weight(din, dout)
            pg, pu = prepare_linear(wg), prepare_linear(wu)
            us_ref, _ = timed(lambda: stamp_dual_linear(
                x, pg.dequant(jnp.float32), pu.dequant(jnp.float32),
                cfg_ref), reps=2)
            us_fused, _ = timed(lambda: stamp_dual_linear(
                x, None, None, cfg_fused, prepared_gate=pg, prepared_up=pu),
                reps=2)
        else:
            w = weight(din, dout)
            prep = prepare_linear(w)
            us_ref, _ = timed(lambda: stamp_linear(
                x, prep.dequant(jnp.float32), None, cfg_ref,
                merge_heads=split), reps=2)
            us_fused, _ = timed(lambda: stamp_linear(
                x, None, None, cfg_fused, prepared=prep,
                merge_heads=split), reps=2)
        ref_b, fused_b = stamp_site_bytes(s, din, dout, dual=dual)
        rows.append({"name": f"kernels/site/{name}/reference",
                     "us_per_call": us_ref,
                     "derived": f"tpu_hbm_bytes={ref_b}"})
        rows.append({"name": f"kernels/site/{name}/fused",
                     "us_per_call": us_fused,
                     "derived": (f"tpu_hbm_bytes={fused_b},"
                                 f"hbm_savings={ref_b / fused_b:.2f}x")})
    return rows


def moe_site_bytes(s: int, d: int, f: int, e: int, k: int,
                   cf: float) -> tuple[int, int]:
    """Derived per-group HBM traffic of the MoE expert site, f32 activation
    accounting (same convention as `stamp_site_bytes`); capacity
    C = ceil(s·k/E·cf).

    Reference: dispatch einsum (x read, f32 (E,C,d) buffer written), gate
    and up each re-read the buffer and re-materialize a bf16 expert weight
    from the int8 codes (dequant write + matmul read), the (E,C,f)
    gate/up/silu·mul intermediates all round-trip, down re-materializes its
    weight, expert outputs written + re-read by the combine.

    Fused: read the activation once (token quantize), move int8 codes
    through the dispatch buffer (write + kernel read), stream the int8
    expert codes, write the (E,C,d) expert outputs once, combine.  The
    (E,C,f) intermediates never leave VMEM.
    """
    cap = max(int(np.ceil(s * k / e * cf)), 1)
    act = s * d * 4
    buck_i8 = e * cap * d                # int8 dispatch codes
    buck = e * cap * d * 4               # f32 (E, C, d) buffer
    hid = e * cap * f * 4                # f32 (E, C, f) intermediate
    w_gu = e * d * f                     # int8 codes, gate or up
    w_dn = e * f * d
    remat = lambda codes: codes + 2 * codes * 2   # read + bf16 write/read
    ref = (act + buck                    # dispatch: x read, xin written
           + 2 * buck                    # gate + up each read xin
           + 2 * remat(w_gu)            # gate/up weight re-materialized
           + 2 * hid                    # g, u written
           + 3 * hid                    # silu·mul: g, u read, h written
           + hid + remat(w_dn)         # down: h read, weight re-materialized
           + buck                       # expert outputs written
           + buck + act)                # combine: outputs read, y written
    fused = (act                         # activation read once
             + 2 * buck_i8              # int8 dispatch written + kernel read
             + 2 * w_gu + w_dn          # int8 expert codes streamed
             + buck                     # expert outputs written
             + buck + act)              # combine: outputs read, y written
    return ref, fused


@functools.lru_cache(maxsize=1)
def moe_site_rows() -> list[dict]:
    """Fused grouped-kernel vs reference einsum MoE expert site (one
    routing group).  Both rows deploy the same prepared int8 expert codes;
    the reference path dequantizes them per call."""
    from repro.core.stamp import prepare_linear
    from repro.models import layers as L

    rng = np.random.default_rng(11)
    s, d, f, e, k, cf = 256, 128, 128, 8, 2, 1.25
    x = jnp.asarray(rng.normal(size=(1, s, d)).astype(np.float32))
    gate_w = jnp.asarray(rng.normal(size=(d, e)).astype(np.float32))

    def expert(k_dim, n_dim, seed):
        r = np.random.default_rng(seed)
        w = jnp.asarray(r.normal(size=(e, k_dim, n_dim)
                                 ).astype(np.float32) * 0.05)
        p = prepare_linear(w, bits=8)
        return {"iq": p.qw, "isw": p.sw, "izw": p.zw}

    prep = {"g": expert(d, f, 1), "u": expert(d, f, 2), "d": expert(f, d, 3)}
    deq = {n: (w["iq"].astype(jnp.float32) - w["izw"]) * w["isw"]
           for n, w in prep.items()}

    us_ref, _ = timed(lambda: L.moe_ffn(
        x, gate_w, deq["g"], deq["u"], deq["d"], k, cf, group_size=s),
        reps=2)
    us_fused, _ = timed(lambda: L.moe_ffn_fused(
        x, gate_w, prep["g"], prep["u"], prep["d"], k, cf, group_size=s),
        reps=2)
    ref_b, fused_b = moe_site_bytes(s, d, f, e, k, cf)
    return [
        {"name": "kernels/site/moe.experts/reference", "us_per_call": us_ref,
         "derived": f"tpu_hbm_bytes={ref_b}"},
        {"name": "kernels/site/moe.experts/fused", "us_per_call": us_fused,
         "derived": (f"tpu_hbm_bytes={fused_b},"
                     f"hbm_savings={ref_b / fused_b:.2f}x")},
    ]


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="also write rows to this JSON file "
                         "(BENCH_kernels.json is committed by CI)")
    args = ap.parse_args()
    rows = run()
    for r in rows:
        print(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"suite": "kernels", "rows": rows}, f, indent=1)
            f.write("\n")
        print(f"wrote {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()

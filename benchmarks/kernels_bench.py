"""Kernel micro-benchmarks (interpret mode on CPU — correctness +
derived TPU traffic estimates; wall times are NOT TPU latencies)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 1024, 256)).astype(np.float32))
    rows = []

    us, _ = timed(lambda: ops.haar_dwt_seq(x, levels=4, interpret=True), reps=2)
    hbm = 2 * x.size * 4
    rows.append({"name": "kernels/haar_dwt_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.walsh_hadamard(x, axis=-2, interpret=True), reps=2)
    rows.append({"name": "kernels/wht_seq_1k", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={hbm}"})

    us, _ = timed(lambda: ops.quantize_pack(x, bits=4, interpret=True), reps=2)
    rows.append({"name": "kernels/quant_pack_int4", "us_per_call": us,
                 "derived": f"tpu_hbm_bytes={int(x.size * 4.5)}"})

    m = k = n = 256
    qx = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    qw = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    ones = jnp.ones((m, 1), jnp.float32)
    onesn = jnp.ones((1, n), jnp.float32)
    us, _ = timed(lambda: ops.int8_matmul(qx, qw, ones, ones, onesn, onesn,
                                          interpret=True), reps=2)
    rows.append({"name": "kernels/int8_matmul_256", "us_per_call": us,
                 "derived": f"tpu_int_macs={2 * m * n * k}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

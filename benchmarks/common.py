"""Shared benchmark scaffolding: models, data and quantization harnesses.

No pretrained PixArt/Llama weights exist on this container, so each paper
table is reproduced *structurally*: the same quantization configurations,
transforms and metrics, evaluated on (a) briefly-trained small models from
this framework and (b) synthetic activations matched to the paper's
autocorrelation structure.  Claims validated are the paper's orderings and
deltas (STaMP > baseline at matched bits, DWT ≈ DCT ≈ WHT, composition with
feature transforms), not the absolute table numbers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core import transforms as T
from repro.core.feature_transforms import (FeatureTransformSpec,
                                           build_feature_transform,
                                           svdquant_decompose)
from repro.core.stamp import StampConfig
from repro.data.pipeline import ar_grid_features

Array = jax.Array


def hist_percentiles(hist, qs: tuple = (0.5, 0.9, 0.99),
                     digits: int = 4) -> dict:
    """Render a `repro.obs.metrics.Histogram` as a ``{"p50": ...}`` row:
    bucket-interpolated estimates (error bounded by the bucket growth
    factor), replacing the old sort-the-raw-list percentiles so the bench
    reports exactly what the engines' registries aggregate."""
    return {f"p{int(round(q * 100))}": round(hist.percentile(q), digits)
            for q in qs}


def timed(fn: Callable, *args, reps: int = 3) -> tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out   # µs


@dataclasses.dataclass
class QuantSetting:
    """One table row: a feature-transform method × STaMP on/off."""

    method: str                  # rtn | smoothquant | quarot | vidit-q |
                                 # svdquant | flatquant
    stamp: Optional[StampConfig]
    act_bits: int = 4
    weight_bits: Optional[int] = 4
    block: Optional[int] = None  # per-block activation quant (Table 1: 64)


def quantized_linear_output(
    x: Array,                    # (b, s, d) calibration/eval activations
    w: Array,                    # (d, dout)
    setting: QuantSetting,
    x_calib: Optional[Array] = None,
    key: Optional[jax.Array] = None,
) -> Array:
    """Evaluate one linear layer under `setting` — the measurement core of
    Tables 1/2/4 and Figs. 4b/7."""
    d = x.shape[-1]
    spec = build_feature_transform(
        setting.method, d,
        x_calib=(x_calib if x_calib is not None else x),
        w=w, key=key, bits=setting.act_bits)

    w_eff = spec.fold_into_weight(w)
    lowrank = None
    if setting.method == "svdquant":
        sq = svdquant_decompose(w_eff, rank=max(8, d // 16),
                                bits=setting.weight_bits or 4)
        wq = sq.residual.dequant(jnp.float32)
        lowrank = (sq.l1, sq.l2)
    elif setting.weight_bits:
        wq = Q.rtn_quantize_weight(
            w_eff, bits=setting.weight_bits, axis=0).dequant(jnp.float32)
    else:
        wq = w_eff

    tx = spec.apply_to_activation(x)
    s = x.shape[-2]
    if setting.stamp is not None:
        st = setting.stamp
        tx = T.sequence_transform(
            tx, st.seq_transform, levels=st.resolved_levels(s),
            skip_first=st.skip_first_token, hw=st.hw)
        bits = st.bits_vector(s)
    else:
        bits = jnp.full((s,), float(setting.act_bits))
    if setting.block:
        *lead, ss, dd = tx.shape
        xb = tx.reshape(*lead, ss, dd // setting.block, setting.block)
        n = (2.0 ** bits[:, None] - 1.0)[..., None]
        mn = jnp.min(xb, -1, keepdims=True)
        mx = jnp.max(xb, -1, keepdims=True)
        sc = jnp.maximum((mx - mn) / n, 1e-8)
        zp = jnp.round(-mn / sc)
        qq = jnp.clip(jnp.round(xb / sc) + zp, 0.0, n)
        tq = ((qq - zp) * sc).reshape(*lead, ss, dd)
    else:
        tq = Q.fake_quant(tx, bits, axis=-1)
    y = tq @ wq
    if setting.stamp is not None:
        st = setting.stamp
        y = T.inverse_sequence_transform(
            y, st.seq_transform, levels=st.resolved_levels(s),
            skip_first=st.skip_first_token, hw=st.hw)
    if lowrank is not None:
        l1, l2 = lowrank
        y = y + spec.apply_to_activation(x) @ (l1 @ l2)
    return y


def lvm_activations(batch=4, hw=(32, 32), d=128, seed=0) -> Array:
    """DiT-like latent-grid activations (block-Toeplitz autocorrelation)."""
    return jnp.asarray(ar_grid_features(batch, hw, d, rho=0.9, seed=seed))


def stamp_2d(num_hi=64, hw=(32, 32)) -> StampConfig:
    return StampConfig(seq_transform="dwt2d", levels=3, num_hi_tokens=num_hi,
                       skip_first_token=False, hw=hw)


def stamp_1d(num_hi=64, transform="dwt") -> StampConfig:
    return StampConfig(seq_transform=transform, num_hi_tokens=num_hi,
                       skip_first_token=True)

"""Figure 7 — feature transforms (rows) × sequence transforms (columns):
improvements are complementary, and DCT ≈ WHT ≈ DWT."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (QuantSetting, lvm_activations,
                               quantized_linear_output, timed)
from repro.core.quant import sqnr_db
from repro.core.stamp import StampConfig

FEATURES = ["rtn", "smoothquant", "quarot"]
SEQUENCES = ["none", "dwt", "dct", "wht"]


def run() -> list[dict]:
    d, dout = 128, 128
    x = lvm_activations(batch=4, hw=(32, 32), d=d, seed=0)
    x = x.at[..., :3].multiply(8.0)     # outlier channels
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(d, dout)).astype(np.float32) / np.sqrt(d))
    ref = x @ w
    rows = []
    for feat in FEATURES:
        for seq in SEQUENCES:
            stamp = None
            if seq != "none":
                stamp = StampConfig(seq_transform=seq, num_hi_tokens=64,
                                    skip_first_token=False)
            setting = QuantSetting(method=feat, stamp=stamp, act_bits=4,
                                   weight_bits=None)
            us, y = timed(lambda: quantized_linear_output(
                x, w, setting, key=jax.random.PRNGKey(2)))
            rows.append({
                "name": f"fig7/{feat}+{seq}",
                "us_per_call": us,
                "derived": f"sqnr_db={float(sqnr_db(ref, y)):.2f}",
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Table 3 — transform overhead for one denoising-step-sized workload.

The paper reports <1% FLOPs and ~5% CUDA latency for DWT.  Here: FLOPs
overhead from `cost_analysis` of a jit'd DiT-block forward with/without
each transform (hardware-independent), plus CPU wall time and the Pallas
kernel's analytic VMEM/HBM traffic (the TPU latency estimate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import lvm_activations, timed
from repro.core import transforms as T
from repro.core.feature_transforms import hadamard_matrix


def _block_flops(transform: str, x, w1, w2, hmat):
    def fwd(x):
        h = x
        if transform in ("feat_hadamard", "both"):
            h = T.wht(h, axis=-1)      # butterfly, O(s·d·log d) — the
        if transform in ("seq_dwt", "both"):   # paper's fast-hadamard path
            h = T.haar_dwt(h, levels=3)
        if transform == "seq_hadamard":
            h = T.wht(h, axis=-2)
        y = jax.nn.silu(h @ w1) @ w2
        if transform in ("seq_dwt", "both"):
            y = T.haar_idwt(y, levels=3)
        if transform == "seq_hadamard":
            y = T.iwht(y, axis=-2)
        if transform in ("feat_hadamard", "both"):
            y = T.iwht(y, axis=-1)
        return y
    compiled = jax.jit(fwd).lower(x).compile()
    cost = compiled.cost_analysis() or {}
    us, _ = timed(jax.jit(fwd), x)
    return float(cost.get("flops", 0.0)), us


def run() -> list[dict]:
    hw, d = (32, 32), 512
    x = lvm_activations(batch=2, hw=hw, d=d, seed=0)
    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.normal(size=(d, 4 * d)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(size=(4 * d, d)).astype(np.float32))
    hmat = jnp.asarray(hadamard_matrix(d))

    base_flops, base_us = _block_flops("none", x, w1, w2, hmat)
    rows = [{"name": "table3/baseline", "us_per_call": base_us,
             "derived": f"flops={base_flops:.3e}"}]
    for tf in ("feat_hadamard", "seq_hadamard", "seq_dwt", "both"):
        fl, us = _block_flops(tf, x, w1, w2, hmat)
        rows.append({
            "name": f"table3/{tf}",
            "us_per_call": us,
            "derived": (f"flops_overhead_pct="
                        f"{(fl - base_flops) / base_flops * 100:.2f}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Serving benchmark: paged continuous batching (unified ragged step vs the
two-call step pair) vs bucketed lockstep on one workload, emitting
``BENCH_serving.json``.

The paged engine is measured twice: ``step_mode="unified"`` (one ragged
device program per step — prefill chunks + decode batch together) and
``step_mode="two_call"`` (the PR-3 prefill-then-decode jit pair).  The
``device_dispatches_per_step`` column makes the 2 → 1 program win visible
in the committed trajectory (unified is exactly 1.0 by construction —
asserted), ``recompiles`` pins the bounded shape-bucketing, and the two
modes must emit identical tokens (asserted).

Wall-clock rows are CPU interpret-mode numbers (relative, not TPU
latencies); the HBM bytes/token rows are derived analytically from the two
cache layouts and the *observed* request lengths:

* contiguous bf16 — every decode step streams each slot's full ``max_seq``
  reservation: ``layers · 2(K,V) · max_seq · kv · hd · 2B``;
* paged int4 — a step reads only the pages a request has mapped: int8 sink
  pages for the first ``num_hi`` tokens, int4-packed pages (+ f16 scale/zp)
  for the rest, rounded up to the page size.

The paged/contiguous ratio is the serving-time claim of the mixed-precision
cache (§B.2): ~8× fewer bytes per decoded token at 256-token reservations,
growing with ``max_seq`` since the contiguous cost is length-independent.

The ``hybrid_jamba`` row serves the reduced Jamba config (Mamba +
attention + MoE) through the same engines: paged K/V for the attention
layers plus the slot-dense SSM state pool, with a forced preemption so the
swap traffic (pages + per-slot conv/SSM state) and
``ssm_state_bytes_per_slot`` land in the trajectory; token parity against
the bucketed oracle and one-dispatch-per-unified-step are asserted.

The ``degraded`` row runs the same smoke model deliberately overloaded
(tiny page pool, bounded waiting queue, per-request deadlines on a virtual
clock) and reports goodput, shed rate, and deadline misses — the
graceful-degradation contract from the robustness PR.

The ``prefix_share`` row serves a seeded prefix-heavy mix (75% of
requests share one 96-token system prefix) twice — prefix caching on and
off — and reports the tokens/s speedup, the TTFT drop, and the peak
page-pool footprint of each pass.  Tokens must be bit-identical between
the two passes (the cache changes where prefill *starts*, never what any
chunk computes) and the allocator must be leak-free at exit; both are
asserted, alongside the deterministic signal (fewer prefill chunks, hit
rate) that makes the row meaningful even where wall clocks are noisy.

    PYTHONPATH=src:. python benchmarks/serving_bench.py --smoke \
        --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from benchmarks.common import hist_percentiles                 # noqa: E402
from repro.models import lm                                    # noqa: E402
from repro.models.config import ModelConfig                    # noqa: E402
from repro.serving import kvcache as KV                        # noqa: E402
from repro.serving.engine import (BucketedEngine, EngineConfig,  # noqa: E402
                                  PagedEngineConfig, PagedServingEngine)


def drive_workload(engine, prompts, max_new: int) -> tuple:
    """One measured engine pass: an untimed warmup over the same request
    mix first (compiles every shape variant — prefill buckets / unified
    n_pf buckets / decode — and is then reset via ``reset_stats`` so the
    timed pass starts from zeroed registries and an empty event ring,
    except the cumulative ``recompiles``), then the timed pass.
    Percentiles come from the engines' own latency histograms — both
    engine classes share the registry surface, so the old hasattr guard
    (which silently skipped the reset on one of them) is gone.  Returns
    ``(done, row)`` — shared by the dense and hybrid workloads so the
    warmup/reset protocol cannot drift between rows of the same JSON."""
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    engine.run()
    engine.reset_stats(clear_events=True)
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    row = {
        "requests": len(done),
        "decode_tokens": toks,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(toks / dt, 2),
        "ttft_s": hist_percentiles(engine.metrics.histogram("ttft_s")),
        "latency_s": hist_percentiles(engine.metrics.histogram("latency_s")),
    }
    return done, row


def _cache_bytes_per_token(cfg: ModelConfig, kv: KV.KVCacheConfig,
                           max_seq: int, block_size: int,
                           lengths: list[int], paged: bool) -> float:
    """Mean HBM bytes the decode attention reads per generated token."""
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    layers = cfg.num_layers

    def per_head_bytes(tokens_hi: float, tokens_lo: float,
                       quantized: bool) -> float:
        """Bytes read for one of K or V, one kv head, given token counts."""
        if not quantized:
            return (tokens_hi + tokens_lo) * hd * 2.0        # bf16 codes
        code = tokens_hi * hd * 1.0 + tokens_lo * hd * 0.5   # int8 / nibbles
        meta = (tokens_hi + tokens_lo) * 2 * 2.0             # f16 scale+zp
        return code + meta

    if not paged:
        # contiguous: the full reservation streams every step regardless of
        # how many tokens a request actually holds
        num_hi = min(kv.num_hi, max_seq) if kv.quantized else 0
        per_head = per_head_bytes(num_hi, max_seq - num_hi, kv.quantized)
        return layers * 2 * per_head * kvh
    # paged: only the pages a request has mapped, rounded up to page size
    total = 0.0
    for ln in lengths:
        num_hi = min(kv.num_hi, ln) if kv.quantized else 0
        hi_pages = -(-num_hi // block_size) if num_hi else 0
        lo_tokens = ln - num_hi
        lo_pages = -(-lo_tokens // block_size) if lo_tokens > 0 else 0
        per_head = per_head_bytes(hi_pages * block_size,
                                  lo_pages * block_size, kv.quantized)
        total += layers * 2 * per_head * kvh
    return total / max(len(lengths), 1)


def run(smoke: bool = True, seed: int = 0, trace_out: str = None,
        metrics_out: str = None) -> dict:
    if smoke:
        cfg = ModelConfig(name="bench-smoke", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128)
        n_req, max_seq, bucket = 6, 96, 64
        prompt_lens = (20, 33, 47, 12, 28, 40)
        max_new = 8
    else:
        cfg = ModelConfig(name="bench", family="dense", num_layers=4,
                          d_model=256, num_heads=8, num_kv_heads=4,
                          d_ff=512, vocab_size=512)
        n_req, max_seq, bucket = 16, 256, 128
        prompt_lens = tuple(24 + (i * 37) % 100 for i in range(n_req))
        max_new = 16

    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in prompt_lens]

    def workload(engine):
        done, row = drive_workload(engine, prompts, max_new)
        return row, done

    results = {"config": {"model": cfg.name, "requests": n_req,
                          "max_new": max_new, "max_seq": max_seq,
                          "prompt_lens": list(map(int, prompt_lens)),
                          # wall_s / tokens_per_s are single-shot CPU
                          # interpret-mode numbers: comparable between rows
                          # of ONE run, not across machines or commits —
                          # the deterministic columns (dispatches/step,
                          # recompiles, HBM bytes, token parity) are the
                          # trajectory signal
                          "wall_clock_comparable_within_run_only": True}}

    # contiguous bf16 cache through the bucketed engine (the baseline the
    # acceptance ratio is defined against)
    serve_bf16 = lm.ServeConfig(stamp=None,
                                kv=KV.KVCacheConfig(quantized=False))
    eng = BucketedEngine(params, cfg, serve_bf16,
                         EngineConfig(max_batch=8, bucket=bucket,
                                      max_seq=max_seq))
    row, done = workload(eng)
    final_lens = [len(p) + len(r.out_tokens)
                  for p, r in zip(prompts, sorted(done, key=lambda r: r.uid))]
    row["hbm_bytes_per_token"] = int(_cache_bytes_per_token(
        cfg, serve_bf16.kv, max_seq, 16, final_lens, paged=False))
    results["bucketed_bf16"] = row

    # paged int4 (64@8b sink) through the continuous-batching engine —
    # once per step mode, so the unified ragged step's 2 → 1
    # dispatches-per-step win (and its token parity with the two-call
    # pair) lands in the committed trajectory
    kv_q = KV.KVCacheConfig(quantized=True, num_hi=16 if smoke else 64)
    serve_q = lm.ServeConfig(stamp=None, kv=kv_q)
    block = 16
    paged_tokens = {}
    for mode, key in (("unified", "paged_int4"),
                      ("two_call", "paged_int4_two_call")):
        eng = PagedServingEngine(params, cfg, serve_q,
                                 PagedEngineConfig(max_slots=8,
                                                   prefill_chunk=bucket,
                                                   max_seq=max_seq,
                                                   block_size=block,
                                                   step_mode=mode))
        row, done_p = workload(eng)
        paged_tokens[mode] = {r.uid: r.out_tokens for r in done_p}
        row["preemptions"] = eng.stats["preemptions"]
        row["scheduler_steps"] = eng.stats["steps"]
        row["device_dispatches_per_step"] = round(
            eng.stats["device_dispatches"] / max(eng.stats["steps"], 1), 3)
        row["recompiles"] = eng.stats["recompiles"] if mode == "unified" \
            else None
        row["hbm_bytes_per_token"] = int(_cache_bytes_per_token(
            cfg, kv_q, max_seq, block, final_lens, paged=True))
        results[key] = row
        if mode == "unified":
            # CI artifacts from the timed unified pass (the headline row):
            # the Perfetto-loadable span timeline and the full registry
            # snapshot the schema check guards
            if trace_out:
                from repro.obs.trace import export_chrome_trace
                with open(trace_out, "w") as f:
                    json.dump(export_chrome_trace(
                        eng.events, engine="paged_unified"), f)
            if metrics_out:
                with open(metrics_out, "w") as f:
                    f.write(eng.metrics.to_json())
    assert results["paged_int4"]["device_dispatches_per_step"] == 1.0, \
        "unified step must dispatch exactly one device program per step"
    assert results["paged_int4_two_call"]["device_dispatches_per_step"] > \
        1.0, "two-call baseline should exceed one dispatch per step"
    # recorded, not asserted: single-shot wall clocks on a shared CI
    # runner are too noisy for a hard gate — the trajectory JSON carries
    # the ratio so a real regression shows up in history (the dispatch
    # and token-parity asserts above are the deterministic guards)
    results["unified_vs_two_call_tokens_ratio"] = round(
        results["paged_int4"]["tokens_per_s"] /
        max(results["paged_int4_two_call"]["tokens_per_s"], 1e-9), 3)
    for uid, toks in paged_tokens["two_call"].items():
        np.testing.assert_array_equal(
            toks, paged_tokens["unified"][uid],
            err_msg=f"unified/two_call token divergence uid={uid}")

    # same quantized cache through the bucketed engine: isolates the
    # continuous-batching scheduling win from the layout win
    eng = BucketedEngine(params, cfg, serve_q,
                         EngineConfig(max_batch=8, bucket=bucket,
                                      max_seq=max_seq))
    row, _ = workload(eng)
    row["hbm_bytes_per_token"] = int(_cache_bytes_per_token(
        cfg, kv_q, max_seq, 16, final_lens, paged=False))
    results["bucketed_int4"] = row

    ratio = results["bucketed_bf16"]["hbm_bytes_per_token"] / \
        max(results["paged_int4"]["hbm_bytes_per_token"], 1)
    results["paged_vs_bf16_hbm_ratio"] = round(ratio, 2)
    results["hybrid_jamba"] = run_hybrid(seed)
    results["moe_arctic"] = run_moe(seed)
    results["degraded"] = run_degraded(seed)
    results["prefix_share"] = run_prefix_share(seed)
    return results


def run_moe(seed: int = 0) -> dict:
    """Expert-scale row: the reduced Arctic config (8 experts, top-2,
    dense residual) served fused end to end — every STaMP site including
    the MoE expert einsums runs the integer kernels (grouped dispatch), so
    ``reference_fallback_sites`` must be 0 and the unified ragged step
    still dispatches exactly ONE device program per step (both asserted).
    Router health comes from the engine's own registry (the ``moe_router``
    pseudo-site `moe_route` records inside the step program): per-expert
    load, capacity occupancy, and the drop rate."""
    from repro.configs import get_reduced
    from repro.core.stamp import StampConfig
    cfg = get_reduced("arctic-480b")
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompt_lens = (20, 33, 12)
    max_new = 8
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in prompt_lens]
    serve = lm.ServeConfig(
        stamp=StampConfig(num_hi_tokens=8, execution="fused"),
        kv=KV.KVCacheConfig(quantized=True, num_hi=16),
        quant_telemetry=True)
    eng = PagedServingEngine(
        params, cfg, serve,
        PagedEngineConfig(max_slots=4, prefill_chunk=64, max_seq=96,
                          block_size=16, step_mode="unified"))
    assert eng.stats["reference_fallback_sites"] == 0, \
        "expert config must reach full fused coverage (grouped MoE)"
    _, row = drive_workload(eng, prompts, max_new)
    st = eng.stats
    row["model"] = cfg.name
    row["num_experts"] = cfg.num_experts
    row["experts_per_token"] = cfg.experts_per_token
    row["prompt_lens"] = list(map(int, prompt_lens))
    row["max_new"] = max_new
    row["reference_fallback_sites"] = st["reference_fallback_sites"]
    row["device_dispatches_per_step"] = round(
        st["device_dispatches"] / max(st["steps"], 1), 3)
    assert row["device_dispatches_per_step"] == 1.0, \
        "fused MoE unified step must dispatch exactly one program per step"
    m = eng.metrics
    row["router"] = {
        "expert_tokens_last_step": [
            m.gauge("moe_expert_tokens", labels={"expert": str(i)}).value
            for i in range(cfg.num_experts)],
        "dropped_tokens_total": m.counter("moe_dropped_tokens").value,
        "capacity_occupancy": round(
            m.gauge("moe_capacity_occupancy").value, 4),
        "drop_rate": round(m.gauge("moe_drop_rate").value, 4),
    }
    return row


def run_degraded(seed: int = 0) -> dict:
    """Graceful-degradation row: the same smoke model on a deliberately
    under-provisioned engine — tiny page pool (watermark preemption
    active), bounded waiting queue, and per-request deadlines driven by an
    injected virtual clock (2 virtual ms per clock read, so the row is
    machine-independent and deterministic).  Reports **goodput** (tokens
    of *finished* requests per real second), the shed rate, and the
    deadline-miss count alongside raw tokens/s — the load-shedding
    contract: under overload the engine degrades by plan (reject / shed /
    fail-at-deadline), never by exception, and releases every page/slot
    (asserted)."""
    cfg = ModelConfig(name="bench-degraded", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompt_lens = tuple(12 + (i * 17) % 40 for i in range(10))
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in prompt_lens]
    max_new = 8
    tick = 0.02                       # virtual seconds per clock read
    deadline_s, ttft_deadline_s = 0.6, 0.35
    max_waiting, shed_policy, watermark = 5, "reject_newest", 0.75
    clk = {"t": 0.0}

    def clock() -> float:
        clk["t"] += tick
        return clk["t"]

    serve = lm.ServeConfig(stamp=None,
                           kv=KV.KVCacheConfig(quantized=True, num_hi=16))
    eng = PagedServingEngine(
        params, cfg, serve,
        PagedEngineConfig(max_slots=3, prefill_chunk=32, max_seq=96,
                          block_size=16, num_lo_blocks=5,
                          max_waiting=max_waiting, shed_policy=shed_policy,
                          preempt_watermark=watermark),
        clock=clock)
    uids = [eng.submit(p, max_new_tokens=max_new, deadline_s=deadline_s,
                       ttft_deadline_s=ttft_deadline_s) for p in prompts]
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert sorted(r.uid for r in done) == sorted(uids), \
        "degraded run lost a request"
    assert eng.sched.quiescent(), "degraded run leaked pages/slots"
    st = eng.stats
    assert st["finished"] > 0, "overload must not starve every request"
    good_tokens = sum(len(r.out_tokens) for r in done
                      if r.status == "finished")
    all_tokens = sum(len(r.out_tokens) for r in done)
    return {
        "model": cfg.name, "requests": len(prompts),
        "virtual_s_per_clock_read": tick,
        "virtual_wall_s": round(clk["t"], 3),
        "deadline_s": deadline_s, "ttft_deadline_s": ttft_deadline_s,
        "max_waiting": max_waiting, "shed_policy": shed_policy,
        "preempt_watermark": watermark,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(all_tokens / wall, 2),
        "goodput_tokens_per_s": round(good_tokens / wall, 2),
        "finished": st["finished"], "failed": st["failed"],
        "shed": st["shed"], "rejected": st["rejected"],
        "shed_rate": round(st["shed"] / len(prompts), 3),
        "deadline_misses": st["deadline_misses"],
        "preemptions": st["preemptions"],
        "watchdog_trips": st["watchdog_trips"],
    }


def gen_prefix_workload(seed: int, vocab: int, n_req: int = 8,
                        shared_frac: float = 0.75, prefix_len: int = 96,
                        tail: tuple = (8, 20),
                        unique: tuple = (40, 72)) -> tuple:
    """Seeded prefix-heavy request mix: ``shared_frac`` of the requests are
    the same ``prefix_len``-token system prefix plus a short unique tail
    (``tail`` token range); the rest are fully unique prompts drawn from the
    ``unique`` length range.  Which positions carry the shared prefix is a
    Bresenham spread (``floor((i+1)·f) > floor(i·f)``), so the mix is evenly
    interleaved and a pure function of ``(seed, n_req, shared_frac)`` — the
    arrival *order* is the list order, identical for every engine under
    test.  Returns ``(prompts, shared_flags)``."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len)
    prompts, flags = [], []
    for i in range(n_req):
        hit = int((i + 1) * shared_frac) > int(i * shared_frac)
        if hit:
            t = rng.integers(0, vocab,
                             int(rng.integers(tail[0], tail[1] + 1)))
            prompts.append(np.concatenate([prefix, t]))
        else:
            prompts.append(rng.integers(
                0, vocab, int(rng.integers(unique[0], unique[1] + 1))))
        flags.append(hit)
    return prompts, flags


def run_prefix_share(seed: int = 0) -> dict:
    """Prefix-caching row: the same seeded prefix-heavy workload served
    with the hash-addressed prefix cache on and off.  The warmup pass
    populates the cache (and compiles every shape variant); the timed pass
    then admits every shared request at its first uncached token.  Tokens
    must be **bit-identical** between the two passes — the cache only moves
    the prefill start, chunk boundaries coincide by construction — and
    both allocators must be leak-free at exit (``quiescent`` +
    ``all_free``).  Deterministic guards (prefill chunks, hit count) back
    the wall-clock speedup, which is asserted at the acceptance floor."""
    cfg = ModelConfig(name="bench-prefix", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    shared_frac, prefix_len, max_new = 0.75, 96, 6
    prompts, flags = gen_prefix_workload(seed, cfg.vocab_size,
                                         shared_frac=shared_frac,
                                         prefix_len=prefix_len)

    def drive(prefix_caching: bool) -> tuple:
        eng = PagedServingEngine(
            params, cfg,
            lm.ServeConfig(stamp=None,
                           kv=KV.KVCacheConfig(quantized=True, num_hi=16)),
            PagedEngineConfig(max_slots=4, prefill_chunk=32, max_seq=128,
                              block_size=16, prefix_caching=prefix_caching))
        for p in prompts:          # warmup: compiles AND registers prefixes
            eng.submit(p, max_new_tokens=max_new)
        eng.run()
        eng.reset_stats(clear_events=True)
        alloc = eng.sched.alloc
        alloc.peak_referenced = 0  # fresh peak for the timed pass
        uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        assert eng.sched.quiescent() and alloc.all_free(), \
            "prefix workload leaked pages/slots"
        by_uid = {r.uid: r.out_tokens for r in done}
        tokens = [by_uid[u] for u in uids]     # submission order
        return eng, tokens, dt

    eng_on, tok_on, dt_on = drive(True)
    eng_off, tok_off, dt_off = drive(False)
    for i, (a, b) in enumerate(zip(tok_on, tok_off)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"prefix cache changed tokens (request {i}, "
                          f"shared={flags[i]})")
    st_on, st_off = eng_on.stats, eng_off.stats
    n_shared = sum(flags)
    assert st_on["prefix_cache_hits"] >= n_shared, \
        "warm cache must hit every shared-prefix request"
    assert st_off["prefix_cache_hits"] == 0, \
        "cache-off engine must never consult the prefix cache"
    assert st_on["prefill_chunks"] < st_off["prefill_chunks"], \
        "cached prefixes must shrink the prefill work"
    toks = sum(len(t) for t in tok_on)
    speedup = (toks / dt_on) / max(toks / dt_off, 1e-9)
    assert speedup >= 1.3, \
        f"prefix cache speedup {speedup:.2f}x below the 1.3x floor"
    ttft_on = hist_percentiles(eng_on.metrics.histogram("ttft_s"))
    ttft_off = hist_percentiles(eng_off.metrics.histogram("ttft_s"))
    assert ttft_on["p50"] < ttft_off["p50"], \
        "cached prefixes must cut time-to-first-token"
    peak_on = eng_on.sched.alloc.peak_referenced
    peak_off = eng_off.sched.alloc.peak_referenced
    assert peak_on <= peak_off, \
        "page sharing must not grow the peak pool footprint"
    return {
        "requests": len(prompts),
        "shared_prefix_fraction": shared_frac,
        "prefix_len": prefix_len,
        "max_new": max_new,
        "decode_tokens": toks,
        "tokens_per_s": round(toks / dt_on, 2),
        "tokens_per_s_cache_off": round(toks / dt_off, 2),
        "speedup": round(speedup, 3),
        "ttft_s": ttft_on,
        "ttft_s_cache_off": ttft_off,
        "prefill_chunks": st_on["prefill_chunks"],
        "prefill_chunks_cache_off": st_off["prefill_chunks"],
        "prefix_cache_hits": st_on["prefix_cache_hits"],
        "prefix_cache_hit_rate": round(st_on["prefix_cache_hit_rate"], 4),
        "prefix_tokens_reused": st_on["prefix_tokens_reused"],
        "cow_copies": st_on["cow_copies"],
        "peak_pages": peak_on,
        "peak_pages_cache_off": peak_off,
    }


def run_hybrid(seed: int = 0) -> dict:
    """Hybrid (Mamba + attention + MoE) workload on the reduced Jamba
    config: continuous batching over paged K/V *plus* the slot-dense SSM
    state pool.  The lo pool is sized to force a preemption, so the row
    also reports the swap traffic a hybrid eviction moves (pages + per-slot
    conv/SSM state) and `ssm_state_bytes_per_slot` — the fixed HBM a slot
    pins across every Mamba layer, the admission-time cost the scheduler
    accounts by its slot gate.  Tokens must be identical to the bucketed
    oracle (single-chunk prompts: chunk width == bucket width) and the
    unified mode must dispatch exactly ONE device program per step —
    both asserted."""
    from repro.configs import get_reduced
    cfg = get_reduced("jamba-1.5-large-398b")
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompt_lens = (20, 33, 12)
    max_new = 8
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in prompt_lens]
    kv_q = KV.KVCacheConfig(quantized=True, num_hi=16)
    serve = lm.ServeConfig(stamp=None, kv=kv_q)

    def drive(engine):
        done, row = drive_workload(engine, prompts, max_new)
        return {r.uid: r.out_tokens for r in done}, row

    buck_tokens, buck_row = drive(BucketedEngine(
        params, cfg, serve, EngineConfig(max_batch=8, bucket=64,
                                         max_seq=96)))
    row = {"model": cfg.name, "requests": len(prompts),
           "prompt_lens": list(map(int, prompt_lens)), "max_new": max_new,
           "bucketed": buck_row}
    for mode in ("unified", "two_call"):
        eng = PagedServingEngine(
            params, cfg, serve,
            PagedEngineConfig(max_slots=3, prefill_chunk=64, max_seq=96,
                              block_size=16, num_lo_blocks=4,
                              step_mode=mode))
        tokens, mode_row = drive(eng)
        st = eng.stats
        mode_row["preemptions"] = st["preemptions"]
        mode_row["swap_bytes"] = st["swap_bytes"]
        mode_row["device_dispatches_per_step"] = round(
            st["device_dispatches"] / max(st["steps"], 1), 3)
        row[mode] = mode_row
        assert st["preemptions"] > 0, \
            f"hybrid {mode} workload did not exercise preemption"
        for uid in buck_tokens:
            np.testing.assert_array_equal(
                tokens[uid], buck_tokens[uid],
                err_msg=f"hybrid {mode} vs bucketed divergence uid={uid}")
    row["ssm_state_bytes_per_slot"] = eng.sched.cfg.state_bytes_per_slot
    assert row["unified"]["device_dispatches_per_step"] == 1.0, \
        "hybrid unified step must dispatch exactly one program per step"
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short workload (CI)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the timed unified-mode pass's event ring "
                         "as Chrome trace-event JSON (ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the unified-mode engine's metrics "
                         "registry snapshot as JSON")
    args = ap.parse_args()
    results = run(smoke=args.smoke, seed=args.seed,
                  trace_out=args.trace_out, metrics_out=args.metrics_out)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))
    assert results["paged_int4"]["hbm_bytes_per_token"] < \
        results["bucketed_bf16"]["hbm_bytes_per_token"], \
        "paged int4 must move fewer HBM bytes/token than contiguous bf16"


if __name__ == "__main__":
    main()

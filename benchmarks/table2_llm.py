"""Table 2 — STaMP always improves LLM quantization (W4A4KV4, 64@8b).

A small in-framework LM is trained briefly on the locally-correlated
corpus, then evaluated under W4A4KV4 serving with each feature-transform
baseline (RTN, SmoothQuant, QuaRot, FlatQuant-lite) × STaMP on/off.
Metric: held-out perplexity (the paper's WikiText-2 PPL analog) via the
layer-simulation harness on true model activations.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QuantSetting, quantized_linear_output, stamp_1d, timed
from repro.core.quant import sqnr_db
from repro.data.pipeline import DataConfig, markov_batch
from repro.launch.train import TrainConfig, train
from repro.models import lm
from repro.models.config import ModelConfig

METHODS = ["rtn", "smoothquant", "quarot", "flatquant"]

CFG = ModelConfig(name="bench-lm", family="dense", num_layers=4,
                  d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                  vocab_size=256, tie_embeddings=True)


@functools.lru_cache(maxsize=1)
def _trained():
    out = train(CFG, TrainConfig(steps=400, global_batch=8, seq=128,
                                 lr=3e-3, warmup=40), ckpt_dir=None,
                verbose=False)
    return out["params"]


def _block_inputs(params, batch):
    """True activations entering the first block's qkv projection."""
    emb = lm._embed(params, jnp.asarray(batch["tokens"]))
    from repro.models.layers import rms_norm
    p0 = jax.tree.map(lambda a: a[0], params["period"])[0]
    return rms_norm(emb, p0["ln1"].astype(emb.dtype)), p0


def run() -> list[dict]:
    params = _trained()
    dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=128, global_batch=8)
    x, p0 = _block_inputs(params, markov_batch(dcfg, -100))
    x = x.astype(jnp.float32)
    x_calib, _ = _block_inputs(params, markov_batch(dcfg, -101))
    x_calib = x_calib.astype(jnp.float32)
    w = jnp.asarray(p0["wq"], jnp.float32)
    ref = x @ w

    rows = []
    for method in METHODS:
        for use_stamp in (False, True):
            setting = QuantSetting(
                method=method,
                stamp=stamp_1d(num_hi=16) if use_stamp else None,
                act_bits=4, weight_bits=4)
            us, y = timed(lambda: quantized_linear_output(
                x, w, setting, x_calib=x_calib,
                key=jax.random.PRNGKey(1)))
            rows.append({
                "name": f"table2/{method}{'+stamp' if use_stamp else ''}",
                "us_per_call": us,
                "derived": f"sqnr_db={float(sqnr_db(ref, y)):.2f}",
            })

    # end-to-end perplexity under full W4A4KV4 serving (model-level claim)
    eval_batch = markov_batch(dcfg, -102)
    from repro.core.stamp import StampConfig
    from repro.serving.kvcache import KVCacheConfig

    def ppl(seq_transform: str):
        # A4 everywhere, 16 tokens at 8 bits for BOTH settings (the paper
        # gives baselines the same mixed-precision budget, §B.2) — the only
        # difference is the sequence transform.
        stamp = StampConfig(seq_transform=seq_transform, num_hi_tokens=16,
                            skip_first_token=True)
        serve = lm.ServeConfig(stamp=stamp,
                               kv=KVCacheConfig(quantized=True, num_hi=16),
                               weight_bits=None)
        x_h, _, _ = lm.model_hidden(
            params, {k: jnp.asarray(v) for k, v in eval_batch.items()},
            CFG, mode="prefill", policy=None,
            stamp=serve.stamp, kv_cfg=serve.kv, remat=False)
        loss = lm.chunked_xent(x_h, lm._head_weight(params),
                               jnp.asarray(eval_batch["labels"]))
        return float(jnp.exp(loss))

    base = ppl("none")
    stamped = ppl("dwt")
    x_fp, _, _ = lm.model_hidden(
        params, {k: jnp.asarray(v) for k, v in eval_batch.items()},
        CFG, mode="train", policy=None, remat=False)
    fp = float(jnp.exp(lm.chunked_xent(x_fp, lm._head_weight(params),
                                       jnp.asarray(eval_batch["labels"]))))
    rows.append({"name": "table2/ppl_fp", "us_per_call": 0.0,
                 "derived": f"ppl={fp:.2f}"})
    rows.append({"name": "table2/ppl_a4_uniform", "us_per_call": 0.0,
                 "derived": f"ppl={base:.2f}"})
    rows.append({"name": "table2/ppl_a4_stamp", "us_per_call": 0.0,
                 "derived": f"ppl={stamped:.2f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Table 1 — STaMP consistently improves LVM quantization.

W4A4 per-block (64) quantization of DiT-like latent-grid activations;
methods: RTN, ViDiT-Q (SDCB), SVDQuant — each with and without STaMP
(2-D DWT, 64 tokens at 8 bits).  Metric: SQNR of the layer output (the
paper's image-space SQNR needs the full diffusion loop; the layer-level
ordering is the claim being validated).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (QuantSetting, lvm_activations,
                               quantized_linear_output, stamp_2d, timed)
from repro.core.quant import sqnr_db

METHODS = ["rtn", "vidit-q", "svdquant"]


def run() -> list[dict]:
    hw, d, dout = (32, 32), 128, 256
    rng = np.random.default_rng(0)
    x = lvm_activations(batch=4, hw=hw, d=d, seed=0)
    x_calib = lvm_activations(batch=4, hw=hw, d=d, seed=1)
    w = jnp.asarray(rng.normal(size=(d, dout)).astype(np.float32) / np.sqrt(d))
    # a few outlier channels, as in real DiT activations
    x = x.at[..., :3].multiply(8.0)
    x_calib = x_calib.at[..., :3].multiply(8.0)
    ref = x @ w

    rows = []
    for method in METHODS:
        for use_stamp in (False, True):
            setting = QuantSetting(
                method=method,
                stamp=stamp_2d(num_hi=64, hw=hw) if use_stamp else None,
                act_bits=4, weight_bits=4, block=64)
            us, y = timed(lambda: quantized_linear_output(
                x, w, setting, x_calib=x_calib,
                key=jax.random.PRNGKey(0)))
            rows.append({
                "name": f"table1/{method}{'+stamp' if use_stamp else ''}",
                "us_per_call": us,
                "derived": f"sqnr_db={float(sqnr_db(ref, y)):.2f}",
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Table 4 — per-site A4 ablation: STaMP helps at sequence-structured sites
and is ~neutral at the pooled-conditioning site (cross-attn to_out),
QuaRot+STaMP is the strongest combination everywhere else.

Alongside the accuracy ablation this table now reports the *deployment*
per-site picture: fused-vs-reference wall time and derived HBM bytes for
every model site wired through the fused integer kernels (rows shared with
`kernels_bench.fused_site_rows` — QKV, out-proj, gate/up pair, down-proj
and the Mamba projections)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (QuantSetting, lvm_activations,
                               quantized_linear_output, timed)
from repro.core.quant import sqnr_db
from repro.core.stamp import StampConfig

TRANSFORMS = ["identity", "quarot", "stamp", "quarot+stamp"]


def _site_activations(site: str, d: int):
    """Sequence-structured sites get grid activations; attn2.to_out mimics
    pooled text conditioning (every token ≈ the same pooled vector →
    no Toeplitz structure along the sequence)."""
    if site == "attn2.to_out":
        # pooled-conditioning site: no sequence-local correlation (tokens
        # exchange with a per-image text embedding) → iid activations, the
        # case where sequence transforms cannot concentrate energy.
        rng = np.random.default_rng(3)
        return jnp.asarray(rng.normal(size=(4, 1024, d)).astype(np.float32))
    seed = hash(site) % 1000
    return lvm_activations(batch=4, hw=(32, 32), d=d, seed=seed)


def run() -> list[dict]:
    d, dout = 128, 128
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(d, dout)).astype(np.float32) / np.sqrt(d))
    rows = []
    for site in ("attn1", "attn1.to_out", "ffn.up_proj", "attn2.to_out"):
        x = _site_activations(site, d)
        ref = x @ w
        for tf in TRANSFORMS:
            method = "quarot" if "quarot" in tf else "rtn"
            stamp = None
            if "stamp" in tf:
                stamp = StampConfig(seq_transform="dwt2d", levels=3,
                                    hw=(32, 32), num_hi_tokens=64,
                                    skip_first_token=False)
            setting = QuantSetting(method=method, stamp=stamp, act_bits=4,
                                   weight_bits=None)
            us, y = timed(lambda: quantized_linear_output(
                x, w, setting, key=jax.random.PRNGKey(3)))
            rows.append({
                "name": f"table4/{site}/{tf}",
                "us_per_call": us,
                "derived": f"sqnr_db={float(sqnr_db(ref, y)):.2f}",
            })
    from benchmarks.kernels_bench import fused_site_rows
    rows.extend(fused_site_rows())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

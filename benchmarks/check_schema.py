"""Schema gate for the committed serving trajectory and the observability
artifacts CI uploads.

A refactor that silently drops a column from ``BENCH_serving.json`` (or a
metric family from the registry snapshot) breaks the trajectory history —
every later commit's JSON stops being comparable to the ones before it.
Renames are fine, but they must show up here as an explicit edit in the
same PR, not as a quiet hole in the data.

    PYTHONPATH=src:. python benchmarks/check_schema.py \
        --bench BENCH_serving.json [--metrics metrics.json] \
        [--trace trace.json]

Exit code is nonzero (with every missing key listed) on any violation.
"""

from __future__ import annotations

import argparse
import json
import sys

# -- BENCH_serving.json --------------------------------------------------
# top-level row -> keys that row must carry.  Percentile dicts are checked
# one level deeper via PERCENTILE_KEYS.
BENCH_ROWS = {
    "config": ("model", "requests", "max_new", "max_seq", "prompt_lens"),
    "bucketed_bf16": ("requests", "decode_tokens", "wall_s", "tokens_per_s",
                      "ttft_s", "latency_s", "hbm_bytes_per_token"),
    "paged_int4": ("requests", "decode_tokens", "wall_s", "tokens_per_s",
                   "ttft_s", "latency_s", "preemptions", "scheduler_steps",
                   "device_dispatches_per_step", "recompiles",
                   "hbm_bytes_per_token"),
    "paged_int4_two_call": ("requests", "decode_tokens", "tokens_per_s",
                            "device_dispatches_per_step",
                            "hbm_bytes_per_token"),
    "bucketed_int4": ("requests", "decode_tokens", "tokens_per_s",
                      "hbm_bytes_per_token"),
    "hybrid_jamba": ("model", "requests", "bucketed", "unified", "two_call",
                     "ssm_state_bytes_per_slot"),
    "moe_arctic": ("model", "requests", "decode_tokens", "tokens_per_s",
                   "num_experts", "experts_per_token",
                   "reference_fallback_sites",
                   "device_dispatches_per_step", "router"),
    "degraded": ("requests", "virtual_wall_s", "tokens_per_s",
                 "goodput_tokens_per_s", "finished", "failed", "shed",
                 "rejected", "shed_rate", "deadline_misses", "preemptions",
                 "watchdog_trips"),
    "prefix_share": ("requests", "shared_prefix_fraction", "prefix_len",
                     "max_new", "decode_tokens", "tokens_per_s",
                     "tokens_per_s_cache_off", "speedup", "ttft_s",
                     "ttft_s_cache_off", "prefill_chunks",
                     "prefill_chunks_cache_off", "prefix_cache_hits",
                     "prefix_cache_hit_rate", "prefix_tokens_reused",
                     "cow_copies", "peak_pages", "peak_pages_cache_off"),
}
BENCH_SCALARS = ("paged_vs_bf16_hbm_ratio", "unified_vs_two_call_tokens_ratio")
PERCENTILE_KEYS = ("p50", "p90", "p99")
# router-health sub-dict of the moe_arctic row (grouped fused MoE serving)
ROUTER_KEYS = ("expert_tokens_last_step", "dropped_tokens_total",
               "capacity_occupancy", "drop_rate")

# -- metrics snapshot ----------------------------------------------------
METRIC_SECTIONS = ("t", "counters", "gauges", "histograms")
# counter families the engines must always register (value may be 0)
METRIC_COUNTERS = ("steps", "decode_tokens", "prefill_chunks", "preemptions",
                   "device_dispatches", "recompiles", "finished", "failed",
                   "deadline_misses", "nan_quarantines", "demotions",
                   "prefix_cache_queries", "prefix_cache_hits",
                   "prefix_tokens_reused", "cow_copies")
METRIC_HISTOGRAMS = ("ttft_s", "latency_s", "queue_wait_s")
HISTOGRAM_FIELDS = ("edges", "counts", "sum", "count")

# -- Chrome trace --------------------------------------------------------
TRACE_KEYS = ("traceEvents", "displayTimeUnit", "metadata")
TRACE_EVENT_KEYS = ("ph", "name", "ts", "pid", "tid")

# -- static-analysis artifacts -------------------------------------------
# STATIC_ANALYSIS.json: the ratchet baseline the contracts CLI enforces
STATIC_KEYS = ("version", "vmem_budget_bytes", "allowlist")
# eligibility_matrix.json: site × config fused/reference matrix
ELIGIBILITY_KEYS = ("version", "stamp", "configs")
ELIGIBILITY_CELL_KEYS = ("status", "kernel", "wiring", "layers", "reasons")


def _check_bench(doc: dict, errs: list) -> None:
    for row, keys in BENCH_ROWS.items():
        if row not in doc:
            errs.append(f"bench: missing row {row!r}")
            continue
        for k in keys:
            if k not in doc[row]:
                errs.append(f"bench: {row}.{k} missing")
        for pk in ("ttft_s", "latency_s"):
            if isinstance(doc[row].get(pk), dict):
                for q in PERCENTILE_KEYS:
                    if q not in doc[row][pk]:
                        errs.append(f"bench: {row}.{pk}.{q} missing")
    router = doc.get("moe_arctic", {}).get("router")
    if isinstance(router, dict):
        for k in ROUTER_KEYS:
            if k not in router:
                errs.append(f"bench: moe_arctic.router.{k} missing")
    for k in BENCH_SCALARS:
        if k not in doc:
            errs.append(f"bench: missing scalar {k!r}")


def _check_metrics(doc: dict, errs: list) -> None:
    for sec in METRIC_SECTIONS:
        if sec not in doc:
            errs.append(f"metrics: missing section {sec!r}")
    counters = doc.get("counters", {})
    for name in METRIC_COUNTERS:
        if name not in counters:
            errs.append(f"metrics: counter {name!r} missing")
    hists = doc.get("histograms", {})
    for name in METRIC_HISTOGRAMS:
        if name not in hists:
            errs.append(f"metrics: histogram {name!r} missing")
        else:
            for f in HISTOGRAM_FIELDS:
                if f not in hists[name]:
                    errs.append(f"metrics: histogram {name}.{f} missing")


def _check_trace(doc: dict, errs: list) -> None:
    for k in TRACE_KEYS:
        if k not in doc:
            errs.append(f"trace: missing key {k!r}")
    evs = doc.get("traceEvents", [])
    if not evs:
        errs.append("trace: traceEvents is empty")
    for i, ev in enumerate(evs):
        # metadata records ("M": process/thread names) carry no timestamp
        keys = TRACE_EVENT_KEYS if ev.get("ph") != "M" else ("ph", "name",
                                                             "pid", "tid")
        for k in keys:
            if k not in ev:
                errs.append(f"trace: event[{i}] missing {k!r}")
                break
        if ev.get("ph") == "X" and "dur" not in ev:
            errs.append(f"trace: complete event[{i}] missing 'dur'")


def _check_static(doc: dict, errs: list) -> None:
    for k in STATIC_KEYS:
        if k not in doc:
            errs.append(f"static: missing key {k!r}")
    allow = doc.get("allowlist", [])
    if not isinstance(allow, list):
        errs.append("static: allowlist is not a list")
        return
    for i, key in enumerate(allow):
        # stable key shape: CODE:path:scope#ordinal
        parts = str(key).split(":", 2)
        if len(parts) != 3 or "#" not in parts[2]:
            errs.append(f"static: allowlist[{i}] {key!r} is not "
                        f"CODE:path:scope#ordinal")


def _check_eligibility(doc: dict, errs: list) -> None:
    for k in ELIGIBILITY_KEYS:
        if k not in doc:
            errs.append(f"eligibility: missing key {k!r}")
    for cfg, sites in doc.get("configs", {}).items():
        for site, cell in sites.items():
            for k in ELIGIBILITY_CELL_KEYS:
                if k not in cell:
                    errs.append(f"eligibility: {cfg}.{site}.{k} missing")
            status = cell.get("status")
            if status not in ("fused", "reference"):
                errs.append(f"eligibility: {cfg}.{site}.status {status!r}")
            if status == "reference" and not cell.get("reasons"):
                errs.append(f"eligibility: {cfg}.{site} reference cell "
                            f"carries no reasons")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, metavar="PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH")
    ap.add_argument("--trace", default=None, metavar="PATH")
    ap.add_argument("--static", default=None, metavar="PATH")
    ap.add_argument("--eligibility", default=None, metavar="PATH")
    args = ap.parse_args()
    if not (args.bench or args.metrics or args.trace or args.static
            or args.eligibility):
        ap.error("nothing to check: pass --bench/--metrics/--trace/"
                 "--static/--eligibility")
    errs: list = []
    for path, fn, label in ((args.bench, _check_bench, "bench"),
                            (args.metrics, _check_metrics, "metrics"),
                            (args.trace, _check_trace, "trace"),
                            (args.static, _check_static, "static"),
                            (args.eligibility, _check_eligibility,
                             "eligibility")):
        if path is None:
            continue
        with open(path) as f:
            fn(json.load(f), errs)
        print(f"[schema] {label}: {path} "
              f"{'OK' if not any(e.startswith(label) for e in errs) else 'FAIL'}")
    for e in errs:
        print(f"[schema] {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a kernel-throughput suite)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    modules = [
        "benchmarks.table1_lvm",
        "benchmarks.table2_llm",
        "benchmarks.table3_overhead",
        "benchmarks.fig4b_tokens",
        "benchmarks.fig7_combinations",
        "benchmarks.table4_sites",
        "benchmarks.fig3_energy",
        "benchmarks.kernels_bench",
    ]
    print("name,us_per_call,derived")
    failed = []
    for mod_name in modules:
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()

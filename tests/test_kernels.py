"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


class TestHaarDWT:
    @pytest.mark.parametrize("shape", [(1, 64, 128), (2, 128, 256),
                                       (3, 256, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("levels", [1, 3])
    def test_forward(self, shape, dtype, levels):
        x = rand(shape, dtype)
        y = ops.haar_dwt_seq(x, levels=levels, interpret=True)
        yr = ref.haar_dwt_ref(x.astype(jnp.float32), levels=levels)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(yr, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype])

    @pytest.mark.parametrize("levels", [1, 2, 4])
    def test_inverse_roundtrip(self, levels):
        x = rand((2, 128, 128), seed=1)
        y = ops.haar_dwt_seq(x, levels=levels, interpret=True)
        back = ops.haar_dwt_seq(y, levels=levels, inverse=True,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=1e-5)

    def test_vmem_block_autoshrink(self):
        # long sequence → block_d shrinks to keep the tile inside VMEM
        x = rand((1, 16384, 16), seed=2)
        y = ops.haar_dwt_seq(x, levels=3, interpret=True)
        yr = ref.haar_dwt_ref(x, levels=3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


class TestWHT:
    @pytest.mark.parametrize("axis", [-2, -1])
    @pytest.mark.parametrize("shape", [(2, 128, 256), (1, 64, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, axis, shape, dtype):
        x = rand(shape, dtype, seed=3)
        y = ops.walsh_hadamard(x, axis=axis, interpret=True)
        yr = ref.wht_ref(x.astype(jnp.float32), axis=axis)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(yr, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype])

    def test_involution(self):
        x = rand((2, 128, 128), seed=4)
        y = ops.walsh_hadamard(ops.walsh_hadamard(x, interpret=True),
                               interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


class TestQuantPack:
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("shape", [(2, 256, 128), (1, 512, 64)])
    def test_matches_ref(self, bits, shape):
        x = rand(shape, seed=5)
        p, s, z = ops.quantize_pack(x, bits=bits, interpret=True)
        pr, sr, zr = ref.quant_pack_ref(x, bits=bits)
        np.testing.assert_array_equal(np.asarray(p, np.int32),
                                      np.asarray(pr, np.int32))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_dequant_error_within_half_step(self):
        x = rand((1, 128, 64), seed=6)
        p, s, z = ops.quantize_pack(x, bits=4, interpret=True)
        deq = ref.unpack_dequant_ref(p, s, z, bits=4)
        assert float(jnp.max(jnp.abs(deq - x))) <= float(jnp.max(s)) / 2 + 1e-6


class TestInt8Matmul:
    @pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 384),
                                     (128, 256, 512)])
    def test_matches_ref(self, mnk):
        m, n, k = mnk
        rng = np.random.default_rng(7)
        qx = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
        qw = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
        sx = jnp.asarray(rng.uniform(0.01, 0.1, (m, 1)).astype(np.float32))
        zx = jnp.asarray(rng.integers(0, 16, (m, 1)).astype(np.float32))
        sw = jnp.asarray(rng.uniform(0.01, 0.1, (1, n)).astype(np.float32))
        zw = jnp.asarray(rng.integers(0, 16, (1, n)).astype(np.float32))
        y = ops.int8_matmul(qx, qw, sx, zx, sw, zw, out_dtype=jnp.float32,
                            interpret=True)
        yr = ref.int8_matmul_ref(qx, qw, sx, zx, sw, zw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)

    def test_quantize_then_matmul_approximates_float(self):
        """The full W4A8 path ≈ the float matmul it replaces."""
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.normal(size=(1, 128, 256)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32) * 0.05)
        qx, sx, zx = ops.quantize_pack(x, bits=8, interpret=True)
        # weight: per-column 4-bit
        n = 15.0
        mn, mx = w.min(0, keepdims=True), w.max(0, keepdims=True)
        swt = jnp.maximum((mx - mn) / n, 1e-8)
        zwt = jnp.round(-mn / swt)
        qw = jnp.clip(jnp.round(w / swt) + zwt, 0, n).astype(jnp.int8)
        y = ops.int8_matmul(qx[0], qw, sx[0], zx[0], swt, zwt,
                            out_dtype=jnp.float32, interpret=True)
        ref_y = x[0] @ w
        rel = float(jnp.linalg.norm(y - ref_y) / jnp.linalg.norm(ref_y))
        assert rel < 0.15   # W4 weight noise dominates (step/2 ≈ 9% rel)
        # W8A8 must be near-exact
        n8 = 255.0
        sw8 = jnp.maximum((mx - mn) / n8, 1e-8)
        zw8 = jnp.round(-mn / sw8)
        qw8 = (jnp.clip(jnp.round(w / sw8) + zw8, 0, n8) - 128).astype(jnp.int8)
        y8 = ops.int8_matmul(qx[0], qw8, sx[0], zx[0], sw8, zw8 - 128,
                             out_dtype=jnp.float32, interpret=True)
        rel8 = float(jnp.linalg.norm(y8 - ref_y) / jnp.linalg.norm(ref_y))
        assert rel8 < 0.02


class TestCacheAttention:
    """Fused decode attention over the packed mixed-precision cache vs the
    dequantize-then-attend oracle."""

    @pytest.mark.parametrize("shape", [
        # (b, s, g, hd, h, num_hi, block_s)
        (2, 288, 2, 64, 8, 32, 64),
        (1, 576, 4, 128, 8, 64, 128),
        (2, 160, 2, 64, 4, 32, 128),
    ])
    def test_matches_dequant_oracle(self, shape):
        from repro.serving import kvcache as KV
        from repro.kernels.cache_attention import cache_decode_attention
        from repro.models.layers import decode_attention
        b, s, g, hd, h, num_hi, bs = shape
        rng = np.random.default_rng(42)
        cfg = KV.KVCacheConfig(quantized=True, num_hi=num_hi)
        k = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
        entry = KV.quantize_full(k, v, cfg)
        q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
        length = jnp.asarray([s - 17], jnp.int32)
        out = cache_decode_attention(entry, q, length, block_s=bs,
                                     interpret=True)
        kf, vf = KV.dequantize_full(entry, cfg, jnp.float32)
        ref_out = decode_attention(q, kf, vf, length=length)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   atol=2e-2, rtol=2e-2)

"""Hybrid (Mamba + attention) serving under the paged engine: the
slot-dense SSM state pool next to the paged KV cache.

Pins the PR-5 contracts:

* masked decode — inactive slots (null tokens) leave per-slot conv/SSM
  state bit-for-bit untouched;
* chunked prefill carries conv/SSM state across chunk boundaries (incl.
  window-unaligned final chunks) and matches the one-shot prefill;
* the bucketed engine's right-padding no longer advances the Mamba
  recurrence with pad tokens (the pad-state audit fix);
* preemption + resume swap the SSM slot state with the victim's pages and
  restore bit-identically;
* the reduced Jamba config serves token-identically across
  BucketedEngine / unified / two-call paged modes, including a forced
  preemption, at exactly one device dispatch per unified step;
* pure-SSM stacks serve pageless (slots are the only capacity dimension);
* capability checks fail with actionable errors (enc-dec, missing
  num_slots, the serve CLI).
"""

import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_reduced
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.serving.engine import (BucketedEngine, EngineConfig,
                                  PagedEngineConfig, PagedServingEngine)

ROOT = pathlib.Path(__file__).resolve().parents[1]

# small fast hybrid: period (mamba, attn), both FFN'd — every state family
# in four layers
HCFG = ModelConfig(name="hybrid-test", family="hybrid", num_layers=4,
                   d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                   vocab_size=128, attn_period=2, ssm_state=16,
                   ssm_head_dim=16)
SCFG = ModelConfig(name="ssm-test", family="ssm", num_layers=3,
                   d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                   vocab_size=128, ssm_state=16, ssm_head_dim=16,
                   tie_embeddings=True)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)


@pytest.fixture(scope="module")
def hparams():
    return lm.init_params(jax.random.PRNGKey(0), HCFG)


@pytest.fixture(scope="module")
def sparams():
    return lm.init_params(jax.random.PRNGKey(1), SCFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(3)
    return [rng.integers(0, 128, l) for l in (20, 40, 12, 33, 26)]


def paged_cfg(**kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def run_engine(engine, prompts, max_new):
    for p, m in zip(prompts, max_new):
        engine.submit(p, m)
    done = engine.run()
    lm.set_fused_cache_attention(False)
    lm.set_fused_decode_matmul(False)
    return {r.uid: r.out_tokens for r in done}


def hybrid_pools(cfg, num_slots=3):
    pcfg = PKV.PagedCacheConfig(block_size=16, num_lo_blocks=8,
                                num_hi_blocks=4, max_blocks_per_seq=5,
                                quant=QUANT)
    return lm.init_paged_cache(cfg, pcfg, num_slots=num_slots), pcfg


# ---------------------------------------------------------------------------
# slot pool plumbing
# ---------------------------------------------------------------------------


class TestSSMStatePool:
    def test_pool_shapes_and_null_slot(self):
        pools, _ = hybrid_pools(HCFG)
        ssm = [v for v in pools.values() if PKV.is_ssm_entry(v)]
        attn = [v for v in pools.values() if not PKV.is_ssm_entry(v)]
        assert len(ssm) == 1 and len(attn) == 1   # period = (mamba, attn)
        entry = ssm[0]
        nper = HCFG.num_layers // 2
        # num_slots + 1: the last row is the null slot (scatter target for
        # unused prefill chunk rows)
        assert entry["state"].shape == (nper, 4, 8, 16, 16)
        assert entry["conv"].shape == (nper, 4, HCFG.conv_width - 1,
                                       HCFG.d_inner + 2 * HCFG.ssm_state)

    def test_state_bytes_per_slot_analytic(self):
        pools, _ = hybrid_pools(HCFG)
        nper = HCFG.num_layers // 2
        di, n = HCFG.d_inner, HCFG.ssm_state
        state = nper * HCFG.ssm_heads * HCFG.ssm_head_dim * n * 4
        conv = nper * (HCFG.conv_width - 1) * (di + 2 * n) * 2
        assert PKV.ssm_state_bytes_per_slot(pools) == state + conv

    def test_swap_roundtrip_with_ssm_state(self):
        """extract -> zero the row -> insert at a DIFFERENT slot restores
        the state bit-identically (the preemption/resume contract)."""
        pools, _ = hybrid_pools(HCFG)
        rng = np.random.default_rng(0)
        key = next(k for k, v in pools.items() if PKV.is_ssm_entry(v))
        entry = dict(pools[key])
        entry["state"] = jnp.asarray(
            rng.normal(size=pools[key]["state"].shape).astype(np.float32))
        entry["conv"] = jnp.asarray(
            rng.normal(size=pools[key]["conv"].shape)).astype(jnp.bfloat16)
        pools[key] = entry
        saved = PKV.extract_pages(pools, [1], [1, 2], slot=1)
        restored = PKV.insert_pages(pools, saved, [2], [3, 4], slot=2)
        for name in ("state", "conv"):
            np.testing.assert_array_equal(
                np.asarray(restored[key][name][:, 2]),
                np.asarray(pools[key][name][:, 1]))

    def test_swap_without_slot_raises(self):
        pools, _ = hybrid_pools(HCFG)
        with pytest.raises(ValueError, match="slot"):
            PKV.extract_pages(pools, [1], [1])
        with pytest.raises(ValueError, match="slot"):
            PKV.insert_pages(pools, {}, [1], [1])


# ---------------------------------------------------------------------------
# masked decode (satellite: null tokens must not advance the recurrence)
# ---------------------------------------------------------------------------


class TestMaskedDecode:
    def _decode(self, params, pools, active):
        s = len(active)
        z = jnp.zeros((s,), jnp.int32)
        ht = jnp.zeros((s, 1), jnp.int32)
        lt = jnp.zeros((s, 2), jnp.int32)
        serve = lm.ServeConfig(stamp=None,
                               kv=KV.KVCacheConfig(quantized=False))
        serve = dataclasses.replace(
            serve, paged=PKV.PagedCacheConfig(
                block_size=16, num_lo_blocks=4, num_hi_blocks=1,
                max_blocks_per_seq=2,
                quant=KV.KVCacheConfig(quantized=False)))
        _, new_pools = lm.paged_decode_step(
            params, pools, z, z, ht, lt, z, z,
            jnp.zeros((s,), bool), SCFG, serve,
            active=jnp.asarray(active))
        return new_pools

    def test_inactive_slots_keep_state_bit_identical(self, sparams):
        """A step where no slot is RUNNING (all tokens are null pads) must
        be a no-op on every conv/SSM state row — previously the recurrence
        advanced with the pad-token garbage."""
        pcfg = PKV.PagedCacheConfig(
            block_size=16, num_lo_blocks=4, num_hi_blocks=1,
            max_blocks_per_seq=2, quant=KV.KVCacheConfig(quantized=False))
        pools = lm.init_paged_cache(SCFG, pcfg, num_slots=3)
        new_pools = self._decode(sparams, pools, [False, False, False])
        for k, entry in pools.items():
            for name in ("state", "conv"):
                np.testing.assert_array_equal(np.asarray(entry[name]),
                                              np.asarray(new_pools[k][name]))

    def test_active_slot_advances_only_its_row(self, sparams):
        pcfg = PKV.PagedCacheConfig(
            block_size=16, num_lo_blocks=4, num_hi_blocks=1,
            max_blocks_per_seq=2, quant=KV.KVCacheConfig(quantized=False))
        pools = lm.init_paged_cache(SCFG, pcfg, num_slots=3)
        new_pools = self._decode(sparams, pools, [False, True, False])
        key = next(iter(pools))
        st_old = np.asarray(pools[key]["state"])
        st_new = np.asarray(new_pools[key]["state"])
        assert not np.array_equal(st_old[:, 1], st_new[:, 1])
        np.testing.assert_array_equal(st_old[:, 0], st_new[:, 0])
        np.testing.assert_array_equal(st_old[:, 2], st_new[:, 2])
        np.testing.assert_array_equal(st_old[:, 3], st_new[:, 3])  # null


# ---------------------------------------------------------------------------
# stateful chunked prefill (satellite: state carry vs one-shot parity)
# ---------------------------------------------------------------------------


class TestChunkedPrefillStateCarry:
    def test_chunked_state_matches_one_shot(self, hparams):
        """Prefill a 33-token prompt in 16-token chunks (the final chunk
        end is window-unaligned) through the two-call path; the slot's
        conv/SSM state must match the one-shot dense prefill of the same
        prompt (the state a decode step continues from)."""
        rng = np.random.default_rng(7)
        prompt = rng.integers(0, HCFG.vocab_size, 33)
        serve_d = lm.ServeConfig(stamp=None, kv=QUANT, cache_capacity=64)
        _, dense_cache = lm.prefill(
            hparams, {"tokens": jnp.asarray(prompt[None])}, HCFG, serve_d)

        # max_new=1: the first token comes from the prefill logits and the
        # request finishes before any decode step, so the slot holds the
        # post-prompt state — the object under test
        eng = PagedServingEngine(
            hparams, HCFG, lm.ServeConfig(stamp=None, kv=QUANT),
            paged_cfg(max_slots=2, step_mode="two_call"))
        eng.submit(prompt, 1)
        eng.run()
        key = next(k for k, v in eng.pools.items() if PKV.is_ssm_entry(v))
        got = np.asarray(eng.pools[key]["state"][:, 0])   # slot 0
        want = np.asarray(dense_cache[key]["state"][:, 0])
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)
        # the conv tail is the layer-input activation at the last 3 valid
        # positions: later layers see the (tiny) cross-chunk attention/SSD
        # reduction differences of earlier ones, so the comparison is
        # approximate rather than bitwise (bf16 magnitudes ~1, drift <0.1)
        got_c = np.asarray(eng.pools[key]["conv"][:, 0], np.float32)
        want_c = np.asarray(dense_cache[key]["conv"][:, 0], np.float32)
        np.testing.assert_allclose(got_c, want_c, rtol=1e-1, atol=1e-1)

    def test_single_chunk_prompt_is_bit_identical(self, hparams, prompts):
        """Prompts that fit one prefill chunk: paged (unified) and
        bucketed tokens must be EQUAL, not just close — chunk width ==
        bucket width makes every per-row computation identical."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        short = prompts[:3]
        max_new = (8, 8, 8)
        buck = run_engine(
            BucketedEngine(hparams, HCFG, serve,
                           EngineConfig(max_batch=3, bucket=64, max_seq=96)),
            short, max_new)
        uni = run_engine(
            PagedServingEngine(hparams, HCFG, serve,
                               paged_cfg(prefill_chunk=64)),
            short, max_new)
        for uid in buck:
            np.testing.assert_array_equal(buck[uid], uni[uid],
                                          err_msg=f"uid={uid}")


class TestBucketedPadMask:
    def test_padded_prefill_state_matches_unpadded(self, hparams):
        """The pad-state audit fix: right-padding a hybrid prompt must not
        advance the Mamba recurrence past the prompt's last token —
        prefill(last_pos=) now masks dt and slices the conv tail at the
        valid boundary, so the padded state equals the unpadded one."""
        rng = np.random.default_rng(9)
        prompt = rng.integers(0, HCFG.vocab_size, 21)
        serve = lm.ServeConfig(stamp=None, kv=QUANT, cache_capacity=64)
        padded = np.zeros((1, 32), np.int32)
        padded[0, :21] = prompt
        lg_p, cache_p = lm.prefill(hparams,
                                   {"tokens": jnp.asarray(padded)}, HCFG,
                                   serve, last_pos=jnp.asarray([20]))
        lg_u, cache_u = lm.prefill(hparams,
                                   {"tokens": jnp.asarray(prompt[None])},
                                   HCFG, serve)
        key = next(k for k in cache_p if "state" in cache_p[k])
        np.testing.assert_allclose(np.asarray(cache_p[key]["state"]),
                                   np.asarray(cache_u[key]["state"]),
                                   rtol=2e-2, atol=2e-3)
        np.testing.assert_allclose(
            np.asarray(cache_p[key]["conv"], np.float32),
            np.asarray(cache_u[key]["conv"], np.float32),
            rtol=2e-2, atol=2e-3)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_u),
                                   rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# engine-level parity (acceptance: reduced Jamba, forced preemption)
# ---------------------------------------------------------------------------


class TestHybridUnifiedParity:
    def test_unified_vs_two_call_under_contention(self, hparams, prompts):
        """Multi-chunk prompts, staggered admission (5 requests, 3 slots)
        and a lo pool tight enough to preempt: the unified hybrid step must
        reproduce the two-call engine token for token — SSM state carry,
        masked decode and the state swap all inside one device program."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        max_new = (14, 10, 16, 8, 12)
        out = {}
        for mode in ("two_call", "unified"):
            eng = PagedServingEngine(
                hparams, HCFG, serve,
                paged_cfg(max_slots=5, num_lo_blocks=6, step_mode=mode))
            out[mode] = (run_engine(eng, prompts, max_new), eng)
        two, _ = out["two_call"]
        uni, eng = out["unified"]
        assert eng.stats["preemptions"] > 0
        assert eng.stats["swap_bytes"] > 0
        assert eng.stats["device_dispatches"] == eng.stats["steps"]
        for uid in two:
            np.testing.assert_array_equal(two[uid], uni[uid],
                                          err_msg=f"uid={uid}")


class TestReducedJambaAcceptance:
    @pytest.fixture(scope="class")
    def jamba(self):
        cfg = get_reduced("jamba-1.5-large-398b")
        return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)

    def test_paged_matches_bucketed_with_forced_preemption(self, jamba):
        """The acceptance workload: the reduced Jamba hybrid config (MoE +
        Mamba + attention) serves bit-identical tokens through
        BucketedEngine and both paged step modes, the paged runs include a
        forced preemption + resume, and the unified run dispatches exactly
        one device program per step."""
        cfg, params = jamba
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        rng = np.random.default_rng(5)
        reqs = [rng.integers(0, cfg.vocab_size, l) for l in (20, 33, 12)]
        max_new = (8, 8, 8)
        buck = run_engine(
            BucketedEngine(params, cfg, serve,
                           EngineConfig(max_batch=3, bucket=64, max_seq=96)),
            reqs, max_new)
        outs = {}
        for mode in ("unified", "two_call"):
            eng = PagedServingEngine(
                params, cfg, serve,
                paged_cfg(prefill_chunk=64, num_lo_blocks=4,
                          step_mode=mode))
            outs[mode] = run_engine(eng, reqs, max_new)
            assert eng.stats["preemptions"] > 0, mode
            kinds = [k for _, k, _ in eng.events]
            assert "preempt" in kinds and "resume" in kinds
            if mode == "unified":
                assert eng.stats["device_dispatches"] == eng.stats["steps"]
        for uid in buck:
            np.testing.assert_array_equal(buck[uid], outs["unified"][uid],
                                          err_msg=f"uid={uid}")
            np.testing.assert_array_equal(buck[uid], outs["two_call"][uid],
                                          err_msg=f"uid={uid}")


class TestPureSSM:
    def test_pageless_serving_matches_bucketed(self, sparams, prompts):
        """A stack with no attention layers allocates no pages at all
        (needs_kv_pages=False): slots are the only capacity dimension, and
        tokens match the bucketed oracle."""
        serve = lm.ServeConfig(stamp=None,
                               kv=KV.KVCacheConfig(quantized=False))
        short = prompts[:3]
        max_new = (6, 6, 6)
        buck = run_engine(
            BucketedEngine(sparams, SCFG, serve,
                           EngineConfig(max_batch=3, bucket=64, max_seq=96)),
            short, max_new)
        eng = PagedServingEngine(sparams, SCFG, serve,
                                 paged_cfg(prefill_chunk=64))
        paged = run_engine(eng, short, max_new)
        for uid in buck:
            np.testing.assert_array_equal(buck[uid], paged[uid],
                                          err_msg=f"uid={uid}")
        active = [r for r in eng.sched.active]
        assert eng.sched.cfg.needs_kv_pages is False
        assert eng.sched.cfg.state_bytes_per_slot > 0
        assert not active or all(
            not (r.hi_pages or r.lo_pages) for r in active)

    def test_more_requests_than_slots(self, sparams, prompts):
        """Slot turnover without pages: admission waves drain the queue."""
        serve = lm.ServeConfig(stamp=None,
                               kv=KV.KVCacheConfig(quantized=False))
        eng = PagedServingEngine(sparams, SCFG, serve,
                                 paged_cfg(max_slots=2, prefill_chunk=64))
        out = run_engine(eng, prompts, (6, 6, 6, 6, 6))
        assert len(out) == 5
        assert all(len(v) == 6 for v in out.values())


# ---------------------------------------------------------------------------
# capability checks (satellite: actionable errors + CLI smoke)
# ---------------------------------------------------------------------------


class TestCapability:
    def test_hybrid_without_num_slots_raises(self):
        pcfg = PKV.PagedCacheConfig(quant=QUANT)
        with pytest.raises(ValueError, match="num_slots"):
            lm.init_paged_cache(HCFG, pcfg)

    def test_encdec_raises_actionable(self):
        cfg = ModelConfig(name="encdec", family="audio", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=128, encoder_layers=2,
                          frontend="frames")
        with pytest.raises(NotImplementedError, match="BucketedEngine"):
            lm.init_paged_cache(cfg, PKV.PagedCacheConfig(quant=QUANT),
                                num_slots=2)

    def test_engine_rejects_encdec_before_allocation(self):
        cfg = ModelConfig(name="encdec", family="audio", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=128, encoder_layers=2,
                          frontend="frames")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(NotImplementedError, match="bucketed"):
            PagedServingEngine(params, cfg,
                               lm.ServeConfig(stamp=None, kv=QUANT),
                               paged_cfg())


class TestServeCLI:
    def _env(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        return env

    def test_paged_encdec_fails_fast_with_fix(self):
        """The CLI must reject --engine paged on an enc-dec arch at the
        argument boundary (not five frames deep in cache init), naming the
        working alternative."""
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "seamless-m4t-large-v2", "--reduced", "--engine", "paged",
             "--requests", "1", "--max-new", "1"],
            env=self._env(), capture_output=True, text=True, timeout=120)
        assert p.returncode != 0
        assert "bucketed" in p.stderr

    def test_paged_serves_pure_ssm_end_to_end(self):
        """PR-5 smoke: `--engine paged` on the mamba2 reduced config used
        to die inside init_paged_cache; now it serves."""
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "mamba2-1.3b", "--reduced", "--engine", "paged",
             "--requests", "2", "--prompt-len", "24", "--max-new", "4",
             "--prefill-chunk", "32"],
            env=self._env(), capture_output=True, text=True, timeout=900)
        assert p.returncode == 0, p.stderr[-2000:]
        assert "[serve:paged" in p.stdout

"""Per-architecture smoke tests (reduced configs) + serving equivalence.

Every assigned arch instantiates a REDUCED same-family config and runs one
forward/train step on CPU asserting output shapes and finiteness; serving
paths check prefill+decode against the full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models import lm
from repro.models.config import SHAPES, shape_applicable
from repro.core.stamp import StampConfig
from repro.serving.kvcache import KVCacheConfig

jax.config.update("jax_platform_name", "cpu")

SMOKE_ARCHS = [a for a in ARCHS if a != "pixart_sigma"]


def make_batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.frontend == "patch":
        s_txt = s - cfg.num_patches
        batch["tokens"] = batch["tokens"][:, :s_txt]
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.bfloat16)
        labels = np.asarray(batch["labels"]).copy()
        labels[:, :cfg.num_patches] = -1
        batch["labels"] = jnp.asarray(labels)
    if cfg.frontend == "frames" or cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s // cfg.frame_ratio, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        loss = lm.train_loss(params, batch, cfg)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        grads = jax.grad(lambda p: lm.train_loss(p, batch, cfg))(params)
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(l, np.float32)).all()
                   for l in leaves), f"{arch}: non-finite grads"

    def test_hidden_shape(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        x, _, _ = lm.model_hidden(params, batch, cfg, mode="train",
                                  policy=None, remat=False)
        assert x.shape[0] == 2 and x.shape[-1] == cfg.d_model
        assert np.isfinite(np.asarray(x, np.float32)).all()

    def test_prefill_decode(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        serve = lm.ServeConfig(stamp=StampConfig(num_hi_tokens=8),
                               kv=KVCacheConfig(num_hi=8))
        logits, cache = lm.prefill(params, batch, cfg, serve)
        assert logits.shape == (2, cfg.padded_vocab)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache2 = lm.decode_step(params, cache, tok, jnp.int32(64),
                                         cfg, serve)
        assert np.isfinite(np.asarray(logits2)).all()


class TestShapeMatrix:
    def test_40_cells_defined(self):
        cells = [(a, s) for a in SMOKE_ARCHS[:10] for s in SHAPES]
        assert len(cells) == 40

    def test_long_500k_rules(self):
        skipped = []
        for arch in SMOKE_ARCHS[:10]:
            cfg = get_reduced(arch)
            ok, why = shape_applicable(cfg, SHAPES["long_500k"])
            if not ok:
                skipped.append(arch)
        assert len(skipped) == 8   # all but jamba + mamba2
        assert "jamba_1_5_large_398b" not in skipped
        assert "mamba2_1_3b" not in skipped


class TestServingEquivalence:
    def test_unquantized_decode_matches_full_forward(self):
        """prefill(s tokens) + decode(token s) ≡ forward(s+1 tokens)."""
        cfg = get_reduced("llama3_8b")
        params = lm.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (1, 65)).astype(np.int32)
        serve = lm.ServeConfig(stamp=None, kv=KVCacheConfig(quantized=False),
                               weight_bits=None, cache_capacity=80)
        _, cache = lm.prefill(params, {"tokens": jnp.asarray(toks[:, :64])},
                              cfg, serve)
        logits_dec, _ = lm.decode_step(params, cache,
                                       jnp.asarray(toks[:, 64]),
                                       jnp.int32(64), cfg, serve)
        x, _, _ = lm.model_hidden(params, {"tokens": jnp.asarray(toks)},
                                  cfg, mode="train", policy=None, remat=False)
        from repro.models.layers import rms_norm
        logits_full = (x[:, -1] @ lm._head_weight(params).astype(x.dtype)
                       ).astype(jnp.float32)
        # model_hidden applies final_norm already
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full),
                                   rtol=0.1, atol=0.15)

    def test_quantized_cache_close_to_bf16_cache(self):
        cfg = get_reduced("llama3_8b")
        params = lm.init_params(jax.random.PRNGKey(2), cfg)
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                           jnp.int32)
        ref_serve = lm.ServeConfig(stamp=None,
                                   kv=KVCacheConfig(quantized=False),
                                   weight_bits=None, cache_capacity=80)
        q_serve = lm.ServeConfig(stamp=None,
                                 kv=KVCacheConfig(quantized=True, num_hi=16),
                                 weight_bits=None, cache_capacity=80)
        _, c_ref = lm.prefill(params, {"tokens": toks}, cfg, ref_serve)
        _, c_q = lm.prefill(params, {"tokens": toks}, cfg, q_serve)
        tok = jnp.zeros((2,), jnp.int32)
        l_ref, _ = lm.decode_step(params, c_ref, tok, jnp.int32(64), cfg,
                                  ref_serve)
        l_q, _ = lm.decode_step(params, c_q, tok, jnp.int32(64), cfg,
                                q_serve)
        ref_n = np.asarray(l_ref)
        rel = np.abs(np.asarray(l_q) - ref_n).max() / \
            (np.abs(ref_n).max() + 1e-9)
        assert rel < 0.25, f"quantized cache diverges: {rel}"

    def test_weight_pack_roundtrip(self):
        w = jnp.asarray(np.random.default_rng(3).normal(size=(64, 32)),
                        jnp.float32)
        packed = lm.pack_weight(w, bits=4)
        deq = lm._dequant_packed(packed, jnp.float32)
        rel = float(jnp.linalg.norm(deq - w) / jnp.linalg.norm(w))
        assert rel < 0.12

"""Per-architecture smoke tests (reduced configs) + serving equivalence.

Every assigned arch instantiates a REDUCED same-family config and runs one
forward/train step on CPU asserting output shapes and finiteness; serving
paths check prefill+decode against the full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models import lm
from repro.models.config import SHAPES, shape_applicable
from repro.core.stamp import StampConfig
from repro.serving.kvcache import KVCacheConfig

jax.config.update("jax_platform_name", "cpu")

SMOKE_ARCHS = [a for a in ARCHS if a != "pixart_sigma"]


def make_batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.frontend == "patch":
        s_txt = s - cfg.num_patches
        batch["tokens"] = batch["tokens"][:, :s_txt]
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.bfloat16)
        labels = np.asarray(batch["labels"]).copy()
        labels[:, :cfg.num_patches] = -1
        batch["labels"] = jnp.asarray(labels)
    if cfg.frontend == "frames" or cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s // cfg.frame_ratio, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        loss = lm.train_loss(params, batch, cfg)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        grads = jax.grad(lambda p: lm.train_loss(p, batch, cfg))(params)
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(l, np.float32)).all()
                   for l in leaves), f"{arch}: non-finite grads"

    def test_hidden_shape(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        x, _, _ = lm.model_hidden(params, batch, cfg, mode="train",
                                  policy=None, remat=False)
        assert x.shape[0] == 2 and x.shape[-1] == cfg.d_model
        assert np.isfinite(np.asarray(x, np.float32)).all()

    def test_prefill_decode(self, arch, arch_setup):
        cfg, params = arch_setup(arch)
        batch = make_batch(cfg)
        serve = lm.ServeConfig(stamp=StampConfig(num_hi_tokens=8),
                               kv=KVCacheConfig(num_hi=8))
        logits, cache = lm.prefill(params, batch, cfg, serve)
        assert logits.shape == (2, cfg.padded_vocab)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache2 = lm.decode_step(params, cache, tok, jnp.int32(64),
                                         cfg, serve)
        assert np.isfinite(np.asarray(logits2)).all()


class TestShapeMatrix:
    def test_40_cells_defined(self):
        cells = [(a, s) for a in SMOKE_ARCHS[:10] for s in SHAPES]
        assert len(cells) == 40

    def test_long_500k_rules(self):
        skipped = []
        for arch in SMOKE_ARCHS[:10]:
            cfg = get_reduced(arch)
            ok, why = shape_applicable(cfg, SHAPES["long_500k"])
            if not ok:
                skipped.append(arch)
        assert len(skipped) == 8   # all but jamba + mamba2
        assert "jamba_1_5_large_398b" not in skipped
        assert "mamba2_1_3b" not in skipped


class TestServingEquivalence:
    def test_unquantized_decode_matches_full_forward(self):
        """prefill(s tokens) + decode(token s) ≡ forward(s+1 tokens)."""
        cfg = get_reduced("llama3_8b")
        params = lm.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (1, 65)).astype(np.int32)
        serve = lm.ServeConfig(stamp=None, kv=KVCacheConfig(quantized=False),
                               weight_bits=None, cache_capacity=80)
        _, cache = lm.prefill(params, {"tokens": jnp.asarray(toks[:, :64])},
                              cfg, serve)
        logits_dec, _ = lm.decode_step(params, cache,
                                       jnp.asarray(toks[:, 64]),
                                       jnp.int32(64), cfg, serve)
        x, _, _ = lm.model_hidden(params, {"tokens": jnp.asarray(toks)},
                                  cfg, mode="train", policy=None, remat=False)
        from repro.models.layers import rms_norm
        logits_full = (x[:, -1] @ lm._head_weight(params).astype(x.dtype)
                       ).astype(jnp.float32)
        # model_hidden applies final_norm already
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full),
                                   rtol=0.1, atol=0.15)

    def test_quantized_cache_close_to_bf16_cache(self):
        cfg = get_reduced("llama3_8b")
        params = lm.init_params(jax.random.PRNGKey(2), cfg)
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                           jnp.int32)
        ref_serve = lm.ServeConfig(stamp=None,
                                   kv=KVCacheConfig(quantized=False),
                                   weight_bits=None, cache_capacity=80)
        q_serve = lm.ServeConfig(stamp=None,
                                 kv=KVCacheConfig(quantized=True, num_hi=16),
                                 weight_bits=None, cache_capacity=80)
        _, c_ref = lm.prefill(params, {"tokens": toks}, cfg, ref_serve)
        _, c_q = lm.prefill(params, {"tokens": toks}, cfg, q_serve)
        tok = jnp.zeros((2,), jnp.int32)
        l_ref, _ = lm.decode_step(params, c_ref, tok, jnp.int32(64), cfg,
                                  ref_serve)
        l_q, _ = lm.decode_step(params, c_q, tok, jnp.int32(64), cfg,
                                q_serve)
        ref_n = np.asarray(l_ref)
        rel = np.abs(np.asarray(l_q) - ref_n).max() / \
            (np.abs(ref_n).max() + 1e-9)
        assert rel < 0.25, f"quantized cache diverges: {rel}"

    def test_weight_pack_roundtrip(self):
        w = jnp.asarray(np.random.default_rng(3).normal(size=(64, 32)),
                        jnp.float32)
        packed = lm.pack_weight(w, bits=4)
        deq = lm._dequant_packed(packed, jnp.float32)
        rel = float(jnp.linalg.norm(deq - w) / jnp.linalg.norm(w))
        assert rel < 0.12


class TestMoEPadding:
    """moe_ffn at sequence lengths that don't divide the routing group:
    the tail group pads with zero tokens, which must be masked out of
    routing (no expert-capacity theft) and of the combine (zero output
    contribution).  Pre-fix this path died on a bare `assert seq % gs == 0`
    — which `python -O` silently strips, turning the crash into a reshape
    error or silent corruption."""

    from repro.models import layers as _L

    def _experts(self, d, f, e, seed=0):
        rng = np.random.default_rng(seed)
        wg = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32) * 0.2)
        wu = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32) * 0.2)
        wd = jnp.asarray(rng.normal(size=(e, f, d)).astype(np.float32) * 0.2)
        return wg, wu, wd

    def _dense_mixture(self, x, gate_w, wg, wu, wd, k):
        """Per-token oracle: top-k softmax-weighted sum of expert MLPs —
        what capacity routing converges to when nothing is dropped."""
        probs = jax.nn.softmax(
            x.astype(jnp.float32) @ gate_w.astype(jnp.float32), axis=-1)
        gv, gi = jax.lax.top_k(probs, k)
        gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
        y = jnp.zeros_like(x)
        for j in range(k):
            sel = gi[..., j]
            g = jnp.einsum("bsd,bsdf->bsf", x,
                           wg[sel].astype(x.dtype))
            u = jnp.einsum("bsd,bsdf->bsf", x,
                           wu[sel].astype(x.dtype))
            h = jax.nn.silu(g) * u
            o = jnp.einsum("bsf,bsfd->bsd", h, wd[sel].astype(x.dtype))
            y = y + gv[..., j:j + 1] * o
        return y

    def test_odd_seq_regression(self):
        """seq=100, group=64 raised AssertionError pre-fix.  With generous
        capacity the padded run must equal the per-token dense mixture —
        pads contribute nothing and steal nothing."""
        d, f, e, k = 16, 32, 4, 2
        rng = np.random.default_rng(10)
        x = jnp.asarray(rng.normal(size=(2, 100, d)).astype(np.float32))
        gate_w = jnp.asarray(rng.normal(size=(d, e)).astype(np.float32))
        wg, wu, wd = self._experts(d, f, e, seed=11)
        y = self._L.moe_ffn(x, gate_w, wg, wu, wd, k,
                            capacity_factor=float(e) / k * 2,
                            group_size=64)
        assert y.shape == (2, 100, d)
        oracle = self._dense_mixture(x, gate_w, wg, wu, wd, k)
        np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                                   rtol=2e-4, atol=2e-4)

    def test_padding_does_not_steal_capacity(self):
        """Tight capacity, tail group half padding: zero-input pad tokens
        tie-break their top-1 onto expert 0 — exactly where the tail's real
        tokens' second choice lands.  In the capacity cumsum, top-1 claims
        order before top-2 claims, so unmasked pads would take the expert-0
        slots and drop the real tokens' second expert.  Masked routing must
        reproduce the full (nothing-dropped) per-token mixture."""
        d = e = 4
        f, k, gs, seq = 16, 2, 4, 6         # tail group: 2 real + 2 pads
        gate_w = jnp.eye(d, dtype=jnp.float32)     # logits = features
        # group 1: claims balanced 2-per-expert so cap=2 drops nothing;
        # tail reals: top-1 expert 2, top-2 expert 0 (the pads' tie-break
        # target); pads: zeros → uniform → top-2 = experts (0, 1)
        x = jnp.asarray(np.array([
            [1.0, 0.5, 0.0, 0.0],           # (e0, e1)
            [0.5, 1.0, 0.0, 0.0],           # (e1, e0)
            [0.0, 0.0, 1.0, 0.5],           # (e2, e3)
            [0.0, 0.0, 0.5, 1.0],           # (e3, e2)
            [0.5, 0.0, 1.0, 0.0],           # (e2, e0)
            [0.5, 0.0, 1.0, 0.0],           # (e2, e0)
        ], np.float32))[None]
        wg, wu, wd = self._experts(d, f, e, seed=13)
        # cap = ceil(gs·k/e · cf) = 2 slots per expert per group: exactly
        # the real tokens' demand, zero slack for pads
        y = self._L.moe_ffn(x, gate_w, wg, wu, wd, k,
                            capacity_factor=1.0, group_size=gs)
        oracle = self._dense_mixture(x, gate_w, wg, wu, wd, k)
        np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                                   rtol=2e-4, atol=2e-4)

    def test_divisible_seq_unchanged(self):
        """The padding path must be a no-op when seq divides the group."""
        d, f, e, k = 16, 32, 4, 2
        rng = np.random.default_rng(14)
        x = jnp.asarray(rng.normal(size=(1, 128, d)).astype(np.float32))
        gate_w = jnp.asarray(rng.normal(size=(d, e)).astype(np.float32))
        wg, wu, wd = self._experts(d, f, e, seed=15)
        y64 = self._L.moe_ffn(x, gate_w, wg, wu, wd, k, 1.25, group_size=64)
        assert y64.shape == (1, 128, d)
        assert bool(jnp.isfinite(y64).all())

"""Continuous-batching subsystem tests: block allocator, paged-cache code
parity with the contiguous layout, the Pallas paged-attention and decode
matmul kernels vs their oracles, engine token parity (paged vs bucketed),
admission ordering, mid-stream join/leave, and block-exhaustion
preemption + bit-identical resume."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.stamp import StampConfig
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.serving.engine import (BucketedEngine, EngineConfig,
                                  PagedEngineConfig, PagedServingEngine)
from repro.serving.paged_kvcache import (BlockAllocator, OutOfBlocks,
                                         PagedCacheConfig)

CFG = ModelConfig(name="paged-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
PROMPT_LENS = (20, 45, 12, 30, 26)
MAX_NEW = (6, 4, 8, 5, 7)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [rng.integers(0, CFG.vocab_size, l) for l in PROMPT_LENS]


def run_engine(engine, prompts, max_new=MAX_NEW):
    for p, m in zip(prompts, max_new):
        engine.submit(p, m)
    done = engine.run()
    lm.set_fused_cache_attention(False)
    return {r.uid: r.out_tokens for r in done}


def paged_cfg(**kw):
    kw.setdefault("max_slots", 5)
    kw.setdefault("prefill_chunk", 64)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)


# ---------------------------------------------------------------------------
# allocator + page index math
# ---------------------------------------------------------------------------


class TestBlockAllocator:
    def test_null_page_reserved_and_lowest_first(self):
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=4,
                               num_hi_blocks=3, quant=QUANT)
        alloc = BlockAllocator(cfg)
        assert alloc.alloc_lo() == 1 and alloc.alloc_lo() == 2
        assert alloc.alloc_hi() == 1
        alloc.free([1], [1])
        assert alloc.alloc_lo() == 1     # lowest-first → deterministic
        assert alloc.alloc_lo() == 3
        with pytest.raises(OutOfBlocks):
            alloc.alloc_lo()             # 1,2,3 all out (0 is null)

    def test_token_page_index_regions(self):
        cfg = PagedCacheConfig(block_size=8, quant=QUANT)  # num_hi=16
        assert PKV.token_page_index(0, cfg) == (True, 0, 0)
        assert PKV.token_page_index(15, cfg) == (True, 1, 7)
        assert PKV.token_page_index(16, cfg) == (False, 0, 0)
        assert PKV.token_page_index(31, cfg) == (False, 1, 7)

    def test_num_hi_must_divide_into_pages(self):
        with pytest.raises(ValueError):
            PagedCacheConfig(block_size=12, quant=QUANT)

    def test_free_guards_raise_real_exceptions(self):
        """Double-free / null-page / out-of-range frees must raise even
        under ``python -O`` (ValueError, not assert)."""
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=4,
                               num_hi_blocks=3, quant=QUANT)
        alloc = BlockAllocator(cfg)
        p = alloc.alloc_lo()
        alloc.free([], [p])
        with pytest.raises(ValueError, match="double free"):
            alloc.free([], [p])
        with pytest.raises(ValueError, match="null page"):
            alloc.free([0], [])
        with pytest.raises(ValueError, match="outside the allocatable"):
            alloc.free([], [99])


# ---------------------------------------------------------------------------
# paged cache <-> contiguous cache code parity
# ---------------------------------------------------------------------------


class TestPagedCacheParity:
    def _fill(self, s=40, seed=0):
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=12,
                               num_hi_blocks=4, max_blocks_per_seq=6,
                               quant=QUANT)
        rng = np.random.default_rng(seed)
        g, hd = 2, 16
        k = jnp.asarray(rng.normal(size=(1, s, g, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, s, g, hd)).astype(np.float32))
        entry = {kk: a[0] for kk, a in PKV.init_pools(1, g, hd, cfg).items()}
        hi_pages, lo_pages = [1, 2], [1, 2, 3]
        pages, offs, ishi = [], [], []
        for pos in range(s):
            is_hi, pidx, off = PKV.token_page_index(pos, cfg)
            pages.append((hi_pages if is_hi else lo_pages)[pidx])
            offs.append(off)
            ishi.append(is_hi)
        entry = PKV.write_chunk(entry, k, v, jnp.asarray(pages, jnp.int32),
                                jnp.asarray(offs, jnp.int32),
                                jnp.asarray(ishi, bool), cfg)
        ht = jnp.asarray([hi_pages], jnp.int32)
        lt = jnp.asarray([lo_pages + [0, 0, 0]], jnp.int32)
        return cfg, entry, (k, v), (ht, lt)

    def test_prefill_chunk_matches_bulk_quantization(self):
        cfg, entry, (k, v), (ht, lt) = self._fill()
        s = k.shape[1]
        hi = cfg.num_hi
        segs = PKV.gather_segments(entry, ht, lt, cfg, jnp.float32)
        bulk = KV.quantize_full(k, v, cfg.quant)
        kd, vd = KV.dequantize_full(bulk, cfg.quant, jnp.float32)
        np.testing.assert_array_equal(np.asarray(segs[0][0]),
                                      np.asarray(kd[:, :hi]))
        np.testing.assert_array_equal(np.asarray(segs[1][0][:, :s - hi]),
                                      np.asarray(kd[:, hi:s]))
        np.testing.assert_array_equal(np.asarray(segs[1][1][:, :s - hi]),
                                      np.asarray(vd[:, hi:s]))

    def test_decode_write_matches_contiguous_write_token(self):
        cfg, entry, (k, v), (ht, lt) = self._fill()
        rng = np.random.default_rng(7)
        k1 = jnp.asarray(rng.normal(size=(1, 1, 2, 16)).astype(np.float32))
        v1 = jnp.asarray(rng.normal(size=(1, 1, 2, 16)).astype(np.float32))
        for pos in (3, 16, 39):          # hi page, lo page start, lo tail
            is_hi, pidx, off = PKV.token_page_index(pos, cfg)
            page = ([1, 2] if is_hi else [1, 2, 3])[pidx]
            paged = PKV.write_tokens(entry, k1, v1,
                                     jnp.asarray([page], jnp.int32),
                                     jnp.asarray([off], jnp.int32),
                                     jnp.asarray([is_hi], bool), cfg)
            bulk = KV.write_token(KV.quantize_full(k, v, cfg.quant),
                                  k1, v1, jnp.int32(pos), cfg.quant)
            segs = PKV.gather_segments(paged, ht, lt, cfg, jnp.float32)
            kd, vd = KV.dequantize_full(bulk, cfg.quant, jnp.float32)
            hi = cfg.num_hi
            np.testing.assert_array_equal(np.asarray(segs[0][0]),
                                          np.asarray(kd[:, :hi]))
            np.testing.assert_array_equal(
                np.asarray(segs[1][0][:, :40 - hi]), np.asarray(kd[:, hi:40]))

    def test_swap_roundtrip_bit_identical(self):
        """Swap-out/in must restore exactly, for both pool layouts: scanned
        periods ("0": (P, N, ...)) and period-stripped prologue entries
        ("pro0": (N, ...)) — the page axis moves between the two."""
        cfg, entry, _, (ht, lt) = self._fill()
        pools = {"0": jax.tree.map(lambda a: a[None], entry),
                 "pro0": entry}
        saved = PKV.extract_pages(pools, [1, 2], [1, 2, 3])
        # relocate to different page ids; gather must read back identically
        restored = PKV.insert_pages(pools, saved, [3, 1], [5, 9, 2])
        ht2 = jnp.asarray([[3, 1]], jnp.int32)
        lt2 = jnp.asarray([[5, 9, 2, 0, 0, 0]], jnp.int32)
        before = PKV.gather_segments(entry, ht, lt, cfg, jnp.float32)
        for layer_key, strip in (("0", True), ("pro0", False)):
            moved = restored[layer_key]
            if strip:
                moved = {k: a[0] for k, a in moved.items()}
            after = PKV.gather_segments(moved, ht2, lt2, cfg, jnp.float32)
            for (a, b, _), (c, d, _) in zip(before, after):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(c),
                                              err_msg=layer_key)
                np.testing.assert_array_equal(np.asarray(b), np.asarray(d),
                                              err_msg=layer_key)


# ---------------------------------------------------------------------------
# kernels vs oracles
# ---------------------------------------------------------------------------


class TestPagedAttentionKernel:
    def test_matches_gather_reference(self):
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=12,
                               num_hi_blocks=6, max_blocks_per_seq=4,
                               quant=QUANT)
        rng = np.random.default_rng(3)
        g, hd, h, S = 2, 16, 4, 3
        entry = {k: a[0] for k, a in PKV.init_pools(1, g, hd, cfg).items()}
        # three slots with different lengths / page placements
        tables = {0: ([1, 2], [1, 2, 3], 38), 1: ([3, 4], [4], 20),
                  2: ([5, 0], [0], 9)}
        for slot, (hp, lp, ln) in tables.items():
            k = jnp.asarray(rng.normal(size=(1, ln, g, hd)).astype(np.float32))
            v = jnp.asarray(rng.normal(size=(1, ln, g, hd)).astype(np.float32))
            pages, offs, ishi = [], [], []
            for pos in range(ln):
                is_hi, pidx, off = PKV.token_page_index(pos, cfg)
                pages.append((hp if is_hi else lp)[pidx])
                offs.append(off)
                ishi.append(is_hi)
            entry = PKV.write_chunk(entry, k, v,
                                    jnp.asarray(pages, jnp.int32),
                                    jnp.asarray(offs, jnp.int32),
                                    jnp.asarray(ishi, bool), cfg)
        q = jnp.asarray(rng.normal(size=(S, 1, h, hd)).astype(np.float32))
        lengths = jnp.asarray([tables[i][2] for i in range(S)], jnp.int32)
        ht = jnp.asarray([tables[i][0] for i in range(S)], jnp.int32)
        lt = jnp.asarray([tables[i][1] + [0] * (4 - len(tables[i][1]))
                          for i in range(S)], jnp.int32)
        out = paged_decode_attention(entry, q, lengths, ht, lt,
                                     cfg.block_size, interpret=True)
        oracle = ref.paged_attention_ref(entry, q, lengths, ht, lt,
                                         cfg.block_size, cfg.num_hi)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(oracle), atol=1e-5, rtol=1e-5)

    def test_unmapped_blocks_and_partial_pages_masked(self):
        """A slot whose length ends mid-page must ignore the page tail and
        every unmapped (null) block."""
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=8,
                               num_hi_blocks=4, max_blocks_per_seq=3,
                               quant=QUANT)
        rng = np.random.default_rng(4)
        g, hd, h = 2, 16, 4
        entry = {k: a[0] for k, a in PKV.init_pools(1, g, hd, cfg).items()}
        ln = 21                          # 16 hi + 5 lo (page 1 of lo, partial)
        k = jnp.asarray(rng.normal(size=(1, ln, g, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, ln, g, hd)).astype(np.float32))
        pages, offs, ishi = [], [], []
        for pos in range(ln):
            is_hi, pidx, off = PKV.token_page_index(pos, cfg)
            pages.append(([1, 2] if is_hi else [1])[pidx])
            offs.append(off)
            ishi.append(is_hi)
        entry = PKV.write_chunk(entry, k, v, jnp.asarray(pages, jnp.int32),
                                jnp.asarray(offs, jnp.int32),
                                jnp.asarray(ishi, bool), cfg)
        q = jnp.asarray(rng.normal(size=(1, 1, h, hd)).astype(np.float32))
        out = paged_decode_attention(
            entry, q, jnp.asarray([ln], jnp.int32),
            jnp.asarray([[1, 2]], jnp.int32),
            jnp.asarray([[1, 0, 0]], jnp.int32), cfg.block_size,
            interpret=True)
        # oracle over the dense first-ln tokens only
        segs = PKV.gather_segments(entry, jnp.asarray([[1, 2]], jnp.int32),
                                   jnp.asarray([[1, 0, 0]], jnp.int32),
                                   cfg, jnp.float32)
        from repro.models.layers import decode_attention
        kd = jnp.concatenate([segs[0][0], segs[1][0]], axis=1)[:, :ln]
        vd = jnp.concatenate([segs[0][1], segs[1][1]], axis=1)[:, :ln]
        oracle = decode_attention(q.astype(jnp.float32), kd, vd)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(oracle), atol=1e-5, rtol=1e-5)


class TestDecodeMatmul:
    def test_matches_oracle(self):
        rng = np.random.default_rng(5)
        for b, k, n in ((1, 64, 96), (4, 48, 128), (8, 32, 32)):
            x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
            qw = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
            sw = jnp.asarray(rng.uniform(1e-3, 1e-2, (1, n)).astype(np.float32))
            zw = jnp.asarray(rng.integers(-10, 10, (1, n)).astype(np.float32))
            bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            y = ops.stamp_decode_matmul(x, qw, sw, zw, bias,
                                        out_dtype=jnp.float32,
                                        interpret=True)
            yr = ref.stamp_decode_matmul_ref(x, qw, sw, zw, bias)
            rel = float(np.linalg.norm(np.asarray(y) - np.asarray(yr)) /
                        np.linalg.norm(np.asarray(yr)))
            assert rel < 1e-5, (b, k, n, rel)

    def test_decode_step_dispatch_tracks_dequant_path(self, params):
        """fused_decode_matmul consumes the prepared int8 buffers directly;
        logits stay within 8-bit activation-quant tolerance of the
        per-step-dequant path."""
        st = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, st)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, CFG.vocab_size, (2, 64)), jnp.int32)
        base = lm.ServeConfig(stamp=st, kv=QUANT, cache_capacity=96)
        fused = dataclasses.replace(base, fused_decode_matmul=True)
        _, cache = lm.prefill(pf, {"tokens": toks}, CFG, base)
        tok = jnp.zeros((2,), jnp.int32)
        l_deq, _ = lm.decode_step(pf, cache, tok, jnp.int32(64), CFG, base)
        l_int8, _ = lm.decode_step(pf, cache, tok, jnp.int32(64), CFG, fused)
        rel = np.abs(np.asarray(l_deq) - np.asarray(l_int8)).max() / \
            (np.abs(np.asarray(l_deq)).max() + 1e-9)
        assert rel < 5e-2, rel

    def test_prefill_entry_resets_fused_decode_flag(self, params):
        """A fused engine's decode leaves the process-global decode-matmul
        flag set; every prefill/train entry must clear it so a later
        length-1-sequence forward keeps the STaMP transform path — no
        manual `set_fused_decode_matmul(False)` between runs."""
        lm.set_fused_decode_matmul(True)
        toks = jnp.zeros((1, 8), jnp.int32)
        lm.prefill(params, {"tokens": toks}, CFG,
                   lm.ServeConfig(stamp=None, kv=QUANT, cache_capacity=16))
        assert lm._FUSED_DECODE_MATMUL is False


# ---------------------------------------------------------------------------
# engine parity + scheduling behavior
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def parity_runs(params, prompts):
    """One bucketed + one paged run per cache setting, shared by the
    parity assertions below (engine runs dominate this module's cost)."""
    runs = {}
    for label, serve in (
        ("quant", lm.ServeConfig(stamp=None, kv=QUANT)),
        ("bf16", lm.ServeConfig(stamp=None,
                                kv=KV.KVCacheConfig(quantized=False))),
        ("stamp", lm.ServeConfig(stamp=StampConfig(num_hi_tokens=8),
                                 kv=QUANT)),
    ):
        be = BucketedEngine(params, CFG, serve,
                            EngineConfig(max_batch=5, bucket=64, max_seq=96))
        pe = PagedServingEngine(params, CFG, serve, paged_cfg())
        runs[label] = (run_engine(be, prompts), run_engine(pe, prompts), pe)
    return runs


class TestEngineParity:
    @pytest.mark.parametrize("label", ["quant", "bf16", "stamp"])
    def test_token_identical(self, parity_runs, label):
        """Mixed-length request set, greedy decode: the continuous-batching
        engine must reproduce the bucketed engine token for token."""
        bucketed, paged, _ = parity_runs[label]
        assert set(bucketed) == set(paged)
        for uid in bucketed:
            np.testing.assert_array_equal(bucketed[uid], paged[uid],
                                          err_msg=f"{label} uid={uid}")

    def test_every_request_completes_full_budget(self, parity_runs):
        _, paged, _ = parity_runs["quant"]
        for uid, m in zip(sorted(paged), MAX_NEW):
            assert len(paged[uid]) == m


class TestScheduling:
    def test_admission_is_fcfs(self, parity_runs):
        """More requests than slots: admits must follow submit order."""
        _, _, pe = parity_runs["quant"]
        admits = [p for _, kind, p in pe.events if kind == "admit"]
        assert admits == sorted(admits)

    def test_mid_stream_join_and_leave(self, params, prompts):
        """The decode batch gains members while earlier requests are still
        generating, and loses them when they finish — no lockstep bucket."""
        pe = PagedServingEngine(
            params, CFG, lm.ServeConfig(stamp=None, kv=QUANT),
            paged_cfg(max_slots=3))
        run_engine(pe, prompts)
        batches = [set(p) for _, kind, p in pe.events if kind == "decode"]
        assert batches, "no decode steps recorded"
        grew = any(b2 > b1 for b1, b2 in zip(batches, batches[1:]))
        shrank_while_busy = any(
            (b1 - b2) and b2 for b1, b2 in zip(batches, batches[1:]))
        assert grew, "no request ever joined a running batch"
        assert shrank_while_busy, "no request left while others kept going"

    def test_preemption_and_bit_identical_resume(self, params, prompts):
        """Tiny lo pool: decode runs out of pages, the latest arrival is
        swapped out and later resumed; final tokens must equal the
        uncontended run (swap restores the exact cache state).  Longer
        generations than the parity workload so running requests cross page
        boundaries while younger requests still hold pages."""
        max_new = (14, 10, 16, 8, 12)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        ample = run_engine(PagedServingEngine(params, CFG, serve,
                                              paged_cfg()),
                           prompts, max_new)
        pe = PagedServingEngine(params, CFG, serve,
                                paged_cfg(num_lo_blocks=6))
        tight = run_engine(pe, prompts, max_new)
        assert pe.stats["preemptions"] > 0
        kinds = [kind for _, kind, _ in pe.events]
        assert "preempt" in kinds and "resume" in kinds
        assert kinds.index("preempt") < kinds.index("resume")
        preempted_uids = {p for _, k, p in pe.events if k == "preempt"}
        assert any(self_or_req.preemptions > 0
                   for self_or_req in pe._requests.values())
        assert preempted_uids
        for uid in ample:
            np.testing.assert_array_equal(ample[uid], tight[uid])

    def test_mid_prefill_preemption_over_reserved_pages(self, params):
        """`plan_step` reserves the prefill candidate's *next* chunk before
        checking decode capacity, so an earlier arrival's decode growth can
        preempt a PREFILLING request whose page set runs ahead of its
        materialized prefix.  The scheduler must release those empty pages
        at eviction so the saved page set equals the pages_for(pos)
        re-allocation at resume — previously the count mismatch crashed
        `insert_pages` with a shape error.  The tight run must still match
        the uncontended run token for token."""
        rng = np.random.default_rng(11)
        reqs = [rng.integers(0, CFG.vocab_size, 14),
                rng.integers(0, CFG.vocab_size, 40)]   # > 2 prefill chunks
        max_new = (6, 4)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        ample = run_engine(
            PagedServingEngine(params, CFG, serve,
                               paged_cfg(max_slots=2, prefill_chunk=16)),
            reqs, max_new)
        # max_prefills=1 pins the PR-3 one-chunk-per-step schedule: with
        # the unified default (2) both prompts prefill concurrently and the
        # long one finishes before decode growth exhausts the pool, so the
        # over-reservation scenario this regression test constructs never
        # arises (tokens are schedule-invariant either way)
        pe = PagedServingEngine(
            params, CFG, serve,
            paged_cfg(max_slots=2, prefill_chunk=16, num_lo_blocks=3,
                      max_prefills=1))
        tight = run_engine(pe, reqs, max_new)
        assert pe.stats["preemptions"] > 0
        # the long prompt (uid 2) was evicted mid-prefill: it still had
        # chunks left to run after the preemption
        ev = [(kind, p) for _, kind, p in pe.events]
        pre_i = ev.index(("preempt", 2))
        chunks_after = [p for kind, p in ev[pre_i:]
                        if kind == "prefill_chunk" and p[0] == 2]
        assert chunks_after, "victim was not preempted mid-prefill"
        for uid in ample:
            np.testing.assert_array_equal(ample[uid], tight[uid])

    def test_pool_too_small_rejects_at_submit(self, params, prompts):
        """A request whose peak page demand exceeds the whole pool used to
        raise OutOfBlocks out of run() — tearing down every other request.
        It is now rejected at submit() and run() stays clean."""
        pe = PagedServingEngine(
            params, CFG, lm.ServeConfig(stamp=None, kv=QUANT),
            paged_cfg(num_lo_blocks=2))   # 1 usable page = 16 lo tokens
        uid = pe.submit(prompts[1], 40)   # needs 45+40-16 lo tokens
        req = pe.request(uid)
        assert req.status == "rejected"
        assert "capacity-infeasible" in req.error
        assert pe.stats["rejected"] == 1
        done = pe.run()                   # nothing queued; returns reject
        assert [r.uid for r in done] == [uid]
        assert pe.sched.quiescent()       # no page/slot leaked on the way


class TestEngineConfigDefaults:
    def test_engine_config_not_shared_between_instances(self, params):
        """The old ``ecfg: EngineConfig = EngineConfig()`` default was a
        single shared instance — mutating one engine's config leaked into
        every other engine constructed without an explicit config."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        e1 = BucketedEngine(params, CFG, serve)
        e2 = BucketedEngine(params, CFG, serve)
        assert e1.ecfg is not e2.ecfg
        e1.ecfg.bucket = 7
        assert e2.ecfg.bucket != 7
        p1 = PagedServingEngine(params, CFG, serve)
        p2 = PagedServingEngine(params, CFG, serve)
        assert p1.ecfg is not p2.ecfg

"""Fused integer execution path: `stamp_quant_matmul` kernel vs the unfused
oracle, `stamp_linear(execution="fused")` vs `execution="reference"` parity
across transforms/shapes/edge cases, cached-weight reuse (no per-call
dequant), and the end-to-end prefill/serving wiring."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core import quant as Q
from repro.core.stamp import (StampConfig, PreparedLinear, fused_eligible,
                              prepare_linear, stamp_linear)
from repro.kernels import ops, ref


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.linalg.norm(a - b) / np.linalg.norm(b))


def make_int8_weight(din, dout, seed=0, bits=8):
    """Signed int8 codes + (1, dout) scale / shifted zero point."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(din, dout)).astype(np.float32) * 0.05
    n = float(2**bits - 1)
    shift = float(1 << (bits - 1))
    mn, mx = w.min(0, keepdims=True), w.max(0, keepdims=True)
    sw = np.maximum((mx - mn) / n, 1e-8).astype(np.float32)
    zp = np.round(-mn / sw)
    qw = (np.clip(np.round(w / sw) + zp, 0, n) - shift).astype(np.int8)
    return jnp.asarray(qw), jnp.asarray(sw), jnp.asarray(zp - shift), \
        jnp.asarray(w)


class TestStampQuantMatmulKernel:
    """Pallas kernel (interpret mode) vs the pure-jnp unfused oracle."""

    @pytest.mark.parametrize("transform", ["none", "dwt", "wht"])
    @pytest.mark.parametrize("shape", [(2, 128, 64, 96), (1, 100, 48, 40),
                                       (1, 60, 32, 64)])
    def test_matches_ref(self, transform, shape):
        b, s, k, n = shape
        x = rand((b, s, k), seed=1)
        qw, sw, zw, _ = make_int8_weight(k, n, seed=2)
        bias = rand((n,), seed=3)
        kw = dict(transform=transform, levels=3, skip_first=True, num_hi=16)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, bias,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, bias, **kw)
        assert rel_err(y, yr) < 1e-5

    @pytest.mark.parametrize("transform", ["dwt", "wht"])
    def test_multiple_output_blocks_reuse_scratch(self, transform):
        """N > block_n: blocks after the first reuse the scratch-resident
        quantized activation — results must match the oracle on every
        output column."""
        b, s, k, n = 1, 128, 64, 512   # default block_n=256 → 2 blocks
        x = rand((b, s, k), seed=30)
        qw, sw, zw, _ = make_int8_weight(k, n, seed=31)
        kw = dict(transform=transform, levels=3, skip_first=True, num_hi=16)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, None, **kw)
        assert rel_err(y, yr) < 1e-5
        assert rel_err(y[..., 256:], yr[..., 256:]) < 1e-5

    def test_num_hi_exceeds_seq(self):
        """num_hi ≥ seq_len: every token quantizes at hi_bits."""
        x = rand((1, 32, 32), seed=4)
        qw, sw, zw, _ = make_int8_weight(32, 32, seed=5)
        kw = dict(transform="dwt", levels=2, skip_first=True, num_hi=512)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, None, **kw)
        assert rel_err(y, yr) < 1e-5

    def test_mixed_precision_hi_rows_more_accurate(self):
        """The first num_hi (transformed) tokens carry 8-bit codes: against
        an unquantized-activation matmul their rows are strictly closer."""
        s, k, n = 128, 64, 64
        x = rand((1, s, k), seed=6)
        qw, sw, zw, w = make_int8_weight(k, n, seed=7)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None, transform="none",
                                   num_hi=32, out_dtype=jnp.float32,
                                   interpret=True)
        exact = x @ jnp.asarray((np.asarray(qw, np.float32) -
                                 np.asarray(zw)) * np.asarray(sw))
        err = np.abs(np.asarray(y - exact))
        assert err[:, :32].mean() < err[:, 32:].mean()


class TestStampLinearParity:
    """stamp_linear(execution='fused') vs execution='reference'."""

    CASES = [
        # transform, s, din, dout, num_hi
        ("dwt", 128, 64, 96, 32),
        ("dwt", 100, 48, 64, 16),     # odd (non-pow2) sequence length
        ("wht", 128, 64, 64, 32),
        ("wht", 60, 32, 48, 8),       # identity-tail WHT
        ("none", 64, 32, 32, 16),
        ("dwt", 48, 32, 64, 128),     # num_hi ≥ seq_len
    ]

    @pytest.mark.parametrize("transform,s,din,dout,num_hi", CASES)
    def test_fused_matches_reference(self, transform, s, din, dout, num_hi):
        x = rand((2, s, din), seed=8)
        w = rand((din, dout), seed=9, scale=0.05)
        b = rand((dout,), seed=10)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=num_hi)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_linear(x, w, b, cfg)
        y_fused = stamp_linear(x, w, b, cfg_f)
        # 8-bit on-the-fly weight codes: parity within quant tolerance
        assert rel_err(y_fused, y_ref) < 1e-2

    @pytest.mark.parametrize("transform", ["dwt", "wht"])
    def test_shared_wquant_near_exact(self, transform):
        """With the same integer weight codes the two paths are the same
        computation up to float association — far inside 1e-2."""
        x = rand((1, 128, 64), seed=11)
        w = rand((64, 96), seed=12, scale=0.05)
        wq = Q.rtn_quantize_weight(w, bits=4, axis=0)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=16)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_linear(x, w, None, cfg, w_quant=wq)
        y_fused = stamp_linear(x, w, None, cfg_f, w_quant=wq)
        assert rel_err(y_fused, y_ref) < 1e-4

    def test_ineligible_config_falls_back(self):
        """dct / block granularity / feature_rot can't fuse — the reference
        path runs with identical semantics (bit-identical here)."""
        x = rand((1, 64, 32), seed=13)
        w = rand((32, 32), seed=14, scale=0.05)
        for cfg in (StampConfig(seq_transform="dct", execution="fused"),
                    StampConfig(granularity="block", execution="fused")):
            assert not fused_eligible(cfg)
            y_f = stamp_linear(x, w, None, cfg)
            y_r = stamp_linear(x, w, None,
                               dataclasses.replace(cfg,
                                                   execution="reference"))
            np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_r))
        rot = jnp.eye(32)
        cfg = StampConfig(execution="fused")
        assert not fused_eligible(cfg, feature_rot=rot)

    def test_wide_bits_fall_back(self):
        """hi/lo bits beyond int8 storage can't fuse (codes would wrap at
        the signed shift) — must take the reference path, not corrupt."""
        x = rand((1, 64, 32), seed=24)
        w = rand((32, 32), seed=25, scale=0.05)
        cfg = StampConfig(hi_bits=16, execution="fused")
        assert not fused_eligible(cfg)
        y_f = stamp_linear(x, w, None, cfg)
        y_r = stamp_linear(x, w, None,
                           dataclasses.replace(cfg, execution="reference"))
        np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_r))

    def test_explicit_bias_wins_over_prepared(self):
        """Same precedence on the fused path as on the reference fallback:
        a bias passed to stamp_linear overrides PreparedLinear.bias."""
        x = rand((1, 64, 32), seed=26)
        w = rand((32, 48), seed=27, scale=0.05)
        b_prep = jnp.ones((48,))
        b_call = jnp.full((48,), 5.0)
        cfg = StampConfig(execution="fused", num_hi_tokens=8)
        prep = prepare_linear(w, b_prep)
        y_with_call_bias = stamp_linear(x, None, b_call, cfg, prepared=prep)
        y_manual = stamp_linear(
            x, None, None, cfg,
            prepared=dataclasses.replace(prep, bias=b_call))
        np.testing.assert_allclose(np.asarray(y_with_call_bias),
                                   np.asarray(y_manual), atol=1e-5)

    def test_disabled_config_plain_matmul(self):
        x = rand((1, 16, 8), seed=15)
        w = rand((8, 8), seed=16)
        cfg = StampConfig(enabled=False, execution="fused")
        np.testing.assert_allclose(np.asarray(stamp_linear(x, w, None, cfg)),
                                   np.asarray(x @ w), rtol=1e-6)


class TestPreparedWeightReuse:
    def test_prepared_buffers_skip_dequant(self, monkeypatch):
        """With a PreparedLinear the fused path must never re-materialize
        bf16 weights: QuantizedWeight.dequant and prepare_linear may not run
        per call."""
        x = rand((1, 64, 32), seed=17)
        w = rand((32, 48), seed=18, scale=0.05)
        cfg = StampConfig(execution="fused", num_hi_tokens=8)
        prep = prepare_linear(w)

        def boom(*a, **k):
            raise AssertionError("per-call weight re-materialization")

        monkeypatch.setattr(Q.QuantizedWeight, "dequant", boom)
        monkeypatch.setattr("repro.core.stamp.prepare_linear", boom)
        y = stamp_linear(x, None, None, cfg, prepared=prep)
        assert y.shape == (1, 64, 48)

    def test_prepare_from_wquant_reuses_codes(self):
        w = rand((32, 32), seed=19, scale=0.05)
        wq = Q.rtn_quantize_weight(w, bits=4, axis=0)
        prep = prepare_linear(w_quant=wq)
        # signed shift by 2^(bits-1); dequant identical to the rtn dequant
        np.testing.assert_array_equal(
            np.asarray(prep.qw, np.int32) + 8, np.asarray(wq.q, np.int32))
        np.testing.assert_allclose(np.asarray(prep.dequant(jnp.float32)),
                                   np.asarray(wq.dequant(jnp.float32)),
                                   rtol=1e-6)

    def test_one_sided_channel_zero_point_bounded(self):
        """Zero-anchored range: even an all-positive weight channel keeps
        the signed zero point inside bf16-exact integer range, so the
        decode-path bf16 dequant stays faithful."""
        rng = np.random.default_rng(32)
        w = jnp.asarray(rng.uniform(4.99, 5.01, (64, 16)).astype(np.float32))
        prep = prepare_linear(w)
        zw = np.asarray(prep.zw)
        assert zw.min() >= -128 and zw.max() <= 127
        deq16 = ((prep.qw.astype(jnp.bfloat16) -
                  prep.zw.astype(jnp.bfloat16)) *
                 prep.sw.astype(jnp.bfloat16)).astype(jnp.float32)
        # bf16 dequant tracks the f32 dequant to bf16 epsilon, not a
        # systematic zero-point shift
        np.testing.assert_allclose(np.asarray(deq16),
                                   np.asarray(prep.dequant(jnp.float32)),
                                   rtol=2e-2, atol=1e-2)

    def test_prepared_linear_is_pytree(self):
        prep = prepare_linear(rand((8, 8), seed=20))
        leaves = jax.tree.leaves(prep)
        assert len(leaves) == 3      # qw, sw, zw (bias None)
        out = jax.jit(lambda p, x: x @ p.dequant(jnp.float32))(
            prep, rand((4, 8), seed=21))
        assert out.shape == (4, 8)


class TestModelWiring:
    """prefill/serving runs the integer path end-to-end."""

    def _setup(self):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="fused-test", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 64)),
                           jnp.int32)
        return lm, KV, cfg, params, {"tokens": toks}

    def test_prepare_fused_weights_converts_sites(self):
        lm, KV, cfg, params, _ = self._setup()
        st = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, st)
        layer0 = jax.tree.map(lambda a: a, pf["period"][0])
        # self-attention QKV merged into ONE prepared buffer at prepare time
        assert all(k not in layer0 for k in ("wq", "wk", "wv"))
        for site in ("wqkv", "wo_mlp"):
            assert isinstance(layer0[site], dict) and "iq" in layer0[site]
            assert layer0[site]["iq"].dtype == jnp.int8
        d = 64
        assert layer0["wqkv"]["iq"].shape[-1] == d + 2 * (d // 2)  # q+2kv
        # non-fused sites untouched
        assert not isinstance(layer0["wi_gate"], dict)
        # reference-only config: no-op
        assert lm.prepare_fused_weights(
            params, StampConfig(execution="reference")) is params

    def test_prefill_fused_tracks_bf16_like_reference(self):
        """Chaotic 4-bit code flips keep untrained-model logits from matching
        token-for-token, but the fused path must track the unquantized bf16
        forward at least as well as the reference quantized path does."""
        lm, KV, cfg, params, batch = self._setup()
        st = StampConfig(num_hi_tokens=8)
        stf = dataclasses.replace(st, execution="fused")
        kv = KV.KVCacheConfig(quantized=True, num_hi=16)
        l_bf16, _ = lm.prefill(params, batch, cfg, lm.ServeConfig(
            stamp=None, kv=KV.KVCacheConfig(quantized=False),
            cache_capacity=96))
        l_ref, _ = lm.prefill(params, batch, cfg, lm.ServeConfig(
            stamp=st, kv=kv, cache_capacity=96))
        pf = lm.prepare_fused_weights(params, stf)
        l_fused, cache = lm.prefill(pf, batch, cfg, lm.ServeConfig(
            stamp=stf, kv=kv, cache_capacity=96))
        dev_ref = rel_err(l_ref, l_bf16)
        dev_fused = rel_err(l_fused, l_bf16)
        assert dev_fused < max(1.5 * dev_ref, 0.05)
        # decode shares the prepared int8 buffers (dequant `_linear` branch)
        tok = jnp.argmax(l_fused, -1).astype(jnp.int32)
        serve = lm.ServeConfig(stamp=stf, kv=kv, cache_capacity=96)
        logits, _ = lm.decode_step(pf, cache, tok, jnp.int32(64), cfg, serve)
        assert logits.shape == (2, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_single_layer_parity_tight(self):
        """One linear inside the model dtype regime (bf16): fused vs
        reference with shared int8 codes stays inside quant tolerance."""
        x = rand((2, 64, 64), seed=22).astype(jnp.bfloat16)
        w = rand((64, 96), seed=23, scale=0.05)
        cfg = StampConfig(num_hi_tokens=8)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        prep = prepare_linear(w)
        y_f = stamp_linear(x, None, None, cfg_f, prepared=prep)
        y_r = stamp_linear(x, prep.dequant(jnp.float32), None, cfg)
        assert rel_err(y_f, y_r) < 1e-2

    def test_engine_runs_fused(self):
        lm, KV, cfg, params, _ = self._setup()
        from repro.serving.engine import EngineConfig, ServingEngine
        serve = lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"),
            kv=KV.KVCacheConfig(quantized=True, num_hi=16))
        eng = ServingEngine(params, cfg, serve,
                            EngineConfig(max_batch=2, bucket=64, max_seq=96))
        # weights were prepared (and QKV-merged) once at construction
        assert "iq" in eng.params["period"][0]["wqkv"]
        eng.submit(np.arange(10) % 128, max_new_tokens=4)
        eng.submit(np.arange(20) % 128, max_new_tokens=4)
        done = eng.run()
        assert len(done) == 2
        for r in done:
            assert r.out_tokens.shape == (4,)

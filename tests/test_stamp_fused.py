"""Fused integer execution path: `stamp_quant_matmul` kernel vs the unfused
oracle, `stamp_linear(execution="fused")` vs `execution="reference"` parity
across transforms/shapes/edge cases, cached-weight reuse (no per-call
dequant), and the end-to-end prefill/serving wiring."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core import quant as Q
from repro.core.stamp import (StampConfig, PreparedLinear, fused_eligible,
                              prepare_linear, stamp_linear)
from repro.kernels import ops, ref


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.linalg.norm(a - b) / np.linalg.norm(b))


def make_int8_weight(din, dout, seed=0, bits=8):
    """Signed int8 codes + (1, dout) scale / shifted zero point."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(din, dout)).astype(np.float32) * 0.05
    n = float(2**bits - 1)
    shift = float(1 << (bits - 1))
    mn, mx = w.min(0, keepdims=True), w.max(0, keepdims=True)
    sw = np.maximum((mx - mn) / n, 1e-8).astype(np.float32)
    zp = np.round(-mn / sw)
    qw = (np.clip(np.round(w / sw) + zp, 0, n) - shift).astype(np.int8)
    return jnp.asarray(qw), jnp.asarray(sw), jnp.asarray(zp - shift), \
        jnp.asarray(w)


class TestStampQuantMatmulKernel:
    """Pallas kernel (interpret mode) vs the pure-jnp unfused oracle."""

    @pytest.mark.parametrize("transform", ["none", "dwt", "wht"])
    @pytest.mark.parametrize("shape", [(2, 128, 64, 96), (1, 100, 48, 40),
                                       (1, 60, 32, 64)])
    def test_matches_ref(self, transform, shape):
        b, s, k, n = shape
        x = rand((b, s, k), seed=1)
        qw, sw, zw, _ = make_int8_weight(k, n, seed=2)
        bias = rand((n,), seed=3)
        kw = dict(transform=transform, levels=3, skip_first=True, num_hi=16)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, bias,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, bias, **kw)
        assert rel_err(y, yr) < 1e-5

    @pytest.mark.parametrize("transform", ["dwt", "wht"])
    def test_multiple_output_blocks_reuse_scratch(self, transform):
        """N > block_n: blocks after the first reuse the scratch-resident
        quantized activation — results must match the oracle on every
        output column."""
        b, s, k, n = 1, 128, 64, 512   # default block_n=256 → 2 blocks
        x = rand((b, s, k), seed=30)
        qw, sw, zw, _ = make_int8_weight(k, n, seed=31)
        kw = dict(transform=transform, levels=3, skip_first=True, num_hi=16)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, None, **kw)
        assert rel_err(y, yr) < 1e-5
        assert rel_err(y[..., 256:], yr[..., 256:]) < 1e-5

    def test_num_hi_exceeds_seq(self):
        """num_hi ≥ seq_len: every token quantizes at hi_bits."""
        x = rand((1, 32, 32), seed=4)
        qw, sw, zw, _ = make_int8_weight(32, 32, seed=5)
        kw = dict(transform="dwt", levels=2, skip_first=True, num_hi=512)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None,
                                   out_dtype=jnp.float32, interpret=True,
                                   **kw)
        yr = ref.stamp_quant_matmul_ref(x, qw, sw, zw, None, **kw)
        assert rel_err(y, yr) < 1e-5

    def test_mixed_precision_hi_rows_more_accurate(self):
        """The first num_hi (transformed) tokens carry 8-bit codes: against
        an unquantized-activation matmul their rows are strictly closer."""
        s, k, n = 128, 64, 64
        x = rand((1, s, k), seed=6)
        qw, sw, zw, w = make_int8_weight(k, n, seed=7)
        y = ops.stamp_quant_matmul(x, qw, sw, zw, None, transform="none",
                                   num_hi=32, out_dtype=jnp.float32,
                                   interpret=True)
        exact = x @ jnp.asarray((np.asarray(qw, np.float32) -
                                 np.asarray(zw)) * np.asarray(sw))
        err = np.abs(np.asarray(y - exact))
        assert err[:, :32].mean() < err[:, 32:].mean()


class TestStampLinearParity:
    """stamp_linear(execution='fused') vs execution='reference'."""

    CASES = [
        # transform, s, din, dout, num_hi
        ("dwt", 128, 64, 96, 32),
        ("dwt", 100, 48, 64, 16),     # odd (non-pow2) sequence length
        ("wht", 128, 64, 64, 32),
        ("wht", 60, 32, 48, 8),       # identity-tail WHT
        ("none", 64, 32, 32, 16),
        ("dwt", 48, 32, 64, 128),     # num_hi ≥ seq_len
    ]

    @pytest.mark.parametrize("transform,s,din,dout,num_hi", CASES)
    def test_fused_matches_reference(self, transform, s, din, dout, num_hi):
        x = rand((2, s, din), seed=8)
        w = rand((din, dout), seed=9, scale=0.05)
        b = rand((dout,), seed=10)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=num_hi)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_linear(x, w, b, cfg)
        y_fused = stamp_linear(x, w, b, cfg_f)
        # 8-bit on-the-fly weight codes: parity within quant tolerance
        assert rel_err(y_fused, y_ref) < 1e-2

    @pytest.mark.parametrize("transform", ["dwt", "wht"])
    def test_shared_wquant_near_exact(self, transform):
        """With the same integer weight codes the two paths are the same
        computation up to float association — far inside 1e-2."""
        x = rand((1, 128, 64), seed=11)
        w = rand((64, 96), seed=12, scale=0.05)
        wq = Q.rtn_quantize_weight(w, bits=4, axis=0)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=16)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_linear(x, w, None, cfg, w_quant=wq)
        y_fused = stamp_linear(x, w, None, cfg_f, w_quant=wq)
        assert rel_err(y_fused, y_ref) < 1e-4

    def test_ineligible_config_falls_back(self):
        """dct / block granularity / feature_rot can't fuse — the reference
        path runs with identical semantics (bit-identical here)."""
        x = rand((1, 64, 32), seed=13)
        w = rand((32, 32), seed=14, scale=0.05)
        for cfg in (StampConfig(seq_transform="dct", execution="fused"),
                    StampConfig(granularity="block", execution="fused")):
            assert not fused_eligible(cfg)
            y_f = stamp_linear(x, w, None, cfg)
            y_r = stamp_linear(x, w, None,
                               dataclasses.replace(cfg,
                                                   execution="reference"))
            np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_r))
        rot = jnp.eye(32)
        cfg = StampConfig(execution="fused")
        assert not fused_eligible(cfg, feature_rot=rot)

    def test_wide_bits_fall_back(self):
        """hi/lo bits beyond int8 storage can't fuse (codes would wrap at
        the signed shift) — must take the reference path, not corrupt."""
        x = rand((1, 64, 32), seed=24)
        w = rand((32, 32), seed=25, scale=0.05)
        cfg = StampConfig(hi_bits=16, execution="fused")
        assert not fused_eligible(cfg)
        y_f = stamp_linear(x, w, None, cfg)
        y_r = stamp_linear(x, w, None,
                           dataclasses.replace(cfg, execution="reference"))
        np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_r))

    def test_explicit_bias_wins_over_prepared(self):
        """Same precedence on the fused path as on the reference fallback:
        a bias passed to stamp_linear overrides PreparedLinear.bias."""
        x = rand((1, 64, 32), seed=26)
        w = rand((32, 48), seed=27, scale=0.05)
        b_prep = jnp.ones((48,))
        b_call = jnp.full((48,), 5.0)
        cfg = StampConfig(execution="fused", num_hi_tokens=8)
        prep = prepare_linear(w, b_prep)
        y_with_call_bias = stamp_linear(x, None, b_call, cfg, prepared=prep)
        y_manual = stamp_linear(
            x, None, None, cfg,
            prepared=dataclasses.replace(prep, bias=b_call))
        np.testing.assert_allclose(np.asarray(y_with_call_bias),
                                   np.asarray(y_manual), atol=1e-5)

    def test_disabled_config_plain_matmul(self):
        x = rand((1, 16, 8), seed=15)
        w = rand((8, 8), seed=16)
        cfg = StampConfig(enabled=False, execution="fused")
        np.testing.assert_allclose(np.asarray(stamp_linear(x, w, None, cfg)),
                                   np.asarray(x @ w), rtol=1e-6)


class TestPreparedWeightReuse:
    def test_prepared_buffers_skip_dequant(self, monkeypatch):
        """With a PreparedLinear the fused path must never re-materialize
        bf16 weights: QuantizedWeight.dequant and prepare_linear may not run
        per call."""
        x = rand((1, 64, 32), seed=17)
        w = rand((32, 48), seed=18, scale=0.05)
        cfg = StampConfig(execution="fused", num_hi_tokens=8)
        prep = prepare_linear(w)

        def boom(*a, **k):
            raise AssertionError("per-call weight re-materialization")

        monkeypatch.setattr(Q.QuantizedWeight, "dequant", boom)
        monkeypatch.setattr("repro.core.stamp.prepare_linear", boom)
        y = stamp_linear(x, None, None, cfg, prepared=prep)
        assert y.shape == (1, 64, 48)

    def test_prepare_from_wquant_reuses_codes(self):
        w = rand((32, 32), seed=19, scale=0.05)
        wq = Q.rtn_quantize_weight(w, bits=4, axis=0)
        prep = prepare_linear(w_quant=wq)
        # signed shift by 2^(bits-1); dequant identical to the rtn dequant
        np.testing.assert_array_equal(
            np.asarray(prep.qw, np.int32) + 8, np.asarray(wq.q, np.int32))
        np.testing.assert_allclose(np.asarray(prep.dequant(jnp.float32)),
                                   np.asarray(wq.dequant(jnp.float32)),
                                   rtol=1e-6)

    def test_one_sided_channel_zero_point_bounded(self):
        """Zero-anchored range: even an all-positive weight channel keeps
        the signed zero point inside bf16-exact integer range, so the
        decode-path bf16 dequant stays faithful."""
        rng = np.random.default_rng(32)
        w = jnp.asarray(rng.uniform(4.99, 5.01, (64, 16)).astype(np.float32))
        prep = prepare_linear(w)
        zw = np.asarray(prep.zw)
        assert zw.min() >= -128 and zw.max() <= 127
        deq16 = ((prep.qw.astype(jnp.bfloat16) -
                  prep.zw.astype(jnp.bfloat16)) *
                 prep.sw.astype(jnp.bfloat16)).astype(jnp.float32)
        # bf16 dequant tracks the f32 dequant to bf16 epsilon, not a
        # systematic zero-point shift
        np.testing.assert_allclose(np.asarray(deq16),
                                   np.asarray(prep.dequant(jnp.float32)),
                                   rtol=2e-2, atol=1e-2)

    def test_prepared_linear_is_pytree(self):
        prep = prepare_linear(rand((8, 8), seed=20))
        leaves = jax.tree.leaves(prep)
        assert len(leaves) == 3      # qw, sw, zw (bias None)
        out = jax.jit(lambda p, x: x @ p.dequant(jnp.float32))(
            prep, rand((4, 8), seed=21))
        assert out.shape == (4, 8)


class TestModelWiring:
    """prefill/serving runs the integer path end-to-end."""

    def _setup(self):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="fused-test", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 64)),
                           jnp.int32)
        return lm, KV, cfg, params, {"tokens": toks}

    def test_prepare_fused_weights_converts_sites(self):
        lm, KV, cfg, params, _ = self._setup()
        st = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, st)
        layer0 = jax.tree.map(lambda a: a, pf["period"][0])
        # self-attention QKV merged into ONE prepared buffer at prepare time
        assert all(k not in layer0 for k in ("wq", "wk", "wv"))
        # every prefill-path STaMP linear is prepared: merged QKV, out-proj,
        # the gate/up pair and the down projection
        for site in ("wqkv", "wo", "wi_gate", "wi_up", "wo_mlp"):
            assert isinstance(layer0[site], dict) and "iq" in layer0[site]
            assert layer0[site]["iq"].dtype == jnp.int8
        d = 64
        assert layer0["wqkv"]["iq"].shape[-1] == d + 2 * (d // 2)  # q+2kv
        # reference-only config: no-op
        assert lm.prepare_fused_weights(
            params, StampConfig(execution="reference")) is params

    def test_prepare_merges_qkv_bias(self):
        """Satellite: the merged QKV bias concatenates ONCE at prepare time
        (bqkv), not per layer call — the per-site bias leaves are gone."""
        import dataclasses as dc
        lm, KV, cfg, params, _ = self._setup()
        cfgb = dc.replace(cfg, qkv_bias=True)
        pb = lm.init_params(jax.random.PRNGKey(1), cfgb)
        pf = lm.prepare_fused_weights(
            pb, StampConfig(num_hi_tokens=8, execution="fused"))
        layer0 = jax.tree.map(lambda a: a, pf["period"][0])
        assert all(k not in layer0 for k in ("bq", "bk", "bv"))
        # stacked period leaves: (nper, merged_dim), sliced under lax.scan
        assert layer0["bqkv"].shape[-1] == cfgb.q_dim + 2 * cfgb.kv_dim

    def test_legacy_merged_tree_keeps_biases(self):
        """A prepared tree from the previous release (merged 'wqkv' but
        per-site bias leaves, no 'bqkv') must still apply the QKV biases —
        the per-call concat fallback, not a silent bias drop."""
        import dataclasses as dc
        lm, KV, cfg, params, _ = self._setup()
        cfgb = dc.replace(cfg, qkv_bias=True)
        pb = lm.init_params(jax.random.PRNGKey(3), cfgb)
        # make the biases large enough to dominate the logits
        pb = jax.tree_util.tree_map_with_path(
            lambda path, a: jnp.full_like(a, 3.0)
            if any(getattr(k, "key", None) in ("bq", "bk", "bv")
                   for k in path) else a, pb)
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(pb, stf)

        def strip_bqkv(tree):
            if isinstance(tree, dict):
                out = {}
                for k, v in tree.items():
                    if k == "bqkv":
                        continue
                    out[k] = strip_bqkv(v)
                if "bqkv" in tree:      # legacy shape: per-site leaves
                    q, kv = cfgb.q_dim, cfgb.kv_dim
                    out["bq"] = tree["bqkv"][..., :q]
                    out["bk"] = tree["bqkv"][..., q:q + kv]
                    out["bv"] = tree["bqkv"][..., q + kv:]
                return out
            if isinstance(tree, tuple):
                return tuple(strip_bqkv(t) for t in tree)
            return tree

        legacy = strip_bqkv(pf)
        toks = jnp.asarray(np.random.default_rng(4).integers(0, 128, (1, 32)),
                           jnp.int32)
        serve = lm.ServeConfig(stamp=stf,
                               kv=KV.KVCacheConfig(quantized=False),
                               cache_capacity=48)
        l_new, _ = lm.prefill(pf, {"tokens": toks}, cfg, serve)
        l_legacy, _ = lm.prefill(legacy, {"tokens": toks}, cfg, serve)
        np.testing.assert_allclose(np.asarray(l_legacy), np.asarray(l_new),
                                   atol=1e-4)

    def test_prepare_pair_matches_separate(self):
        """The stacked gate/up prepare is identical to two separate
        prepares (per-output-channel scales)."""
        lm, KV, cfg, params, _ = self._setup()
        st = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, st)
        layer0 = jax.tree.map(lambda a: a, pf["period"][0])
        for key in ("wi_gate", "wi_up"):
            sep = prepare_linear(params["period"][0][key],
                                 bits=st.fused_weight_bits)
            np.testing.assert_array_equal(np.asarray(layer0[key]["iq"]),
                                          np.asarray(sep.qw))
            np.testing.assert_allclose(np.asarray(layer0[key]["isw"]),
                                       np.asarray(sep.sw), rtol=1e-6)

    def test_prefill_fused_tracks_bf16_like_reference(self):
        """Chaotic 4-bit code flips keep untrained-model logits from matching
        token-for-token, but the fused path must track the unquantized bf16
        forward at least as well as the reference quantized path does."""
        lm, KV, cfg, params, batch = self._setup()
        st = StampConfig(num_hi_tokens=8)
        stf = dataclasses.replace(st, execution="fused")
        kv = KV.KVCacheConfig(quantized=True, num_hi=16)
        l_bf16, _ = lm.prefill(params, batch, cfg, lm.ServeConfig(
            stamp=None, kv=KV.KVCacheConfig(quantized=False),
            cache_capacity=96))
        l_ref, _ = lm.prefill(params, batch, cfg, lm.ServeConfig(
            stamp=st, kv=kv, cache_capacity=96))
        pf = lm.prepare_fused_weights(params, stf)
        l_fused, cache = lm.prefill(pf, batch, cfg, lm.ServeConfig(
            stamp=stf, kv=kv, cache_capacity=96))
        dev_ref = rel_err(l_ref, l_bf16)
        dev_fused = rel_err(l_fused, l_bf16)
        assert dev_fused < max(1.5 * dev_ref, 0.05)
        # decode shares the prepared int8 buffers (dequant `_linear` branch)
        tok = jnp.argmax(l_fused, -1).astype(jnp.int32)
        serve = lm.ServeConfig(stamp=stf, kv=kv, cache_capacity=96)
        logits, _ = lm.decode_step(pf, cache, tok, jnp.int32(64), cfg, serve)
        assert logits.shape == (2, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_single_layer_parity_tight(self):
        """One linear inside the model dtype regime (bf16): fused vs
        reference with shared int8 codes stays inside quant tolerance."""
        x = rand((2, 64, 64), seed=22).astype(jnp.bfloat16)
        w = rand((64, 96), seed=23, scale=0.05)
        cfg = StampConfig(num_hi_tokens=8)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        prep = prepare_linear(w)
        y_f = stamp_linear(x, None, None, cfg_f, prepared=prep)
        y_r = stamp_linear(x, prep.dequant(jnp.float32), None, cfg)
        assert rel_err(y_f, y_r) < 1e-2

    def test_engine_runs_fused(self):
        lm, KV, cfg, params, _ = self._setup()
        from repro.serving.engine import EngineConfig, ServingEngine
        serve = lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"),
            kv=KV.KVCacheConfig(quantized=True, num_hi=16))
        eng = ServingEngine(params, cfg, serve,
                            EngineConfig(max_batch=2, bucket=64, max_seq=96))
        # weights were prepared (and QKV-merged) once at construction
        assert "iq" in eng.params["period"][0]["wqkv"]
        eng.submit(np.arange(10) % 128, max_new_tokens=4)
        eng.submit(np.arange(20) % 128, max_new_tokens=4)
        done = eng.run()
        assert len(done) == 2
        for r in done:
            assert r.out_tokens.shape == (4,)


class TestDualKernel:
    """Dual-output gate/up kernel (interpret mode) vs the shared-quantize
    oracle, mirroring the single-kernel edge cases: odd sequence lengths,
    num_hi ≥ seq, skip_first_token off."""

    CASES = [
        # transform, s, k, n, num_hi, skip_first
        ("dwt", 128, 64, 96, 32, True),
        ("dwt", 100, 48, 64, 16, True),    # odd (non-pow2) sequence length
        ("wht", 60, 32, 48, 8, True),      # identity-tail WHT
        ("dwt", 48, 32, 64, 128, True),    # num_hi ≥ seq_len
        ("none", 64, 32, 32, 16, True),
        ("dwt", 64, 32, 32, 16, False),    # no first-token exception
    ]

    def _weights(self, k, n, seed):
        qg, sg, zg, _ = make_int8_weight(k, n, seed=seed)
        qu, su, zu, _ = make_int8_weight(k, n, seed=seed + 1)
        return (qg, sg, zg), (qu, su, zu)

    @pytest.mark.parametrize("transform,s,k,n,num_hi,skip_first", CASES)
    def test_silu_mul_matches_ref(self, transform, s, k, n, num_hi,
                                  skip_first):
        x = rand((2, s, k), seed=40)
        (qg, sg, zg), (qu, su, zu) = self._weights(k, n, seed=41)
        kw = dict(transform=transform, levels=3, skip_first=skip_first,
                  num_hi=num_hi)
        y = ops.stamp_quant_dual_matmul(x, qg, sg, zg, qu, su, zu,
                                        out_dtype=jnp.float32,
                                        interpret=True, **kw)
        yr = ref.stamp_quant_dual_matmul_ref(x, qg, sg, zg, qu, su, zu, **kw)
        assert rel_err(y, yr) < 1e-3

    @pytest.mark.parametrize("transform", ["dwt", "wht"])
    def test_no_epilogue_matches_two_singles(self, transform):
        """epilogue='none': each output must equal the single-output kernel
        on the same weights — sharing the scratch-resident quantize across
        the two GEMMs changes nothing."""
        s, k, n = 128, 64, 512      # n > block_n: scratch reuse across blocks
        x = rand((1, s, k), seed=42)
        (qg, sg, zg), (qu, su, zu) = self._weights(k, n, seed=43)
        kw = dict(transform=transform, levels=3, skip_first=True, num_hi=16,
                  out_dtype=jnp.float32, interpret=True)
        g, u = ops.stamp_quant_dual_matmul(x, qg, sg, zg, qu, su, zu,
                                           epilogue="none", **kw)
        g1 = ops.stamp_quant_matmul(x, qg, sg, zg, **kw)
        u1 = ops.stamp_quant_matmul(x, qu, su, zu, **kw)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g1), atol=1e-5)
        np.testing.assert_allclose(np.asarray(u), np.asarray(u1), atol=1e-5)

    def test_dual_bias_applies_before_silu(self):
        """Gate bias must land inside the silu argument (reference order:
        silu(x·Wg + bg) · (x·Wu + bu))."""
        s, k, n = 64, 32, 32
        x = rand((1, s, k), seed=44)
        (qg, sg, zg), (qu, su, zu) = self._weights(k, n, seed=45)
        bg, bu = rand((n,), seed=46), rand((n,), seed=47)
        kw = dict(transform="dwt", levels=3, skip_first=True, num_hi=16)
        y = ops.stamp_quant_dual_matmul(x, qg, sg, zg, qu, su, zu, bg, bu,
                                        out_dtype=jnp.float32,
                                        interpret=True, **kw)
        yr = ref.stamp_quant_dual_matmul_ref(x, qg, sg, zg, qu, su, zu,
                                             bg, bu, **kw)
        assert rel_err(y, yr) < 1e-3


class TestOutProjKernel:
    """Head-merge-fused out-proj: the kernel consumes the raw (b, s, nh, hd)
    attention output and must match the merged 3-D call bit-for-bit."""

    @pytest.mark.parametrize("s,nh,hd,num_hi,skip_first", [
        (128, 4, 16, 32, True),
        (100, 4, 12, 16, True),      # odd sequence length
        (48, 2, 16, 128, True),      # num_hi ≥ seq_len
        (64, 4, 16, 16, False),
    ])
    def test_headsplit_matches_merged(self, s, nh, hd, num_hi, skip_first):
        b, n = 2, 64
        x4 = rand((b, s, nh, hd), seed=50)
        qw, sw, zw, _ = make_int8_weight(nh * hd, n, seed=51)
        kw = dict(transform="dwt", levels=3, skip_first=skip_first,
                  num_hi=num_hi, out_dtype=jnp.float32, interpret=True)
        y4 = ops.stamp_quant_matmul(x4, qw, sw, zw, **kw)
        y3 = ops.stamp_quant_matmul(x4.reshape(b, s, nh * hd), qw, sw, zw,
                                    **kw)
        np.testing.assert_array_equal(np.asarray(y4), np.asarray(y3))

    def test_merge_heads_reference_fallback(self):
        """An ineligible config (dct) with merge_heads merges up front and
        takes the reference path — same result as pre-merged input."""
        from repro.core.stamp import stamp_linear
        x4 = rand((1, 64, 4, 8), seed=52)
        w = rand((32, 16), seed=53, scale=0.05)
        cfg = StampConfig(seq_transform="dct", execution="fused",
                          num_hi_tokens=8)
        assert not fused_eligible(cfg)
        y4 = stamp_linear(x4, w, None, cfg, merge_heads=True)
        y3 = stamp_linear(x4.reshape(1, 64, 32), w, None, cfg)
        np.testing.assert_array_equal(np.asarray(y4), np.asarray(y3))


class TestNewSiteParity:
    """stamp_dual_linear (gate/up) and merge_heads stamp_linear (out-proj):
    fused vs reference across the same edge-case grid as the QKV/down-proj
    cases above."""

    CASES = [
        # transform, s, din, dout, num_hi, skip_first
        ("dwt", 128, 64, 96, 32, True),
        ("dwt", 100, 48, 64, 16, True),    # odd sequence length
        ("wht", 60, 32, 48, 8, True),      # identity-tail WHT
        ("dwt", 48, 32, 64, 128, True),    # num_hi ≥ seq_len
        ("dwt", 64, 32, 48, 16, False),    # skip_first_token off
    ]

    @pytest.mark.parametrize("transform,s,din,dout,num_hi,skip_first", CASES)
    def test_dual_linear_fused_matches_reference(self, transform, s, din,
                                                 dout, num_hi, skip_first):
        from repro.core.stamp import stamp_dual_linear
        x = rand((2, s, din), seed=60)
        wg = rand((din, dout), seed=61, scale=0.05)
        wu = rand((din, dout), seed=62, scale=0.05)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=num_hi,
                          skip_first_token=skip_first)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_dual_linear(x, wg, wu, cfg)
        y_fused = stamp_dual_linear(x, wg, wu, cfg_f)
        # silu·mul squares the quant noise; same tolerance regime as the
        # single-linear on-the-fly-weight cases
        assert rel_err(y_fused, y_ref) < 3e-2

    @pytest.mark.parametrize("transform,s,din,dout,num_hi,skip_first", CASES)
    def test_out_proj_fused_matches_reference(self, transform, s, din, dout,
                                              num_hi, skip_first):
        nh = 4
        assert din % nh == 0
        x4 = rand((2, s, nh, din // nh), seed=63)
        w = rand((din, dout), seed=64, scale=0.05)
        cfg = StampConfig(seq_transform=transform, num_hi_tokens=num_hi,
                          skip_first_token=skip_first)
        cfg_f = dataclasses.replace(cfg, execution="fused")
        y_ref = stamp_linear(x4, w, None, cfg, merge_heads=True)
        y_fused = stamp_linear(x4, w, None, cfg_f, merge_heads=True)
        assert rel_err(y_fused, y_ref) < 1e-2

    def test_dual_linear_prepared_skips_dequant(self, monkeypatch):
        """Prepared gate/up buffers must never re-materialize bf16 weights
        per call (mirrors the single-linear guarantee)."""
        from repro.core.stamp import stamp_dual_linear
        wg = rand((32, 48), seed=65, scale=0.05)
        wu = rand((32, 48), seed=66, scale=0.05)
        cfg = StampConfig(execution="fused", num_hi_tokens=8)
        pg, pu = prepare_linear(wg), prepare_linear(wu)

        def boom(*a, **k):
            raise AssertionError("per-call weight re-materialization")

        monkeypatch.setattr(Q.QuantizedWeight, "dequant", boom)
        monkeypatch.setattr(PreparedLinear, "dequant", boom)
        monkeypatch.setattr("repro.core.stamp.prepare_linear", boom)
        y = stamp_dual_linear(rand((1, 64, 32), seed=67), None, None, cfg,
                              prepared_gate=pg, prepared_up=pu)
        assert y.shape == (1, 64, 48)


class TestNoReferenceRoundTrips:
    """Acceptance: with execution='fused', a prefill forward of a decoder
    layer issues NO reference-path stamp round trips for any wired site,
    and the gate/up pair's transform+quantize runs once (one dual-kernel
    call), not twice."""

    def _counted(self, monkeypatch):
        from repro.kernels import ops as kops
        counts = {"single": 0, "dual": 0}
        real_single, real_dual = (kops.stamp_quant_matmul,
                                  kops.stamp_quant_dual_matmul)

        def single(*a, **k):
            counts["single"] += 1
            return real_single(*a, **k)

        def dual(*a, **k):
            counts["dual"] += 1
            return real_dual(*a, **k)

        monkeypatch.setattr(kops, "stamp_quant_matmul", single)
        monkeypatch.setattr(kops, "stamp_quant_dual_matmul", dual)

        def boom(*a, **k):
            raise AssertionError("reference-path STaMP round trip")

        # _maybe_stamp (the reference fake-quant round trip) and the
        # reference transform inside stamp_linear must never run
        monkeypatch.setattr("repro.models.lm.stamp_fake_quant", boom)
        monkeypatch.setattr("repro.core.stamp.apply_seq_transform", boom)
        return counts

    def test_attn_mlp_layer_all_sites_fused(self, monkeypatch):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="count-test", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, qkv_bias=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, stf)
        counts = self._counted(monkeypatch)
        toks = jnp.asarray(np.random.default_rng(1).integers(0, 128, (1, 64)),
                           jnp.int32)
        logits, _ = lm.prefill(params=pf, batch={"tokens": toks}, cfg=cfg,
                               serve=lm.ServeConfig(
                                   stamp=stf,
                                   kv=KV.KVCacheConfig(quantized=True,
                                                       num_hi=16),
                                   cache_capacity=96))
        assert bool(jnp.isfinite(logits).all())
        # the scanned period traces the layer body once: one dual call for
        # the gate/up pair (NOT two singles), three singles for
        # wqkv / out-proj / down-proj
        assert counts["dual"] == 1
        assert counts["single"] == 3

    def test_mamba_layer_all_sites_fused(self, monkeypatch):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="count-mamba", family="ssm", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, ssm_state=16, ssm_head_dim=16)
        params = lm.init_params(jax.random.PRNGKey(2), cfg)
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, stf)
        counts = self._counted(monkeypatch)
        toks = jnp.asarray(np.random.default_rng(3).integers(0, 128, (1, 64)),
                           jnp.int32)
        logits, _ = lm.prefill(params=pf, batch={"tokens": toks}, cfg=cfg,
                               serve=lm.ServeConfig(
                                   stamp=stf,
                                   kv=KV.KVCacheConfig(quantized=False),
                                   cache_capacity=96))
        assert bool(jnp.isfinite(logits).all())
        # pure-SSM layers have no FFN: the two singles are exactly the
        # mamba in/out projections
        assert counts["dual"] == 0
        assert counts["single"] == 2


class TestHybridEngineFused:
    def test_bucketed_engine_mamba_sites_prepared(self):
        """The bucketed engine (the one covering SSM stacks) prepares the
        mamba in/out projections and serves with them end-to-end."""
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        from repro.serving.engine import EngineConfig, ServingEngine
        cfg = ModelConfig(name="hybrid-eng", family="hybrid", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, attn_period=2, ssm_state=16,
                          ssm_head_dim=16)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        serve = lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"),
            kv=KV.KVCacheConfig(quantized=True, num_hi=16))
        eng = ServingEngine(params, cfg, serve,
                            EngineConfig(max_batch=1, bucket=64, max_seq=96))
        mamba_layer = next(d for d in eng.params["period"]
                           if "in_proj" in d)
        for site in ("in_proj", "out_proj"):
            assert isinstance(mamba_layer[site], dict)
            assert "iq" in mamba_layer[site]
        eng.submit(np.arange(12) % 128, max_new_tokens=3)
        done = eng.run()
        assert len(done) == 1 and done[0].out_tokens.shape == (3,)

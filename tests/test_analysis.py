"""Regression tests for the HLO analyzer — the measurement layer behind
§Roofline/§Perf.  Each case encodes a bug class found (and fixed) during
the perf work: scan trip-count scaling, fusion parameter *index* mapping,
dynamic-slice awareness through pass-through chains, in-place
dynamic-update-slice aliasing, and elementwise fusion-group accounting."""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.analysis import hlo as H
from repro.analysis.roofline import compute_roofline, PEAK_FLOPS


def analyze(txt):
    return H.analyze_hlo_text(textwrap.dedent(txt))


class TestParser:
    def test_fusion_param_index_mapping(self):
        """Callee parameters are matched to operands by parameter(N) index,
        not by textual order (caught a 80× HBM over-count on decode)."""
        txt = """
        %fused (p: f32[4,256], q: s32[]) -> f32[256] {
          %param_1.7 = s32[] parameter(1)
          %param_0.3 = f32[4,256]{1,0} parameter(0)
          %ds = f32[1,256]{1,0} dynamic-slice(%param_0.3, %param_1.7), dynamic_slice_sizes={1,256}
          ROOT %bc = f32[256]{0} bitcast(%ds)
        }
        ENTRY %main (a: f32[4,256], i: s32[]) -> f32[256] {
          %a = f32[4,256]{1,0} parameter(0)
          %i = s32[] parameter(1)
          ROOT %f = f32[256]{0} fusion(%a, %i), kind=kLoop, calls=%fused
        }
        """
        stats = analyze(txt)
        # slice-aware read (1×256×4) + output write (256×4) = 2048, not 4096+
        assert stats["hbm_bytes_per_device"] == pytest.approx(2048, abs=16)

    def test_slice_through_convert_chain(self):
        """param -> convert -> dynamic-slice still counts slice bytes."""
        txt = """
        %fused (p: bf16[8,128], i: s32[]) -> f32[128] {
          %param_0.1 = bf16[8,128]{1,0} parameter(0)
          %param_1.1 = s32[] parameter(1)
          %cv = f32[8,128]{1,0} convert(%param_0.1)
          %ds = f32[1,128]{1,0} dynamic-slice(%cv, %param_1.1), dynamic_slice_sizes={1,128}
          ROOT %bc = f32[128]{0} bitcast(%ds)
        }
        ENTRY %main (a: bf16[8,128], i: s32[]) -> f32[128] {
          %a = bf16[8,128]{1,0} parameter(0)
          %i = s32[] parameter(1)
          ROOT %f = f32[128]{0} fusion(%a, %i), kind=kLoop, calls=%fused
        }
        """
        stats = analyze(txt)
        # read: 1×128 f32 slice of the converted view (fusion computes only
        # what the root needs) = 512 B; write 512 B (+ scalar index)
        assert stats["hbm_bytes_per_device"] == pytest.approx(1024, abs=16)

    def test_dus_root_aliases_target(self):
        """A fusion rooted in dynamic-update-slice writes the update only
        and does not re-read the aliased target buffer."""
        txt = """
        %fused (buf: f32[64,128], upd: f32[1,128], i: s32[]) -> f32[64,128] {
          %param_0.1 = f32[64,128]{1,0} parameter(0)
          %param_1.1 = f32[1,128]{1,0} parameter(1)
          %param_2.1 = s32[] parameter(2)
          ROOT %dus = f32[64,128]{1,0} dynamic-update-slice(%param_0.1, %param_1.1, %param_2.1)
        }
        ENTRY %main (b: f32[64,128], u: f32[1,128], i: s32[]) -> f32[64,128] {
          %b = f32[64,128]{1,0} parameter(0)
          %u = f32[1,128]{1,0} parameter(1)
          %i = s32[] parameter(2)
          ROOT %f = f32[64,128]{1,0} fusion(%b, %u, %i), kind=kLoop, calls=%fused
        }
        """
        stats = analyze(txt)
        # read update (512) + write update (512); NOT 64×128×4 re-read
        assert stats["hbm_bytes_per_device"] == pytest.approx(1024, abs=16)

    def test_while_trip_count_scales_body(self):
        def step(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), ()
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()
        w = jnp.ones((5, 64, 64))
        x = jnp.ones((8, 64))
        txt = jax.jit(step).lower(w, x).compile().as_text()
        stats = H.analyze_hlo_text(txt)
        expected = 2 * 8 * 64 * 64 * 5
        assert abs(stats["dot_flops_per_device"] - expected) / expected < 0.02

    def test_elementwise_chain_counts_once(self):
        """add -> mul -> tanh chain at top level: intermediate tensors fuse
        (no per-op read+write accounting)."""
        txt = """
        ENTRY %main (a: f32[1024,1024], b: f32[1024,1024]) -> f32[1024,1024] {
          %a = f32[1024,1024]{1,0} parameter(0)
          %b = f32[1024,1024]{1,0} parameter(1)
          %s = f32[1024,1024]{1,0} add(%a, %b)
          %m = f32[1024,1024]{1,0} multiply(%s, %s)
          ROOT %t = f32[1024,1024]{1,0} tanh(%m)
        }
        """
        stats = analyze(txt)
        one = 1024 * 1024 * 4
        # chain writes its final output once; inputs are params (free at
        # this accounting level, charged to producers) — well under the
        # naive 6-tensor count
        assert stats["hbm_bytes_per_device"] <= 2 * one

    def test_collective_ring_factors(self):
        txt = """
        ENTRY %main (p: f32[256,256]) -> f32[256,256] {
          %p = f32[256,256]{1,0} parameter(0)
          %ar = f32[256,256]{1,0} all-reduce(%p), to_apply=%add
          %ag = f32[512,256]{1,0} all-gather(%ar), dimensions={0}
          ROOT %rs = f32[128,256]{1,0} reduce-scatter(%ag), dimensions={0}
        }
        """
        stats = analyze(txt)
        sz = 256 * 256 * 4
        by = stats["collective_bytes_by_kind"]
        assert by["all-reduce"] == pytest.approx(2 * sz)     # ring RS+AG
        assert by["all-gather"] == pytest.approx(2 * sz)     # output bytes
        assert by["reduce-scatter"] == pytest.approx(2 * sz) # input bytes


class TestRooflineTerms:
    def test_terms_and_bottleneck(self):
        from repro.configs import get_config
        from repro.models.config import SHAPES
        stats = {
            "dot_flops_per_device": PEAK_FLOPS,     # exactly 1 s of compute
            "elem_flops_per_device": 0.0,
            "hbm_bytes_per_device": 819e9 * 2,      # 2 s of memory
            "collective_bytes_per_device": 50e9 * 0.5,
            "collective_bytes_by_kind": {}, "collective_counts": {},
        }
        r = compute_roofline(stats, get_config("qwen2-72b"),
                             SHAPES["train_4k"], 256)
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(2.0)
        assert r.bottleneck == "memory"
        assert r.roofline_fraction == pytest.approx(0.5)

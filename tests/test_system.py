"""End-to-end behaviour tests for the full system: training converges,
the PTQ pipeline improves matched-budget quantization, the serving engine
drains batched requests, and STaMP serving stays close to bf16 serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.ptq import calibrate_and_quantize
from repro.core.stamp import StampConfig
from repro.data.pipeline import DataConfig, calibration_batches
from repro.launch.train import TrainConfig, train
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvcache import KVCacheConfig

CFG = ModelConfig(name="sys-test", family="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=256, tie_embeddings=True)


@pytest.fixture(scope="module")
def trained():
    out = train(CFG, TrainConfig(steps=100, global_batch=8, seq=64,
                                 lr=3e-3, warmup=10),
                ckpt_dir=None, verbose=False)
    return out


class TestTraining:
    def test_loss_decreases(self, trained):
        losses = trained["losses"]
        assert losses[-1] < losses[0] * 0.9, \
            f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"

    def test_wsd_schedule_used_for_minicpm(self):
        from repro.configs import get_config
        assert get_config("minicpm-2b").schedule == "wsd"

    def test_compressed_grads_still_learn(self):
        out = train(CFG, TrainConfig(steps=60, global_batch=8, seq=64,
                                     lr=3e-3, warmup=10,
                                     compress_grads=True),
                    ckpt_dir=None, verbose=False)
        assert out["losses"][-1] < out["losses"][0]


class TestPTQPipeline:
    def test_calibration_finds_structure(self, trained):
        dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=64,
                          global_batch=4)
        _, serve, report = calibrate_and_quantize(
            trained["params"], calibration_batches(dcfg, 2), CFG)
        assert report.toeplitz_fraction > 0.3
        assert report.num_hi >= 1
        assert serve.stamp is not None and serve.kv.quantized

    def test_quantized_weights_close(self, trained):
        dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=64,
                          global_batch=4)
        sparams, _, _ = calibrate_and_quantize(
            trained["params"], calibration_batches(dcfg, 1), CFG)
        p0 = jax.tree.map(lambda a: a[0], trained["params"]["period"])[0]
        w_ref = np.asarray(p0["wq"], np.float32)
        packed = sparams["period"][0]["wq"]
        deq = np.asarray(lm._dequant_packed(
            jax.tree.map(lambda a: a[0], packed), jnp.float32))
        rel = np.linalg.norm(deq - w_ref) / np.linalg.norm(w_ref)
        assert rel < 0.15


class TestServingEngine:
    def test_batched_requests_complete(self, trained):
        serve = lm.ServeConfig(stamp=StampConfig(num_hi_tokens=8),
                               kv=KVCacheConfig(num_hi=8))
        eng = ServingEngine(trained["params"], CFG, serve,
                            EngineConfig(max_batch=4, bucket=32, max_seq=64))
        rng = np.random.default_rng(0)
        for _ in range(6):
            eng.submit(rng.integers(0, CFG.vocab_size, 20),
                       max_new_tokens=8)
        done = eng.run()
        assert len(done) == 6
        assert all(len(r.out_tokens) == 8 for r in done)
        assert not eng.queue

    def test_stamp_serving_tracks_bf16(self, trained):
        params = trained["params"]
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, CFG.vocab_size, (4, 32)).astype(np.int32)

        def first_tokens(serve, p):
            logits, cache = lm.prefill(p, {"tokens": jnp.asarray(prompts)},
                                       CFG, serve)
            return np.asarray(jnp.argmax(logits, -1))

        bf16 = first_tokens(lm.ServeConfig(
            stamp=None, kv=KVCacheConfig(quantized=False),
            weight_bits=None), params)
        stamp = first_tokens(lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8),
            kv=KVCacheConfig(num_hi=8), weight_bits=None), params)
        agree = (bf16 == stamp).mean()
        assert agree >= 0.5, f"STaMP serving diverged: {agree:.0%} agreement"

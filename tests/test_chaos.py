"""Deterministic fault-injection (chaos) suite — marker ``chaos``, run as
its own CI step so tier-1 stays fast.

Every test drives the REAL serving paths (scheduler preemption, host page
swap, the unified step) through a seeded :class:`FaultPlan` and pins the
ISSUE's acceptance bar: the engine never raises out of ``run()``, every
request reaches exactly one terminal state with no page/slot leaks
(``Scheduler.quiescent()``), and the *surviving* requests' tokens are
bit-identical to a fault-free run."""

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.stamp import StampConfig
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving.engine import PagedEngineConfig, PagedServingEngine
from repro.serving.faults import FaultPlan

pytestmark = pytest.mark.chaos

CFG = ModelConfig(name="chaos-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)
PROMPT_LENS = (20, 45, 12, 30, 26)
MAX_NEW = (6, 4, 8, 5, 7)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [rng.integers(0, CFG.vocab_size, l) for l in PROMPT_LENS]


def paged_cfg(**kw):
    kw.setdefault("max_slots", 5)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def drain(pe, prompts, max_new=MAX_NEW):
    uids = [pe.submit(p, m) for p, m in zip(prompts, max_new)]
    done = pe.run()
    assert sorted(r.uid for r in done) == sorted(uids), \
        "some request never reached a terminal state"
    assert all(r.status in ("finished", "failed", "cancelled", "rejected")
               for r in done)
    assert pe.sched.quiescent(), "pages/slots leaked"
    return {r.uid: r for r in done}


@pytest.fixture(scope="module")
def oracle(params, prompts):
    """Fault-free tokens under the SAME chunking/slots (ample pool)."""
    pe = PagedServingEngine(params, CFG,
                            lm.ServeConfig(stamp=None, kv=QUANT),
                            paged_cfg())
    return {u: r.out_tokens for u, r in drain(pe, prompts).items()}


class TestExhaustionStorm:
    def test_preemption_storm_soak(self, params, prompts, oracle):
        """Injected page exhaustion on alternating steps: every allocation
        probe fails on those steps, so decode growth self-preempts and
        prefills stall — a storm of swap-outs through the production
        preemption path.  All requests must still finish, bit-identical
        to the fault-free run, with the pools fully drained."""
        fault = FaultPlan(seed=5, exhaust_steps=frozenset(
            range(2, 40, 3)))   # recovery gaps < watchdog_steps
        pe = PagedServingEngine(params, CFG,
                                lm.ServeConfig(stamp=None, kv=QUANT),
                                paged_cfg(), fault=fault)
        got = drain(pe, prompts)
        assert fault.injected["exhaustion"] > 0
        assert pe.stats["preemptions"] > 0, "the storm never preempted"
        assert pe.stats["watchdog_trips"] == 0
        for uid, req in got.items():
            assert req.status == "finished"
            np.testing.assert_array_equal(req.out_tokens, oracle[uid])


class TestSwapCorruption:
    def test_corrupted_swap_in_fails_exactly_that_request(self, params):
        """Force a natural preemption (tight pool), corrupt the first
        swap-in: the per-swap CRC must refuse the restore, the engine
        fails only the corrupted request, and the untouched request's
        tokens stay bit-identical to an uncontended run."""
        rng = np.random.default_rng(11)
        reqs = [rng.integers(0, CFG.vocab_size, 14),
                rng.integers(0, CFG.vocab_size, 40)]
        max_new = (6, 4)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        ample = PagedServingEngine(params, CFG, serve,
                                   paged_cfg(max_slots=2))
        want = {u: r.out_tokens
                for u, r in drain(ample, reqs, max_new).items()}

        fault = FaultPlan(seed=1, corrupt_swap_ins=frozenset({0}))
        pe = PagedServingEngine(
            params, CFG, serve,
            paged_cfg(max_slots=2, num_lo_blocks=3, max_prefills=1),
            fault=fault)
        got = drain(pe, reqs, max_new)
        assert fault.injected["swap_corruption"] == 1
        assert pe.stats["swap_corruptions"] == 1
        statuses = {u: r.status for u, r in got.items()}
        assert sorted(statuses.values()) == ["failed", "finished"]
        (bad,) = [u for u, s in statuses.items() if s == "failed"]
        assert "checksum" in got[bad].error.lower() \
            or "corrupt" in got[bad].error.lower()
        (good,) = [u for u in statuses if u != bad]
        np.testing.assert_array_equal(got[good].out_tokens, want[good])


class TestNaNQuarantine:
    def _fused_serve(self):
        return lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"),
            kv=QUANT, numerics_guard=True)

    def test_nan_quarantines_request_and_demotes_to_reference(self, params,
                                                              prompts):
        fault = FaultPlan(seed=0, nan_faults=frozenset({(2, 2)}))
        pe = PagedServingEngine(params, CFG, self._fused_serve(),
                                paged_cfg(max_slots=3), fault=fault)
        got = drain(pe, prompts[:3], MAX_NEW[:3])
        assert fault.injected["nan"] == 1
        assert got[2].status == "failed"
        assert "non-finite" in got[2].error
        assert len(got[2].out_tokens) == 2     # generation stopped at idx 2
        for uid in (1, 3):
            assert got[uid].status == "finished"
        assert pe.stats["nan_quarantines"] == 1
        assert pe.stats["demotions"] == 1
        assert pe._demoted
        kinds = [k for _, k, _ in pe.events]
        assert "fault_nan" in kinds and "nan_quarantine" in kinds \
            and "demote" in kinds
        # demoted engine runs the retained ORIGINAL weights (wq/wk/wv
        # split again, no prepared int8 buffers)
        assert pe.serve.stamp.execution == "reference"
        assert not pe.serve.fused_decode_matmul

    def test_demotion_can_be_disabled(self, params, prompts):
        fault = FaultPlan(seed=0, nan_faults=frozenset({(1, 1)}))
        pe = PagedServingEngine(
            params, CFG, self._fused_serve(),
            paged_cfg(max_slots=3, demote_on_nan=False), fault=fault)
        got = drain(pe, prompts[:3], MAX_NEW[:3])
        assert got[1].status == "failed"
        assert pe.stats["nan_quarantines"] == 1
        assert pe.stats["demotions"] == 0 and not pe._demoted
        assert pe.serve.stamp.execution == "fused"

    def test_guard_off_documents_silent_degradation(self, params, prompts):
        """With numerics_guard off (the default), an injected NaN row
        greedy-samples token 0 and the request runs to completion — the
        pre-robustness behavior, kept reachable on purpose so the guard's
        cost stays opt-in."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT)  # guard defaults off
        fault = FaultPlan(seed=0, nan_faults=frozenset({(1, 1)}))
        pe = PagedServingEngine(params, CFG, serve, paged_cfg(),
                                fault=fault)
        got = drain(pe, prompts[:2], MAX_NEW[:2])
        assert got[1].status == "finished"
        assert got[1].out_tokens[1] == 0       # argmax over all-NaN row
        assert pe.stats["nan_quarantines"] == 0


class TestPrefixSharingChaos:
    def _shared_reqs(self, seed=21, n=5):
        rng = np.random.default_rng(seed)
        pre = rng.integers(0, CFG.vocab_size, 32)
        return [np.concatenate([pre, rng.integers(0, CFG.vocab_size, w)])
                for w in (10, 14, 8, 12, 9)[:n]]

    def _oracle(self, params, reqs, **kw):
        pe = PagedServingEngine(params, CFG,
                                lm.ServeConfig(stamp=None, kv=QUANT),
                                paged_cfg(**kw))
        got = drain(pe, reqs, (6,) * len(reqs))
        assert pe.stats["prefix_cache_hits"] > 0, \
            "chaos workload must actually share prefixes"
        return {u: r.out_tokens for u, r in got.items()}

    def test_exhaustion_storm_with_prefix_sharing(self, params):
        """Injected page exhaustion while requests share cached prefix
        pages: preemption releases shared refs mid-storm, eviction churns
        the zero-ref cache under the survivors — every request must still
        finish bit-identical to a fault-free prefix-sharing run with the
        pools fully drained (no leaked ref, no double free)."""
        reqs = self._shared_reqs()
        kw = dict(max_slots=2)                   # serialize → warm hits
        oracle = self._oracle(params, reqs, **kw)
        fault = FaultPlan(seed=5, exhaust_steps=frozenset(range(2, 40, 3)))
        pe = PagedServingEngine(params, CFG,
                                lm.ServeConfig(stamp=None, kv=QUANT),
                                paged_cfg(**kw), fault=fault)
        got = drain(pe, reqs, (6,) * len(reqs))
        assert fault.injected["exhaustion"] > 0
        assert pe.stats["preemptions"] > 0, "the storm never preempted"
        assert pe.stats["prefix_cache_hits"] > 0
        for uid, req in got.items():
            assert req.status == "finished"
            np.testing.assert_array_equal(req.out_tokens, oracle[uid])
        assert pe.sched.alloc.all_free()

    def test_flush_fault_storm_keeps_sharers_alive(self, params):
        """Periodic whole-cache flushes (``FaultPlan.flush_prefix_steps``)
        while sharers are in flight: requests already holding refs to
        de-registered pages keep them until release, later arrivals just
        miss — tokens stay bit-identical and nothing leaks once drained."""
        reqs = self._shared_reqs(seed=23)
        kw = dict(max_slots=2)
        oracle = self._oracle(params, reqs, **kw)
        fault = FaultPlan(seed=7,
                          flush_prefix_steps=frozenset(range(1, 30, 4)))
        pe = PagedServingEngine(params, CFG,
                                lm.ServeConfig(stamp=None, kv=QUANT),
                                paged_cfg(**kw), fault=fault)
        got = drain(pe, reqs, (6,) * len(reqs))
        assert fault.injected["prefix_flush"] > 0
        assert "fault_prefix_flush" in [k for _, k, _ in pe.events]
        for uid, req in got.items():
            assert req.status == "finished"
            np.testing.assert_array_equal(req.out_tokens, oracle[uid])
        assert pe.sched.alloc.all_free()
        assert pe.stats["prefix_cached_pages"] == \
            pe.sched.alloc.cache_stats()["cached_pages"]


class TestSeededSoak:
    def test_combined_faults_reproducible(self, params, prompts):
        """Rate-based exhaustion + swap corruption + NaN under one seed on
        a tight pool: every request reaches a terminal state with no
        leaks, and replaying the identical plan reproduces every status
        and every token bit-for-bit."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT, numerics_guard=True)

        def once():
            fault = FaultPlan(seed=3, exhaust_rate=0.35, corrupt_rate=0.5,
                              nan_rate=0.01, window=(1, 60))
            pe = PagedServingEngine(
                params, CFG, serve,
                paged_cfg(max_slots=3, num_lo_blocks=7, watchdog_steps=6),
                fault=fault)
            return drain(pe, prompts), pe

        got_a, pe_a = once()
        got_b, pe_b = once()
        assert {u: r.status for u, r in got_a.items()} == \
            {u: r.status for u, r in got_b.items()}
        for uid in got_a:
            np.testing.assert_array_equal(got_a[uid].out_tokens,
                                          got_b[uid].out_tokens)
        assert pe_a.stats == pe_b.stats

"""Unit + property tests for the quantizer (paper §2.1, Eq. 1–3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant as Q
from repro.core import error_bounds as EB

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


class TestFakeQuant:
    def test_roundtrip_error_bounded_by_half_scale(self):
        x = rand((4, 32, 64))
        for bits in (2, 4, 8):
            scale, _ = Q.minmax_scale_offset(x, bits, axis=-1)
            q = Q.fake_quant(x, bits, axis=-1)
            assert float(jnp.max(jnp.abs(q - x) - scale / 2)) <= 1e-5

    def test_no_clipping_minmax(self):
        """Min-max scales guarantee zero clipping error (§2.1)."""
        x = rand((2, 16, 32), seed=1)
        scale, zp = Q.minmax_scale_offset(x, 4, axis=-1)
        q = Q.quantize(x, scale, zp, 4)
        # extreme values representable exactly (up to rounding)
        deq = Q.dequantize(q, scale, zp)
        assert float(jnp.max(jnp.abs(jnp.max(deq, -1) - jnp.max(x, -1)))) < \
            float(jnp.max(scale))

    def test_idempotent_on_grid(self):
        x = rand((2, 8, 16), seed=2)
        q1 = Q.fake_quant(x, 4, axis=-1)
        q2 = Q.fake_quant(q1, 4, axis=-1)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                                   rtol=0, atol=1e-5)

    def test_mixed_precision_bits_vector(self):
        bits = Q.mixed_precision_bits(2048, 64)
        assert float(bits[0]) == 8 and float(bits[64]) == 4
        assert abs(Q.average_bits(bits) - 4.125) < 1e-6

    def test_mixed_precision_quant_runs_per_token(self):
        x = rand((2, 128, 32), seed=3)
        bits = Q.mixed_precision_bits(128, 16)
        q = Q.fake_quant(x, bits, axis=-1)
        # first 16 tokens quantized at 8 bits → smaller error than the tail
        err_hi = float(jnp.mean((q - x)[:, :16] ** 2))
        err_lo = float(jnp.mean((q - x)[:, 16:] ** 2))
        assert err_hi < err_lo

    def test_per_block(self):
        x = rand((2, 16, 64), seed=4)
        qb = Q.fake_quant_per_block(x, 4, block_size=16)
        qt = Q.fake_quant(x, 4, axis=-1)
        errb = float(jnp.sum((qb - x) ** 2))
        errt = float(jnp.sum((qt - x) ** 2))
        assert errb <= errt + 1e-6   # finer granularity never hurts

    def test_ste_gradient(self):
        x = rand((2, 8, 16), seed=5)
        g = jax.grad(lambda t: jnp.sum(Q.fake_quant(t, 4, axis=-1)))(x)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.abs(g).sum()) > 0


class TestWeightQuant:
    def test_rtn_range_search_beats_plain_minmax(self):
        rng = np.random.default_rng(0)
        w = rng.normal(size=(128, 64)).astype(np.float32)
        w[0, 0] = 20.0   # outlier: range search should clip it
        plain = Q.rtn_quantize_weight(jnp.asarray(w), bits=4, axis=0,
                                      num_candidates=1, min_shrink=1.0)
        searched = Q.rtn_quantize_weight(jnp.asarray(w), bits=4, axis=0)
        err_p = float(jnp.sum((plain.dequant(jnp.float32) - w) ** 2))
        err_s = float(jnp.sum((searched.dequant(jnp.float32) - w) ** 2))
        assert err_s <= err_p

    def test_int_storage(self):
        w = rand((32, 16), seed=7)
        qw = Q.rtn_quantize_weight(w, bits=4, axis=0)
        assert qw.q.dtype == jnp.int8
        assert int(jnp.max(qw.q)) <= 15 and int(jnp.min(qw.q)) >= 0


class TestBounds:
    def test_eq3_bound_holds(self):
        x = rand((2, 32, 64), seed=8)
        for bits in (3, 4, 6):
            measured = float(EB.measured_error(x, bits))
            bound = float(EB.eq3_bound(x, bits))
            assert measured <= bound * (1 + 1e-5)

    @settings(deadline=None, max_examples=20)
    @given(bits=st.integers(2, 8), seed=st.integers(0, 100))
    def test_eq3_property(self, bits, seed):
        x = rand((1, 16, 32), seed=seed)
        assert float(EB.measured_error(x, bits)) <= \
            float(EB.eq3_bound(x, bits)) * (1 + 1e-5)

    def test_sqnr_infinite_for_exact(self):
        x = rand((2, 4, 8), seed=9)
        assert float(Q.sqnr_db(x, x)) > 80

"""Unified ragged-step tests: bit-identical token parity between the
unified engine and the PR-3 two-call step pair on a mixed workload
(staggered admissions, chunked prompts, preemption + resume mid-prefill),
ragged-kernel-vs-oracle parity at odd chunk lengths and ``num_hi >= seq``,
the jit-recompile guard (fixed compile count per engine run), the
segment-aware STaMP transform application, and the scheduler determinism /
transform-window satellites."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.stamp import (StampConfig, fold_segments, stamp_fake_quant,
                              stamp_linear, unfold_segments)
from repro.kernels import ref
from repro.kernels.paged_attention import paged_ragged_attention
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.serving.engine import (PagedEngineConfig, PagedServingEngine,
                                  _transform_window)
from repro.serving.paged_kvcache import PagedCacheConfig
from repro.serving.scheduler import (PREFILLING, SchedRequest, Scheduler,
                                     SchedulerConfig)

CFG = ModelConfig(name="unified-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)
# more requests than slots (staggered admission waves), prompts spanning
# one to three 16-token chunks
PROMPT_LENS = (20, 40, 12, 33, 26)
MAX_NEW = (14, 10, 16, 8, 12)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [rng.integers(0, CFG.vocab_size, l) for l in PROMPT_LENS]


def paged_cfg(**kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def run_engine(engine, prompts, max_new=MAX_NEW):
    for p, m in zip(prompts, max_new):
        engine.submit(p, m)
    done = engine.run()
    lm.set_fused_cache_attention(False)
    return {r.uid: r.out_tokens for r in done}


# ---------------------------------------------------------------------------
# unified vs two-call engine: bit-identical tokens
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def contended_runs(params, prompts):
    """Mixed workload under page pressure: chunked prompts, staggered
    admissions (5 requests, 3 slots) and a lo pool tight enough to preempt
    mid-prefill — one run per step mode, shared by the assertions below."""
    serve = lm.ServeConfig(stamp=None, kv=QUANT)
    out = {}
    for mode in ("two_call", "unified"):
        eng = PagedServingEngine(params, CFG, serve,
                                 paged_cfg(max_slots=5, num_lo_blocks=6,
                                           step_mode=mode))
        out[mode] = (run_engine(eng, prompts), eng)
    return out


class TestUnifiedEngineParity:
    def test_token_identical_under_preemption(self, contended_runs):
        """The unified ragged step must reproduce the two-call engine token
        for token across chunked prefill, join/leave and preempt+resume."""
        two, _ = contended_runs["two_call"]
        uni, eng = contended_runs["unified"]
        assert set(two) == set(uni)
        for uid in two:
            np.testing.assert_array_equal(two[uid], uni[uid],
                                          err_msg=f"uid={uid}")

    def test_workload_actually_contended(self, contended_runs):
        """The parity claim is vacuous unless the workload really exercised
        preemption, resumes and multi-chunk prefill."""
        _, eng = contended_runs["unified"]
        assert eng.stats["preemptions"] > 0
        kinds = [k for _, k, _ in eng.events]
        assert "resume" in kinds
        chunk_counts = {}
        for _, k, p in eng.events:
            if k == "prefill_chunk":
                chunk_counts[p[0]] = chunk_counts.get(p[0], 0) + 1
        assert max(chunk_counts.values()) >= 3   # 40-token prompt, chunk 16

    def test_one_dispatch_per_step(self, contended_runs):
        """The tentpole: every unified step is exactly one device program;
        the two-call pair exceeds one per step on mixed steps."""
        _, uni = contended_runs["unified"]
        _, two = contended_runs["two_call"]
        assert uni.stats["device_dispatches"] == uni.stats["steps"]
        assert two.stats["device_dispatches"] > two.stats["steps"]

    def test_stamp_fused_parity(self, params, prompts):
        """Same parity under the fused STaMP integer path (prepared int8
        weights, fused decode matmul) — the segment rule must hold through
        the Pallas kernels."""
        serve = lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"), kv=QUANT)
        short = prompts[:3]
        new = MAX_NEW[:3]
        two = run_engine(PagedServingEngine(
            params, CFG, serve, paged_cfg(step_mode="two_call")), short, new)
        uni = run_engine(PagedServingEngine(
            params, CFG, serve, paged_cfg()), short, new)
        for uid in two:
            np.testing.assert_array_equal(two[uid], uni[uid],
                                          err_msg=f"uid={uid}")


class TestRecompileGuard:
    def test_fixed_compile_count_per_run(self, params, prompts):
        """Shape bucketing bounds the jit variants: one engine run compiles
        at most |{0, 1, 2, …, max_prefills}| unified programs, and feeding
        more work through the same engine adds none."""
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        eng = PagedServingEngine(params, CFG, serve, paged_cfg())
        run_engine(eng, prompts)
        first_count = eng.compile_count()
        assert first_count <= len(eng._npf_buckets)
        assert eng.stats["recompiles"] == len(eng._compiled_keys)
        run_engine(eng, prompts)          # same shapes: zero new compiles
        assert eng.compile_count() == first_count

    def test_events_ring_buffer_capped(self, params, prompts):
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        eng = PagedServingEngine(params, CFG, serve,
                                 paged_cfg(max_events=16))
        run_engine(eng, prompts)
        assert len(eng.events) == 16      # trace clipped to the newest N
        assert eng.events.maxlen == 16


# ---------------------------------------------------------------------------
# ragged kernel vs oracle
# ---------------------------------------------------------------------------


class TestRaggedKernel:
    def _setup(self, c_len=24):
        cfg = PagedCacheConfig(block_size=8, num_lo_blocks=16,
                               num_hi_blocks=8, max_blocks_per_seq=4,
                               quant=QUANT)
        rng = np.random.default_rng(3)
        g, hd, h = 2, 16, 4
        entry = {k: a[0] for k, a in PKV.init_pools(1, g, hd, cfg).items()}
        # span 0: continuation chunk with ODD valid length (start 16,
        # materialized 27); span 1: first chunk, num_hi(16) ≥ its early
        # positions; spans 2-3: decode slots, span 3 with num_hi >= seq
        reqs = {0: ([1, 2], [1, 2], 27), 1: ([3, 4], [3], 21),
                2: ([5, 6], [4, 5], 30), 3: ([7, 0], [0, 0], 9)}
        for uid, (hp, lp, ln) in reqs.items():
            k = jnp.asarray(rng.normal(size=(1, ln, g, hd)
                                       ).astype(np.float32))
            v = jnp.asarray(rng.normal(size=(1, ln, g, hd)
                                       ).astype(np.float32))
            pages, offs, ishi = [], [], []
            for pos in range(ln):
                is_hi, pidx, off = PKV.token_page_index(pos, cfg)
                pages.append((hp if is_hi else lp)[pidx])
                offs.append(off)
                ishi.append(is_hi)
            entry = PKV.write_chunk(entry, k, v,
                                    jnp.asarray(pages, jnp.int32),
                                    jnp.asarray(offs, jnp.int32),
                                    jnp.asarray(ishi, bool), cfg)
        q_pf = jnp.asarray(rng.normal(size=(2, c_len, h, hd)
                                      ).astype(np.float32))
        q_dec = jnp.asarray(rng.normal(size=(2, 1, h, hd)
                                       ).astype(np.float32))
        starts = jnp.asarray([16, 0, 29, 8], jnp.int32)
        lengths = jnp.asarray([27, 21, 30, 9], jnp.int32)
        ht = jnp.asarray([reqs[i][0] for i in range(4)], jnp.int32)
        lt = jnp.asarray([reqs[i][1] + [0] * (4 - len(reqs[i][1]))
                          for i in range(4)], jnp.int32)
        return cfg, entry, q_pf, q_dec, starts, lengths, ht, lt

    def test_matches_oracle_mixed_spans(self):
        """Prefill spans (odd valid length, a no-prefix first chunk) and
        decode spans (one with num_hi ≥ seq) in one grid, vs the dense
        masked-softmax oracle.  Only valid chunk rows compared — pad rows
        are defined but discarded by the caller."""
        cfg, entry, q_pf, q_dec, starts, lengths, ht, lt = self._setup()
        out_pf, out_dec = paged_ragged_attention(
            entry, q_pf, q_dec, starts, lengths, ht, lt, cfg.block_size,
            interpret=True)
        ref_pf, ref_dec = ref.paged_ragged_attention_ref(
            entry, q_pf, q_dec, starts, lengths, ht, lt)
        valid = (int(lengths[0] - starts[0]), int(lengths[1] - starts[1]))
        for i, n in enumerate(valid):
            np.testing.assert_allclose(
                np.asarray(out_pf[i, :n], np.float32),
                np.asarray(ref_pf[i, :n]), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out_dec, np.float32),
                                   np.asarray(ref_dec), atol=1e-5,
                                   rtol=1e-5)

    def test_all_decode_delegates_to_decode_kernel(self):
        """n_pf = 0 (the steady-state fast case) must route through the
        existing decode kernel and agree with the oracle."""
        cfg, entry, q_pf, q_dec, starts, lengths, ht, lt = self._setup()
        out_pf, out_dec = paged_ragged_attention(
            entry, q_pf[:0], q_dec, starts[2:], lengths[2:], ht[2:],
            lt[2:], cfg.block_size, interpret=True)
        assert out_pf.shape[0] == 0
        _, ref_dec = ref.paged_ragged_attention_ref(
            entry, q_pf[:0], q_dec, starts[2:], lengths[2:], ht[2:], lt[2:])
        np.testing.assert_allclose(np.asarray(out_dec, np.float32),
                                   np.asarray(ref_dec), atol=1e-5,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# segment-aware STaMP application
# ---------------------------------------------------------------------------


class TestSegmentedStamp:
    def test_fold_unfold_roundtrip(self):
        x = jnp.arange(2 * 12 * 3, dtype=jnp.float32).reshape(2, 12, 3)
        f = fold_segments(x, 4)
        assert f.shape == (6, 4, 3)
        np.testing.assert_array_equal(np.asarray(unfold_segments(f, 2)),
                                      np.asarray(x))
        with pytest.raises(ValueError):
            fold_segments(x, 5)

    def test_fake_quant_per_span(self):
        """seg_len round trip == running each span alone: the transform
        never mixes tokens across the flattened batch."""
        cfg = StampConfig(num_hi_tokens=4)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1, 32, 16)).astype(np.float32))
        seg = stamp_fake_quant(x, cfg, seg_len=8)
        per_span = jnp.concatenate(
            [stamp_fake_quant(x[:, i:i + 8], cfg) for i in range(0, 32, 8)],
            axis=1)
        np.testing.assert_array_equal(np.asarray(seg),
                                      np.asarray(per_span))

    def test_segment_kernel_wrapper_per_span(self):
        """`stamp_quant_segment_matmul_pallas` (the kernel-level entry for
        flattened callers) == one plain kernel call per span."""
        from repro.core.stamp import prepare_linear
        from repro.kernels.stamp_matmul import (
            stamp_quant_matmul_pallas, stamp_quant_segment_matmul_pallas)
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(2, 24, 16)).astype(np.float32))
        prep = prepare_linear(
            jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32)))
        bias = jnp.zeros((1, 32), jnp.float32)
        kw = dict(transform="dwt", levels=1, num_hi=4, interpret=True)
        seg = stamp_quant_segment_matmul_pallas(
            x, prep.qw, prep.sw, prep.zw, bias, seg_len=8, **kw)
        per_span = jnp.concatenate(
            [stamp_quant_matmul_pallas(x[:, i:i + 8], prep.qw, prep.sw,
                                       prep.zw, bias, **kw)
             for i in range(0, 24, 8)], axis=1)
        np.testing.assert_allclose(np.asarray(seg), np.asarray(per_span),
                                   atol=1e-6, rtol=1e-6)
        with pytest.raises(ValueError):
            stamp_quant_segment_matmul_pallas(
                x, prep.qw, prep.sw, prep.zw, bias, seg_len=7, **kw)

    @pytest.mark.parametrize("execution", ["reference", "fused"])
    def test_stamp_linear_per_span(self, execution):
        cfg = StampConfig(num_hi_tokens=4, execution=execution)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(1, 32, 16)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
        seg = stamp_linear(x, w, None, cfg, seg_len=8)
        per_span = jnp.concatenate(
            [stamp_linear(x[:, i:i + 8], w, None, cfg)
             for i in range(0, 32, 8)], axis=1)
        np.testing.assert_allclose(np.asarray(seg, np.float32),
                                   np.asarray(per_span, np.float32),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# scheduler satellites: determinism + transform-aware boundaries
# ---------------------------------------------------------------------------


def _mk_sched(**kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("prefill_chunk", 16)
    scfg = SchedulerConfig(**kw)
    pcfg = PagedCacheConfig(block_size=8, num_lo_blocks=64, num_hi_blocks=16,
                            max_blocks_per_seq=8, quant=QUANT)
    return Scheduler(scfg, pcfg, swap_out=lambda r: None,
                     swap_in=lambda r: None)


def _req(uid, length, arrival=None):
    return SchedRequest(uid=uid, prompt=np.zeros(length, np.int32),
                        max_new_tokens=4,
                        arrival=uid if arrival is None else arrival)


class TestSchedulerSatellites:
    def test_victim_tie_break_is_uid(self):
        """Equal arrivals: the evicted victim must be the highest (arrival,
        uid) pair, not whichever request happened to be admitted last."""
        sched = _mk_sched(max_prefills=3)   # all three reserve pages
        a, b, c = _req(1, 8, arrival=5), _req(3, 8, arrival=5), \
            _req(2, 8, arrival=5)
        for r in (a, b, c):
            sched.submit(r)
        sched.plan_step()
        victim = sched._pick_victim(exclude=None)
        assert victim.uid == 3

    def test_waiting_order_tie_break(self):
        sched = _mk_sched(max_slots=1)
        for r in (_req(2, 8, arrival=7), _req(1, 8, arrival=7)):
            sched.submit(r)
        assert [r.uid for r in sched.waiting] == [1, 2]

    def test_free_slots_heap_lowest_first(self):
        sched = _mk_sched(max_slots=3)
        reqs = [_req(i, 8) for i in (1, 2, 3)]
        for r in reqs:
            sched.submit(r)
        sched.plan_step()
        slots = {r.uid: r.slot for r in reqs}
        assert slots == {1: 0, 2: 1, 3: 2}
        reqs[1].state = "running"
        sched.finish(reqs[1])             # frees slot 1
        sched.submit(_req(4, 8))
        sched.plan_step()
        assert sched.active[-1].slot == 1  # lowest free slot reused

    def test_transform_window_alignment(self):
        """Non-final chunk ends align down to the window; the final chunk
        keeps the exact prompt end; a window larger than the chunk falls
        back to the unaligned end (per-chunk transform spans the chunk)."""
        sched = _mk_sched(prefill_chunk=12, transform_window=8,
                          max_prefills=2)
        r = _req(1, 40)
        sched.submit(r)
        plan = sched.plan_step()
        (w,) = plan.prefills
        assert (w.start, w.end) == (0, 8)   # 12 aligned down to 8
        r.pos = w.end
        plan = sched.plan_step()
        assert (plan.prefills[0].start, plan.prefills[0].end) == (8, 16)
        r.pos = 36                          # 4 tokens left < window
        plan = sched.plan_step()
        assert plan.prefills[0].end == 40   # final chunk: exact prompt end

    def test_window_larger_than_chunk_falls_back(self):
        sched = _mk_sched(prefill_chunk=8, transform_window=32)
        r = _req(1, 40)
        sched.submit(r)
        plan = sched.plan_step()
        assert plan.prefills[0].end == 8    # unaligned (documented fallback)

    def test_multiple_prefills_fcfs(self):
        """max_prefills > 1: several PREFILLING requests chunk in the same
        step, strictly FCFS-ordered."""
        sched = _mk_sched(max_prefills=3)
        reqs = [_req(i, 40) for i in (1, 2, 3)]
        for r in reqs:
            sched.submit(r)
        plan = sched.plan_step()
        assert [w.sreq.uid for w in plan.prefills] == [1, 2, 3]
        assert all(r.state == PREFILLING for r in reqs)
        spans = plan.spans()
        assert [s[1] for s in spans] == [0, 16, 32]   # ragged offsets
        assert all(s[2] == 16 for s in spans)

    def test_engine_transform_window_helper(self):
        st = StampConfig(num_hi_tokens=8)     # levels auto
        assert _transform_window(st, 64) == 2 ** st.resolved_levels(64)
        assert _transform_window(None, 64) == 1
        assert _transform_window(StampConfig(enabled=False), 64) == 1
        # window > chunk → fallback 1
        deep = StampConfig(num_hi_tokens=1, levels=10)
        assert _transform_window(deep, 64) == 1

"""Prefix-sharing KV cache tests: the ref-counted hash-addressed page
store (acquire/release lifecycle, LRU eviction of zero-ref cached pages,
whole-cache flush), prefix registration/lookup with quantum alignment and
partial-tail matching, engine-level copy-on-write with bit-identical
tokens, shared-page preemption + swap roundtrip, the cancel-while-sharing
leak oracle, the capacity-rejection prefix credit (EOS early stop), and
gauge recomputation across ``reset_stats`` and fused → reference
demotion."""

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.stamp import StampConfig
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.serving.engine import PagedEngineConfig, PagedServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.paged_kvcache import (BlockAllocator, OutOfBlocks,
                                         PagedCacheConfig)

CFG = ModelConfig(name="prefix-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)
BF16 = KV.KVCacheConfig(quantized=False)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


def paged_cfg(**kw):
    kw.setdefault("max_slots", 5)
    kw.setdefault("prefill_chunk", 32)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def lo_alloc(n_lo: int = 8) -> BlockAllocator:
    """bf16 (lo-pool-only) allocator: every token page is a lo page, so
    the page math in the store tests stays one-dimensional."""
    return BlockAllocator(PagedCacheConfig(block_size=8, num_lo_blocks=n_lo,
                                           num_hi_blocks=1, quant=BF16))


def toks(*vals) -> np.ndarray:
    return np.asarray(vals, dtype=np.int32)


# ---------------------------------------------------------------------------
# ref-count lifecycle
# ---------------------------------------------------------------------------


class TestRefCounting:
    def test_acquire_release_roundtrip(self):
        a = lo_alloc()
        p = a.alloc_lo()
        assert a.ref_count("lo", p) == 1
        a.acquire([], [p])
        assert a.ref_count("lo", p) == 2
        a.release([], [p])
        assert a.ref_count("lo", p) == 1
        a.release([], [p])                       # uncached → free list
        assert a.ref_count("lo", p) == 0
        assert a.alloc_lo() == p                 # lowest-first reuse

    def test_acquire_unallocated_raises(self):
        a = lo_alloc()
        with pytest.raises(ValueError, match="not allocated"):
            a.acquire([], [3])

    def test_release_of_cached_page_parks_evictable(self):
        a = lo_alloc()
        prompt = np.arange(8, dtype=np.int32)
        p = a.alloc_lo()
        assert a.register_prefix(prompt, 8, [], [p]) == 1
        a.release([], [p])
        assert a.ref_count("lo", p) == 0
        assert a.evictable_counts() == (0, 1)
        assert a.all_free()                      # evictable = reclaimable
        with pytest.raises(ValueError, match="double free"):
            a.release([], [p])                   # guard survives parking

    def test_lookup_reacquires_evictable_page(self):
        a = lo_alloc()
        prompt = np.arange(8, dtype=np.int32)
        p = a.alloc_lo()
        a.register_prefix(prompt, 8, [], [p])
        a.release([], [p])
        m = a.lookup_prefix(np.arange(12, dtype=np.int32), limit=11,
                            quantum=4)
        assert m is not None and m.matched == 8 and m.lo_pages == [p]
        assert a.ref_count("lo", p) == 1
        assert a.evictable_counts() == (0, 0)
        a.release([], [p])

    def test_register_same_prefix_twice_keeps_first(self):
        """A second request materializing the same prefix privately must
        not steal the registration (digest-collision skip) — its pages
        stay private and free normally."""
        a = lo_alloc()
        prompt = np.arange(8, dtype=np.int32)
        p1, p2 = a.alloc_lo(), a.alloc_lo()
        assert a.register_prefix(prompt, 8, [], [p1]) == 1
        assert a.register_prefix(prompt, 8, [], [p2]) == 0
        a.release([], [p2])
        assert a.evictable_counts() == (0, 0)    # p2 went straight to free
        a.release([], [p1])
        assert a.evictable_counts() == (0, 1)


# ---------------------------------------------------------------------------
# LRU eviction + flush
# ---------------------------------------------------------------------------


class TestEvictionAndFlush:
    def test_lru_eviction_order_is_release_order(self):
        a = lo_alloc(n_lo=4)                     # pages 1, 2, 3 allocatable
        pa, pb, pc = a.alloc_lo(), a.alloc_lo(), a.alloc_lo()
        a.register_prefix(toks(1, 2, 3, 4, 5, 6, 7, 8), 8, [], [pa])
        a.register_prefix(toks(9, 8, 7, 6, 5, 4, 3, 2), 8, [], [pb])
        a.release([], [pa])                      # oldest evictable
        a.release([], [pb])
        a.release([], [pc])                      # unregistered → free list
        assert a.free_counts()[1] == 1 and a.evictable_counts()[1] == 2
        assert a.alloc_lo() == pc                # free list drains first
        assert a.alloc_lo() == pa                # then LRU-oldest evicts
        assert a.alloc_lo() == pb
        assert a.cache_evictions == 2
        assert a.cache_stats()["cached_pages"] == 0
        with pytest.raises(OutOfBlocks):
            a.alloc_lo()

    def test_lookup_refreshes_lru_recency(self):
        a = lo_alloc(n_lo=3)                     # pages 1, 2 allocatable
        pr_a, pr_b = toks(*range(8)), toks(*range(8, 16))
        pa, pb = a.alloc_lo(), a.alloc_lo()
        a.register_prefix(pr_a, 8, [], [pa])
        a.register_prefix(pr_b, 8, [], [pb])
        a.release([], [pa])
        a.release([], [pb])                      # LRU order: pa, pb
        m = a.lookup_prefix(pr_a, limit=7, quantum=1)
        assert m is not None                     # (partial-tail hit)
        a.release(m.hi_pages, m.lo_pages)        # pa re-released → newest
        assert a.alloc_lo() == pb                # pb is now the LRU victim

    def test_flush_cache_drops_all_registrations(self):
        a = lo_alloc()
        pa, pb = a.alloc_lo(), a.alloc_lo()
        a.register_prefix(toks(*range(8)), 8, [], [pa])
        a.register_prefix(toks(*range(8, 16)), 8, [], [pb])
        a.release([], [pa])                      # evictable
        assert a.flush_cache() == 2              # pb unregistered in place
        assert a.cache_stats()["cached_pages"] == 0
        assert a.evictable_counts() == (0, 0)
        assert a.ref_count("lo", pb) == 1        # still held by its owner
        a.release([], [pb])
        assert a.evictable_counts() == (0, 0)    # freed, not re-parked
        assert a.all_free()


# ---------------------------------------------------------------------------
# registration + lookup semantics
# ---------------------------------------------------------------------------


class TestPrefixLookup:
    def _registered(self):
        a = lo_alloc()
        prompt = np.arange(24, dtype=np.int32)   # 3 full pages
        pages = [a.alloc_lo() for _ in range(3)]
        assert a.register_prefix(prompt, 24, [], pages) == 3
        a.release([], pages)
        return a, prompt, pages

    def test_full_match_quantum_and_limit(self):
        a, prompt, pages = self._registered()
        longer = np.concatenate([prompt, toks(99, 98, 97)])
        m = a.lookup_prefix(longer, limit=len(longer) - 1, quantum=8)
        assert m.matched == 24 and m.lo_pages == pages and m.cow is None
        a.release(m.hi_pages, m.lo_pages)
        # the limit caps the match below the full registration …
        m = a.lookup_prefix(prompt, limit=23, quantum=8)
        assert m.matched == 16 and m.lo_pages == pages[:2]
        a.release(m.hi_pages, m.lo_pages)
        # … and the quantum aligns it down to a chunk boundary
        m = a.lookup_prefix(longer, limit=len(longer) - 1, quantum=16)
        assert m.matched == 16
        a.release(m.hi_pages, m.lo_pages)

    def test_partial_tail_match_sets_cow(self):
        a, prompt, pages = self._registered()
        div = prompt.copy()
        div[20:] = 120                           # diverges inside page 3
        div = np.concatenate([div, toks(1, 2, 3)])
        m = a.lookup_prefix(div, limit=len(div) - 1, quantum=4)
        assert m.matched == 20                   # 16 full + 4 common tail
        assert m.cow == ("lo", 2)                # page 3 must copy on write
        a.release(m.hi_pages, m.lo_pages)
        m = a.lookup_prefix(div, limit=len(div) - 1, quantum=8)
        assert m.matched == 16 and m.cow is None
        a.release(m.hi_pages, m.lo_pages)

    def test_peek_is_side_effect_free(self):
        a, prompt, pages = self._registered()
        before = a.evictable_counts()
        assert a.peek_prefix(prompt, limit=len(prompt) - 1, quantum=8) == 16
        assert a.evictable_counts() == before
        assert all(a.ref_count("lo", p) == 0 for p in pages)


# ---------------------------------------------------------------------------
# engine: copy-on-write + bit-identical tokens
# ---------------------------------------------------------------------------


def _run(pe, reqs, max_new):
    uids = [pe.submit(p, m) for p, m in zip(reqs, max_new)]
    done = {r.uid: r for r in pe.run()}
    assert sorted(done) == sorted(uids)
    return [done[u] for u in uids]               # submission order


class TestCopyOnWrite:
    def test_mid_page_divergence_cow_and_parity(self, params):
        """Two prompts sharing 40 tokens (divergence mid-page: 40 % 16)
        served serially with an 8-token chunk (quantum 8 → the match ends
        inside a shared page): the second request must CoW that page and
        still emit tokens bit-identical to a cache-off run."""
        rng = np.random.default_rng(7)
        base = rng.integers(0, CFG.vocab_size, 40)
        reqs = [np.concatenate([base, rng.integers(0, CFG.vocab_size, 18)]),
                np.concatenate([base, rng.integers(0, CFG.vocab_size, 14)])]
        max_new = (5, 6)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        on = PagedServingEngine(params, CFG, serve,
                                paged_cfg(max_slots=1, prefill_chunk=8))
        got_on = _run(on, reqs, max_new)
        off = PagedServingEngine(
            params, CFG, serve,
            paged_cfg(max_slots=1, prefill_chunk=8, prefix_caching=False))
        got_off = _run(off, reqs, max_new)
        for a, b in zip(got_on, got_off):
            np.testing.assert_array_equal(a.out_tokens, b.out_tokens)
        st = on.stats
        assert st["prefix_cache_hits"] >= 1
        assert st["cow_copies"] >= 1, "mid-page hit must copy-on-write"
        assert st["prefill_chunks"] < off.stats["prefill_chunks"]
        assert on.sched.quiescent() and on.sched.alloc.all_free()
        kinds = [k for _, k, _ in on.events]
        assert "prefix_hit" in kinds and "cow" in kinds


class TestSharedPreemption:
    def test_preempt_while_sharing_swap_roundtrip(self, params):
        """Tight lo pool + watermark: requests sharing cached prefix pages
        get preempted mid-flight (CRC'd host swap) and must resume to the
        same tokens a cache-off run produces — preemption releases shared
        refs without freeing pages other requests still read."""
        rng = np.random.default_rng(3)
        pre = rng.integers(0, CFG.vocab_size, 32)
        reqs = [np.concatenate([pre, rng.integers(0, CFG.vocab_size, n)])
                for n in (14, 16, 15, 13)]
        max_new = (6, 6, 6, 6)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        kw = dict(max_slots=3, num_lo_blocks=5, preempt_watermark=0.75)
        on = PagedServingEngine(params, CFG, serve, paged_cfg(**kw))
        got_on = _run(on, reqs, max_new)
        off = PagedServingEngine(params, CFG, serve,
                                 paged_cfg(prefix_caching=False, **kw))
        got_off = _run(off, reqs, max_new)
        assert on.stats["preemptions"] > 0, "pool never tightened"
        assert on.stats["swap_bytes"] > 0
        for a, b in zip(got_on, got_off):
            assert a.status == b.status == "finished"
            np.testing.assert_array_equal(a.out_tokens, b.out_tokens)
        assert on.sched.quiescent() and on.sched.alloc.all_free()


class TestCancelWhileSharing:
    def test_cancel_holding_shared_pages_leaks_nothing(self, params):
        """Cancel a request mid-flight while it holds references to cached
        prefix pages: the release must drop exactly its refs — the cache
        registrations survive, the other sharer finishes bit-identically,
        and the allocator drains to fully free."""
        rng = np.random.default_rng(9)
        pre = rng.integers(0, CFG.vocab_size, 48)
        reqs = [np.concatenate([pre, rng.integers(0, CFG.vocab_size, n)])
                for n in (10, 12)]
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        pe = PagedServingEngine(params, CFG, serve,
                                paged_cfg(prefill_chunk=16))
        pe.submit(pre, 1)                        # registers the prefix
        pe.run()
        uids = [pe.submit(p, 8) for p in reqs]
        done = []
        for _ in range(3):                       # both mid-flight, sharing
            pe._step(done)
        assert pe.stats["prefix_cache_hits"] >= 2
        assert pe.cancel(uids[0])
        done += pe.run()
        by_uid = {r.uid: r for r in done}
        assert by_uid[uids[0]].status == "cancelled"
        assert by_uid[uids[1]].status == "finished"
        assert pe.sched.quiescent() and pe.sched.alloc.all_free()
        off = PagedServingEngine(params, CFG, serve,
                                 paged_cfg(prefill_chunk=16,
                                           prefix_caching=False))
        want = _run(off, [reqs[1]], (8,))[0]
        np.testing.assert_array_equal(by_uid[uids[1]].out_tokens,
                                      want.out_tokens)


# ---------------------------------------------------------------------------
# capacity rejection credits the cached prefix (EOS early stop)
# ---------------------------------------------------------------------------


class TestCapacityPrefixCredit:
    def test_reject_then_would_have_fit(self, params):
        """A request whose WORST-CASE page demand (full max_new budget)
        exceeds the pool used to be rejected outright — even when a warm
        shared prefix meant it would start deep and stop at EOS long
        before that depth.  The admission check must credit fully shared
        pages; the credited request must then actually finish."""
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, CFG.vocab_size, 64)
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        kw = dict(max_slots=2, prefill_chunk=32, max_seq=112,
                  num_lo_blocks=6, num_hi_blocks=2)

        # oracle: learn the greedy continuation, pick an early token as
        # EOS that does not appear before its own index
        ora = PagedServingEngine(params, CFG, serve, paged_cfg(**kw))
        tokens = _run(ora, [prompt], (4,))[0].out_tokens
        k = next(i for i in range(2, len(tokens))
                 if tokens[i] not in tokens[:i])
        eos = int(tokens[k])

        pe = PagedServingEngine(params, CFG, serve,
                                paged_cfg(eos_id=eos, **kw))
        # the workload really is worst-case infeasible on this pool …
        nh, nl = PKV.pages_needed(64 + 47 - 1, pe.pcfg)
        cap_hi, cap_lo = pe.sched.alloc.capacity()
        assert nl > cap_lo, "test workload must exceed the raw capacity"
        # … so COLD it is rejected (the pre-credit behavior, still correct
        # when nothing is cached) …
        cold = pe.submit(prompt, 47)
        assert {r.uid: r for r in pe.run()}[cold].status == "rejected"
        # … warm the cache, and the same request must now be admitted and
        # finish via EOS far above the worst-case depth
        pe.submit(prompt, k)                     # registers prompt pages
        pe.run()
        big = pe.submit(prompt, 47)
        done = {r.uid: r for r in pe.run()}
        assert done[big].status == "finished", done[big].error
        assert len(done[big].out_tokens) <= k + 1
        assert int(done[big].out_tokens[-1]) == eos
        assert pe.sched.quiescent() and pe.sched.alloc.all_free()


# ---------------------------------------------------------------------------
# gauges: recomputed, never carried
# ---------------------------------------------------------------------------


class TestPrefixGauges:
    def _shared_reqs(self, seed=13, n=3):
        rng = np.random.default_rng(seed)
        pre = rng.integers(0, CFG.vocab_size, 32)
        return [np.concatenate([pre, rng.integers(0, CFG.vocab_size, 8)])
                for _ in range(n)]

    def test_reset_stats_recomputes_live_gauges(self, params):
        pe = PagedServingEngine(params, CFG,
                                lm.ServeConfig(stamp=None, kv=QUANT),
                                paged_cfg(max_slots=1))
        reqs = self._shared_reqs()
        _run(pe, reqs, (4,) * len(reqs))
        st = pe.stats
        assert st["prefix_cache_hits"] > 0
        assert st["prefix_cached_pages"] > 0
        cached = st["prefix_cached_pages"]
        pe.reset_stats(clear_events=True)
        st = pe.stats
        assert st["prefix_cache_hits"] == 0      # counters zeroed …
        assert st["prefix_cache_hit_rate"] == 0.0
        assert st["prefix_cached_pages"] == cached  # … gauges recomputed

    def test_demotion_keeps_live_gauges(self, params):
        """Fused → reference demotion rebuilds the step functions and
        re-derives every gauge — the prefix-cache occupancy must survive
        exactly like ``reference_fallback_sites`` does."""
        serve = lm.ServeConfig(
            stamp=StampConfig(num_hi_tokens=8, execution="fused"),
            kv=QUANT, numerics_guard=True)
        fault = FaultPlan(seed=0, nan_faults=frozenset({(2, 1)}))
        pe = PagedServingEngine(params, CFG, serve,
                                paged_cfg(max_slots=1), fault=fault)
        reqs = self._shared_reqs(seed=17)
        got = _run(pe, reqs, (4,) * len(reqs))   # uids are 1-based
        assert pe.stats["demotions"] == 1 and pe._demoted
        assert got[1].status == "failed"         # uid 2 = second submitted
        st = pe.stats
        assert st["prefix_cached_pages"] > 0
        assert st["prefix_cache_hits"] > 0
        assert pe.sched.quiescent() and pe.sched.alloc.all_free()

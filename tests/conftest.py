import itertools
import sys

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "chaos: deterministic fault-injection suite (own CI "
                   "step; tier-1 runs with -m 'not chaos')")


# ---------------------------------------------------------------------------
# hypothesis fallback
# ---------------------------------------------------------------------------
# `hypothesis` is a dev-only dependency (requirements-dev.txt) that is absent
# from the minimal runtime image; without a guard its import breaks
# *collection* of three test modules.  Rather than skipping those modules
# wholesale, install a deterministic micro-shim that evaluates each @given
# property on a small fixed grid of examples drawn from the declared
# strategies.  The real library (when installed) always takes precedence.

try:  # pragma: no cover - exercised only when hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    import types

    class _Strategy:
        def __init__(self, values):
            self.values = list(values)

    def _integers(min_value=0, max_value=10):
        lo, hi = int(min_value), int(max_value)
        span = hi - lo
        pts = sorted({lo, lo + span // 3, lo + (2 * span) // 3, hi})
        return _Strategy(pts)

    def _sampled_from(elements):
        return _Strategy(elements)

    def _booleans():
        return _Strategy([False, True])

    def _given(**strategies):
        names = list(strategies)
        cases = list(itertools.product(*(strategies[n].values
                                         for n in names)))
        argnames = ",".join(names)
        argvalues = cases if len(names) > 1 else [c[0] for c in cases]
        return pytest.mark.parametrize(argnames, argvalues)

    def _settings(**_ignored):
        # deadline/max_examples are hypothesis runtime knobs; the shim's
        # fixed grid is small enough that they can be ignored.
        def deco(fn):
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

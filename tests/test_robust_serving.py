"""Request-lifecycle robustness: submit validation, terminal states,
cancellation (including the cancel-at-every-step invariant audit),
deadlines under an injected clock, bounded-queue load shedding, watermark
preemption, and the no-progress watchdog.

Fault-injection *storms* live in test_chaos.py (marker ``chaos``, its own
CI step); this file is tier-1 — every test here is deterministic and
fault-free except the watchdog regression, which needs injected
exhaustion to reproduce the pre-fix livelock."""

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving import kvcache as KV
from repro.serving import paged_kvcache as PKV
from repro.serving.engine import (BucketedEngine, EngineConfig,
                                  PagedEngineConfig, PagedServingEngine)
from repro.serving.faults import FaultPlan, corrupt_swapped
from repro.serving.scheduler import (CANCELLED, SchedRequest, Scheduler,
                                     SchedulerConfig)

CFG = ModelConfig(name="robust-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [rng.integers(0, CFG.vocab_size, l) for l in (20, 45, 12, 30)]


def paged_cfg(**kw):
    kw.setdefault("max_slots", 5)
    kw.setdefault("prefill_chunk", 64)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def mk_paged(params, **kw):
    ecfg_kw = kw.pop("ecfg_kw", {})
    return PagedServingEngine(params, CFG,
                              lm.ServeConfig(stamp=None, kv=QUANT),
                              paged_cfg(**ecfg_kw), **kw)


# ---------------------------------------------------------------------------
# submit() validation — both engines
# ---------------------------------------------------------------------------


class TestSubmitValidation:
    @pytest.fixture(params=["paged", "bucketed"])
    def engine(self, request, params):
        if request.param == "paged":
            return mk_paged(params)
        return BucketedEngine(params, CFG,
                              lm.ServeConfig(stamp=None, kv=QUANT),
                              EngineConfig(max_batch=4, bucket=64,
                                           max_seq=96))

    def test_empty_prompt(self, engine):
        with pytest.raises(ValueError, match="empty prompt"):
            engine.submit(np.zeros(0, np.int32), 4)

    def test_nonpositive_max_new(self, engine):
        with pytest.raises(ValueError, match="max_new_tokens"):
            engine.submit(np.arange(5) % 128, 0)
        with pytest.raises(ValueError, match="max_new_tokens"):
            engine.submit(np.arange(5) % 128, -3)

    def test_overlong_prompt(self, engine):
        # paged limit: max_seq - 1 = 95; bucketed: min(bucket, max_seq-1)
        with pytest.raises(ValueError, match="prompt length"):
            engine.submit(np.arange(500) % 128, 4)

    def test_validation_rejects_before_enqueue(self, engine):
        try:
            engine.submit(np.zeros(0, np.int32), 4)
        except ValueError:
            pass
        done = getattr(engine, "queue", None)
        if done is not None:                     # bucketed
            assert done == []
        else:
            assert engine.sched.quiescent()      # paged: nothing queued


# ---------------------------------------------------------------------------
# lifecycle terminal states
# ---------------------------------------------------------------------------


class TestLifecycle:
    def test_finished_status_and_stats(self, params, prompts):
        pe = mk_paged(params)
        uids = [pe.submit(p, 4) for p in prompts[:2]]
        done = pe.run()
        assert sorted(r.uid for r in done) == sorted(uids)
        assert all(r.status == "finished" and r.error is None for r in done)
        assert pe.stats["finished"] == 2
        assert pe.sched.quiescent()

    def test_every_terminal_state_reaches_done(self, params, prompts):
        """finished + cancelled + rejected requests all come back from
        run(), each in exactly one terminal state."""
        pe = mk_paged(params, ecfg_kw=dict(num_lo_blocks=2))
        ok = pe.submit(prompts[2], 2)            # 12 tokens: fits 1 page
        bad = pe.submit(prompts[1], 40)          # capacity-infeasible
        gone = pe.submit(prompts[2], 2)
        assert pe.cancel(gone)
        assert not pe.cancel(gone)               # already terminal
        assert not pe.cancel(9999)               # unknown uid
        done = pe.run()
        by_uid = {r.uid: r for r in done}
        assert by_uid[ok].status == "finished"
        assert by_uid[bad].status == "rejected"
        assert by_uid[gone].status == "cancelled"
        assert pe.stats["cancelled"] == 1 and pe.stats["rejected"] == 1
        assert pe.sched.quiescent()


# ---------------------------------------------------------------------------
# cancellation — incl. the invariant audit at every prefill step index
# ---------------------------------------------------------------------------


class TestCancel:
    def test_cancel_mid_decode_releases_and_keeps_partial(self, params,
                                                          prompts):
        pe = mk_paged(params, ecfg_kw=dict(prefill_chunk=16))
        uid = pe.submit(prompts[0], 8)           # 20 tokens → 2 chunks
        other = pe.submit(prompts[2], 8)
        done = []
        for _ in range(4):                       # 2 chunks + 2 decodes
            pe._step(done)
        assert pe.cancel(uid)
        req = pe.request(uid)
        assert req.status == "cancelled"
        assert 0 < len(req.out_tokens) < 8       # partial generation kept
        done += pe.run()
        assert {r.uid for r in done} >= {uid, other}
        assert pe.request(other).status == "finished"
        assert pe.sched.quiescent()

    def test_cancel_at_every_step_index_leaks_nothing(self, params,
                                                      prompts):
        """Invariant audit (the PR-2 victim-release bug class): cancelling
        a multi-chunk prefill at EVERY engine step index — including
        mid-prefill, where the reservation runs ahead of the materialized
        prefix — must return the allocator and slot pool to fully free."""
        total_steps = None
        k = 0
        while True:
            pe = mk_paged(params, ecfg_kw=dict(prefill_chunk=16))
            uid = pe.submit(prompts[1], 4)       # 45 tokens → 3 chunks
            done = []
            for _ in range(k):
                if not pe.sched.has_work():
                    break
                pe._step(done)
            if not pe.sched.has_work():          # ran to completion first
                total_steps = k
                break
            assert pe.cancel(uid), f"cancel failed at step {k}"
            assert pe.sched.quiescent(), \
                f"leaked pages/slots cancelling at step {k}"
            assert pe.request(uid).status == "cancelled"
            k += 1
        assert total_steps >= 6                  # 3 chunks + 3 decodes

    def test_cancel_preempted_request_releases_host_copy(self):
        """Scheduler-level: cancel a request that is swapped out (pages on
        the host) — the release path must not touch the allocator twice
        nor leave the swap dict alive."""
        scfg = SchedulerConfig(max_slots=2, prefill_chunk=16)
        pcfg = PKV.PagedCacheConfig(block_size=8, num_lo_blocks=5,
                                    num_hi_blocks=3, max_blocks_per_seq=6,
                                    quant=QUANT)
        swaps = {}
        sched = Scheduler(scfg, pcfg,
                          swap_out=lambda r: swaps.setdefault(r.uid, {}),
                          swap_in=lambda r: None)
        a = SchedRequest(uid=1, prompt=np.zeros(16, np.int32),
                         max_new_tokens=4, arrival=1)
        sched.submit(a)
        sched.plan_step()
        a.pos = 16                               # chunk materialized
        sched._preempt(a)
        a.swapped = {"layer0": {}}               # engine would set this
        assert a.uid in swaps and a in sched.waiting
        got = sched.cancel(a.uid)
        assert got is a and a.state == CANCELLED
        assert a.swapped is None
        assert sched.quiescent()


# ---------------------------------------------------------------------------
# deadlines — injected clock, no sleeping
# ---------------------------------------------------------------------------


class TestDeadlines:
    def test_total_deadline_fails_at_plan_time(self, params, prompts):
        clk = [0.0]
        pe = mk_paged(params, clock=lambda: clk[0])
        late = pe.submit(prompts[0], 4, deadline_s=5.0)
        fine = pe.submit(prompts[2], 4)
        clk[0] = 10.0                            # past late's budget
        done = pe.run()
        by_uid = {r.uid: r for r in done}
        assert by_uid[late].status == "failed"
        assert "deadline miss" in by_uid[late].error
        assert by_uid[fine].status == "finished"
        assert pe.stats["deadline_misses"] == 1
        assert pe.sched.quiescent()

    def test_ttft_deadline_only_before_first_token(self, params, prompts):
        clk = [0.0]
        pe = mk_paged(params, clock=lambda: clk[0])
        uid = pe.submit(prompts[2], 6, ttft_deadline_s=5.0)
        done = []
        clk[0] = 1.0                             # inside the TTFT budget
        pe._step(done)                           # one chunk → first token
        assert pe.request(uid).ttft_s == 1.0
        clk[0] = 100.0                           # way past the TTFT budget
        done += pe.run()
        # first token already arrived — the TTFT deadline no longer applies
        assert pe.request(uid).status == "finished"
        assert pe.stats["deadline_misses"] == 0

    def test_ttft_deadline_miss(self, params, prompts):
        clk = [0.0]
        pe = mk_paged(params, clock=lambda: clk[0])
        uid = pe.submit(prompts[0], 4, ttft_deadline_s=1.0)
        clk[0] = 2.0
        done = pe.run()
        assert done[0].uid == uid and done[0].status == "failed"
        assert "TTFT" in done[0].error
        assert pe.sched.quiescent()


# ---------------------------------------------------------------------------
# bounded waiting queue + load shedding
# ---------------------------------------------------------------------------


class TestShedding:
    def test_reject_newest(self, params, prompts):
        pe = mk_paged(params, ecfg_kw=dict(max_waiting=2))
        keep = [pe.submit(prompts[2], 2) for _ in range(2)]
        shed = pe.submit(prompts[2], 2)          # queue full → newest out
        assert pe.request(shed).status == "rejected"
        assert "queue full" in pe.request(shed).error
        assert pe.stats["shed"] == 1
        done = pe.run()
        assert {r.uid for r in done} == set(keep) | {shed}
        assert all(pe.request(u).status == "finished" for u in keep)
        assert pe.sched.quiescent()

    def test_shed_oldest_makes_room_for_newest(self, params, prompts):
        pe = mk_paged(params, ecfg_kw=dict(max_waiting=2,
                                           shed_policy="shed_oldest"))
        old = pe.submit(prompts[2], 2)
        mid = pe.submit(prompts[2], 2)
        new = pe.submit(prompts[2], 2)           # sheds `old`, admits `new`
        assert pe.request(old).status == "rejected"
        assert pe.stats["shed"] == 1
        done = pe.run()
        assert {r.uid for r in done} == {old, mid, new}
        assert pe.request(mid).status == "finished"
        assert pe.request(new).status == "finished"
        assert pe.sched.quiescent()

    def test_unknown_policy_rejected_at_construction(self, params):
        with pytest.raises(ValueError, match="shed_policy"):
            mk_paged(params, ecfg_kw=dict(shed_policy="drop_everything"))


# ---------------------------------------------------------------------------
# watermark preemption + watchdog
# ---------------------------------------------------------------------------


class TestDegradationMachinery:
    def test_watermark_preempts_early_and_stays_bit_identical(self, params,
                                                              prompts):
        serve = lm.ServeConfig(stamp=None, kv=QUANT)
        # oracle: identical chunking/slots, ample pool, watermark off
        ample = PagedServingEngine(
            params, CFG, serve, paged_cfg(max_slots=3, prefill_chunk=16))
        for p in prompts[:3]:
            ample.submit(p, 5)
        want = {r.uid: r.out_tokens for r in ample.run()}

        pe = mk_paged(params, ecfg_kw=dict(
            max_slots=3, prefill_chunk=16, num_lo_blocks=9,
            preempt_watermark=0.5))
        for p in prompts[:3]:
            pe.submit(p, 5)
        got = {r.uid: r.out_tokens for r in pe.run()}
        assert pe.stats["preemptions"] > 0       # the watermark did fire
        assert any(kind == "preempt" for _, kind, _ in pe.events)
        for uid, toks in want.items():
            np.testing.assert_array_equal(got[uid], toks)
        assert pe.sched.quiescent()

    def test_watchdog_breaks_livelock(self, params, prompts):
        """Regression for the run() livelock: a request that can never be
        placed (here: allocator reports exhaustion forever) used to spin
        has_work() for eternity once nothing else was runnable.  The
        watchdog now fails the stuck request — not the engine."""
        fault = FaultPlan(seed=0, exhaust_steps=frozenset(range(1, 10_000)))
        pe = mk_paged(params, fault=fault,
                      ecfg_kw=dict(watchdog_steps=4))
        uid = pe.submit(prompts[0], 4)
        done = pe.run()                          # must terminate
        assert done[0].uid == uid
        assert done[0].status == "failed"
        assert "watchdog" in done[0].error
        assert pe.stats["watchdog_trips"] == 1
        assert pe.stats["stalled_steps"] >= 4
        assert pe.sched.quiescent()


# ---------------------------------------------------------------------------
# swap checksums (unit level; storm coverage in test_chaos.py)
# ---------------------------------------------------------------------------


class TestSwapChecksums:
    def _pools_and_pages(self, params):
        pcfg = PKV.PagedCacheConfig(block_size=16, num_lo_blocks=6,
                                    num_hi_blocks=2, max_blocks_per_seq=6,
                                    quant=QUANT)
        pools = lm.init_paged_cache(CFG, pcfg, num_slots=2)
        return pools, pcfg

    def test_roundtrip_passes_checksums(self, params):
        pools, _ = self._pools_and_pages(params)
        swapped = PKV.extract_pages(pools, [1], [2, 3], slot=0)
        assert PKV.CRC_KEY in swapped
        PKV.insert_pages(pools, swapped, [1], [2, 3], slot=0)  # no raise

    def test_corruption_refused_before_restore(self, params):
        pools, _ = self._pools_and_pages(params)
        swapped = PKV.extract_pages(pools, [1], [2, 3], slot=0)
        bad = corrupt_swapped(swapped, seed=11)
        with pytest.raises(PKV.SwapCorruption):
            PKV.insert_pages(pools, bad, [1], [2, 3], slot=0)

    def test_swapped_bytes_ignores_checksum_entry(self, params):
        pools, _ = self._pools_and_pages(params)
        swapped = PKV.extract_pages(pools, [1], [2, 3], slot=0)
        assert PKV.swapped_bytes(swapped) > 0    # ints under CRC_KEY skipped

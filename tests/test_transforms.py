"""Property tests for sequence transforms: orthonormality, invertibility,
energy concentration, Theorem 1, optimal bit allocation (paper §3, App. A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitalloc, error_bounds as EB, quant as Q
from repro.core import transforms as T
from repro.core.calibration import SiteStats, toeplitz_fraction
from repro.core.stamp import StampConfig, stamp_fake_quant
from repro.data.pipeline import ar_features

jax.config.update("jax_platform_name", "cpu")

KINDS = ["dwt", "dct", "wht"]


def correlated(shape, rho=0.95, seed=0):
    return jnp.asarray(ar_features(shape, rho=rho, seed=seed))


class TestOrthonormal:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("skip_first", [False, True])
    def test_roundtrip(self, kind, skip_first):
        x = correlated((2, 128, 32))
        tx = T.sequence_transform(x, kind, levels=4, skip_first=skip_first)
        back = T.inverse_sequence_transform(tx, kind, levels=4,
                                            skip_first=skip_first)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=1e-4)

    @pytest.mark.parametrize("kind", KINDS)
    def test_norm_preserved(self, kind):
        """Eq. 10: orthogonal L leaves the Frobenius norm unchanged."""
        x = correlated((2, 64, 16), seed=1)
        tx = T.sequence_transform(x, kind, levels=3)
        assert abs(float(jnp.linalg.norm(tx) / jnp.linalg.norm(x)) - 1) < 1e-4

    @settings(deadline=None, max_examples=15)
    @given(s=st.sampled_from([32, 48, 64, 100, 128]),
           seed=st.integers(0, 50))
    def test_dwt_roundtrip_odd_lengths(self, s, seed):
        """Non-pow2 lengths: identity-block fallback stays invertible."""
        x = correlated((1, s, 8), seed=seed)
        tx = T.haar_dwt(x, levels=3)
        back = T.haar_idwt(tx, levels=3)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=1e-4)

    def test_dwt2d_roundtrip(self):
        x = correlated((2, 16 * 16, 8), seed=2)
        tx = T.haar_dwt_2d(x, (16, 16), levels=3)
        back = T.haar_idwt_2d(tx, (16, 16), levels=3)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=1e-4)

    def test_klt_roundtrip(self):
        x = correlated((4, 32, 16), seed=3)
        stats = SiteStats.empty(32, 16)
        stats.update(np.asarray(x))
        basis = stats.klt()
        tx = T.apply_matrix(x, basis)
        back = T.apply_matrix(tx, basis, inverse=True)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=1e-3)


class TestEnergyConcentration:
    def test_ordering_klt_best(self):
        """§3.2: KLT is the optimal energy compactor; DCT ≈ KLT on
        Toeplitz-ish data; DWT concentrates into the first s/2^L band."""
        x = correlated((8, 64, 32), rho=0.95, seed=4)
        stats = SiteStats.empty(64, 32)
        stats.update(np.asarray(x))

        def head_energy(kind):
            e = stats.energy_profile(kind, levels=3)
            es = np.sort(e)[::-1]
            return es[:8].sum() / es.sum()

        klt = head_energy("klt")
        dct = head_energy("dct")
        dwt = head_energy("dwt")
        uniform = 8 / 64
        assert klt >= dct - 1e-3 >= 0
        assert min(klt, dct, dwt) > 1.5 * uniform
        assert klt >= dwt - 1e-3

    def test_toeplitz_premise(self):
        x = correlated((8, 64, 32), rho=0.95, seed=5)
        stats = SiteStats.empty(64, 32)
        stats.update(np.asarray(x))
        assert toeplitz_fraction(stats.autocorr) > 0.9

    def test_dwt_energy_in_lowpass_band(self):
        x = correlated((4, 128, 16), rho=0.95, seed=6)
        tx = T.haar_dwt(x, levels=3)
        e = np.asarray(jnp.sum(tx**2, axis=(0, -1)))
        assert e[:16].sum() / e.sum() > 0.6


class TestTheorem1:
    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 100), num_hi=st.sampled_from([4, 16, 32]))
    def test_bound_holds(self, seed, num_hi):
        x = correlated((2, 64, 32), seed=seed)
        tx = T.haar_dwt(x, levels=3)
        bits = bitalloc.two_level_bits(64, num_hi)
        q = Q.fake_quant(tx, bits, axis=-1)
        err = float(jnp.sum((q - tx) ** 2))
        bound = float(EB.theorem1_bound(tx, bits))
        assert err <= bound * (1 + 1e-4)

    def test_eq10_orthogonal_invariance(self):
        """L(X; L) == L(LX) for orthogonal L (Appendix A.1)."""
        x = correlated((2, 64, 16), seed=7)
        tx = T.haar_dwt(x, levels=3)
        q = Q.fake_quant(tx, 4, axis=-1)
        err_transformed = float(jnp.sum((q - tx) ** 2))
        back = T.haar_idwt(q, levels=3)
        err_original = float(jnp.sum((back - x) ** 2))
        assert abs(err_transformed - err_original) / err_original < 1e-3


class TestBitAllocation:
    def test_eq18_matches_closed_form(self):
        e = np.array([16.0, 4.0, 1.0, 0.25])
        b = np.asarray(bitalloc.optimal_bits(jnp.asarray(e), 16.0))
        assert abs(b.sum() - 16.0) < 1e-4
        # b_i - b_j == log2 sqrt(e_i / e_j)
        assert abs((b[0] - b[1]) - 1.0) < 1e-5

    def test_eq18_is_optimal_vs_perturbations(self):
        """Perturbing the optimal allocation never lowers the Thm-1 bound."""
        rng = np.random.default_rng(0)
        e = jnp.asarray(rng.uniform(0.1, 10.0, 16).astype(np.float32))
        b_opt = bitalloc.optimal_bits(e, 64.0)
        base = float(bitalloc.bound_value(e, b_opt, d=32))
        for _ in range(20):
            delta = rng.normal(size=16).astype(np.float32) * 0.3
            delta -= delta.mean()   # keep the budget fixed
            perturbed = float(bitalloc.bound_value(e, b_opt + delta, d=32))
            assert perturbed >= base - 1e-4

    def test_jensen_gap(self):
        """Appendix A.3: concentrated ≤ uniform."""
        rng = np.random.default_rng(1)
        e = jnp.asarray(rng.lognormal(0, 2.0, 64).astype(np.float32))
        uniform, conc = EB.uniform_vs_concentrated(e, avg_bits=4.0, d=32)
        assert float(conc) <= float(uniform) + 1e-6

    def test_integer_allocation_respects_budget(self):
        rng = np.random.default_rng(2)
        e = rng.lognormal(0, 1.5, 32)
        b = bitalloc.integer_rounded_allocation(e, total_bits=128)
        assert b.sum() == 128
        assert b.min() >= 2 and b.max() <= 8


class TestStampEndToEnd:
    def test_stamp_beats_uniform_at_matched_bits(self):
        """The paper's headline: DWT + mixed precision < uniform error."""
        x = correlated((4, 512, 64), rho=0.95, seed=8)
        cfg = StampConfig(num_hi_tokens=32, skip_first_token=False)
        avg = cfg.average_bits(512)
        uniform = Q.fake_quant(x, avg, axis=-1)
        stamped = stamp_fake_quant(x, cfg)
        err_u = float(jnp.sum((uniform - x) ** 2))
        err_s = float(jnp.sum((stamped - x) ** 2))
        assert err_s < err_u

    @pytest.mark.parametrize("kind", KINDS)
    def test_all_transforms_improve(self, kind):
        """Fig. 7: DCT ≈ WHT ≈ DWT all beat no-transform."""
        x = correlated((4, 256, 32), rho=0.95, seed=9)
        cfg = StampConfig(seq_transform=kind, num_hi_tokens=32,
                          skip_first_token=False)
        none_cfg = StampConfig(seq_transform="none", num_hi_tokens=32,
                               skip_first_token=False)
        err_t = float(jnp.sum((stamp_fake_quant(x, cfg) - x) ** 2))
        err_n = float(jnp.sum((stamp_fake_quant(x, none_cfg) - x) ** 2))
        assert err_t < err_n

    def test_skip_first_token_preserves_it(self):
        x = correlated((1, 64, 16), seed=10)
        x = x.at[0, 0].set(100.0)   # attention-sink outlier
        cfg = StampConfig(num_hi_tokens=8, skip_first_token=True)
        tx = jnp.asarray(
            stamp_fake_quant(x, cfg))
        # first token still carries its outlier (hi-precision, unmixed)
        assert float(jnp.abs(tx[0, 0] - x[0, 0]).max()) < \
            float(jnp.abs(x[0, 0]).max()) * 0.02
